"""Online inference filling: Poisson requests served inside training
bubbles via pull-and-execute (paper §3.3), vs the same load on a dedicated
(exclusive) engine.

  PYTHONPATH=src python examples/online_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import SpecInFConfig, TrainConfig
from repro.core import SpecInFRuntime
from repro.core.profiles import dp_profile
from repro.data.pipeline import SyntheticDataset
from repro.launch.mesh import make_dev_mesh
from repro.runtime.step import make_train_step
from repro.serving.core import Priority, SamplingParams
from repro.serving.engine import InferenceEngine


def main():
    cfg = configs.smoke_config("olmo-1b")
    mesh = make_dev_mesh()
    tcfg = TrainConfig(learning_rate=1e-3, fsdp=False, zero1=False)
    art = make_train_step(cfg, tcfg, mesh)
    step = art.jitted(donate=False)
    state = art.init_state(jax.random.PRNGKey(0))
    ds = SyntheticDataset(cfg=cfg, seq_len=48, global_batch=4)

    def batches():
        while True:
            b = ds.next_batch()
            yield {k: jnp.asarray(v) for k, v in b.items()}

    engine = InferenceEngine(cfg, state["params"], max_slots=2, max_seq=48)
    profile = dp_profile(cfg.name, compute_s=0.05, comm_s=0.04)
    rt = SpecInFRuntime(
        train_step=lambda s, b: step(s, b), train_state=state,
        batch_iter=batches(), profile=profile, engine=engine,
        cfg=SpecInFConfig(busy_hold_ms=5.0), decode_microstep_s=0.002,
    )
    # submit the Poisson arrivals straight into the engine core (ONLINE
    # priority): Algorithm 1's policy pulls them inside idle windows, and
    # preempts offline slots if capacity ever blocks an arrival
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(0.05, 12))
    requests = [
        engine.core.submit(
            rng.integers(0, cfg.vocab_size, 6),
            SamplingParams(max_new_tokens=4),
            priority=Priority.ONLINE, arrival_time=float(t),
        )
        for t in arrivals
    ]
    t0 = time.time()
    m = rt.run(num_iterations=12)
    print(f"trained {m.train_iterations} iterations "
          f"(loss {m.train_losses[0]:.3f} -> {m.train_losses[-1]:.3f}) in "
          f"{time.time()-t0:.1f}s wall")
    print(f"online: served {m.online_served}/{len(requests)} requests inside "
          f"bubbles, p95 latency {m.p95_latency_s()*1e3:.1f} ms, "
          f"p95 TTFT {m.p95_ttft_s()*1e3:.1f} ms (virtual)")
    print("phases:", m.phase_counts)


if __name__ == "__main__":
    main()
