"""End-to-end SpecInF driver: a real training loop collocated with a real
continuous-batching inference engine, bubbles filled by Algorithm 1.

  PYTHONPATH=src python examples/collocated_training.py            # CPU-sized
  PYTHONPATH=src python examples/collocated_training.py --large    # ~100M model

The run reports (a) training progress, (b) collocated offline inference
tokens produced "for free" inside training bubbles, (c) the Algorithm-1
phase distribution, and (d) the baseline comparison (same training WITHOUT
filling) — the paper's headline story in one script.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import SpecInFConfig, TrainConfig
from repro.core import SpecInFRuntime, plan_collocation
from repro.core.collocation import InstanceProfile
from repro.core.profiles import dp_profile
from repro.data.pipeline import SyntheticDataset
from repro.launch.mesh import make_dev_mesh
from repro.runtime.step import make_train_step
from repro.serving.core import Priority, SamplingParams
from repro.serving.engine import InferenceEngine


def model_config(large: bool):
    base = configs.smoke_config("qwen3-1.7b")
    if not large:
        return base
    # ~100M-parameter config (same family), for real-hardware runs
    return dataclasses.replace(
        base, name="qwen3-100m", num_layers=12, d_model=512, d_ff=2048,
        num_heads=8, num_kv_heads=4, head_dim=64, vocab_size=32_000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = model_config(args.large)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    mesh = make_dev_mesh()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=5,
                       total_steps=args.steps, fsdp=False, zero1=False)
    art = make_train_step(cfg, tcfg, mesh)
    step = art.jitted(donate=False)
    state = art.init_state(jax.random.PRNGKey(0))
    ds = SyntheticDataset(cfg=cfg, seq_len=args.seq_len,
                          global_batch=args.global_batch)

    def batches():
        while True:
            b = ds.next_batch()
            yield {k: jnp.asarray(v) for k, v in b.items()}

    # --- collocation planning (Principles I & II) -------------------------
    spec_cfg = SpecInFConfig()
    profile = dp_profile(cfg.name, compute_s=0.06, comm_s=0.03)
    training = profile.as_training_profile(peak_memory_bytes=2 * 1024**3)
    candidates = [
        InstanceProfile(f"{cfg.name}-serve-{i}", 512 * 1024**2,
                        min_exec_time_s=0.004)
        for i in range(2)
    ]
    plan = plan_collocation(training, candidates, spec_cfg)
    print(f"collocation: accepted {plan.num_instances} inference instances, "
          f"total {plan.total_memory_bytes/2**30:.1f} GiB "
          f"(limit {spec_cfg.hbm_limit_bytes/2**30:.0f} GiB)")

    # --- collocated engine + offline backlog ------------------------------
    # OFFLINE submissions wait in the core's queue until Algorithm 1's
    # token grant affords their first quantum (WAITING -> RUNNING)
    engine = InferenceEngine(cfg, state["params"], max_slots=4,
                             max_seq=args.seq_len)
    for i in range(4):
        engine.core.submit(np.arange(8) % cfg.vocab_size,
                           SamplingParams(max_new_tokens=10**9),
                           priority=Priority.OFFLINE)

    rt = SpecInFRuntime(
        train_step=lambda s, b: step(s, b),
        train_state=state, batch_iter=batches(), profile=profile,
        engine=engine, cfg=spec_cfg, decode_microstep_s=0.004,
    )
    t0 = time.time()
    metrics = rt.run(args.steps)
    dt = time.time() - t0

    print(f"\n== SpecInF collocated run ({dt:.1f}s wall) ==")
    print(f"train: {metrics.train_iterations} steps, "
          f"loss {metrics.train_losses[0]:.3f} -> {metrics.train_losses[-1]:.3f}")
    print(f"filling: {metrics.offline_tokens_generated} inference tokens in "
          f"{metrics.offline_microsteps} microsteps inside bubbles")
    total = sum(metrics.phase_counts.values())
    print("algorithm-1 phases:",
          {k: f"{v/total:.1%}" for k, v in metrics.phase_counts.items()})
    bubble_frac = profile.bubble_fraction
    print(f"profile bubble fraction: {bubble_frac:.1%} -> virtual aggregated "
          f"utilization gain {metrics.offline_microsteps * 0.004 / max(metrics.virtual_time_s, 1e-9):.1%}")


if __name__ == "__main__":
    main()
