"""Quickstart: build an assigned architecture, train a few steps, decode.

  PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]

Runs the REDUCED (smoke) config so it finishes on CPU in seconds; on real
hardware drop ``smoke_config`` for ``get_config`` and a production mesh.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticDataset
from repro.launch.mesh import make_dev_mesh
from repro.runtime.step import make_train_step
from repro.serving.core import Priority, SamplingParams
from repro.serving.engine import InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(configs.ARCH_IDS))
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch)
    mesh = make_dev_mesh()
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=args.steps,
                       fsdp=False, zero1=False)

    # ---- train a few steps -------------------------------------------------
    art = make_train_step(cfg, tcfg, mesh)
    step = art.jitted(donate=False)
    state = art.init_state(jax.random.PRNGKey(0))
    ds = SyntheticDataset(cfg=cfg, seq_len=64, global_batch=8)
    for i in range(args.steps):
        b = ds.next_batch()
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = step(state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f}")

    # ---- greedy decode through the engine lifecycle core ------------------
    # submit() queues the request; stream() yields tokens as EngineCore.step()
    # quanta produce them (prefill -> first token, fused decode -> the rest).
    params = state["params"]
    engine = InferenceEngine(cfg, params, max_slots=1, max_seq=32)
    prompt = np.arange(8) % cfg.vocab_size
    req = engine.core.submit(
        prompt, SamplingParams(max_new_tokens=9), priority=Priority.ONLINE
    )
    out = list(engine.core.stream(req))
    print("prompt:", prompt.tolist())
    print("generated:", out, f"({req.finish_reason})")


if __name__ == "__main__":
    main()
