"""CI guard: the deprecated InferenceEngine shim must not drift from the
EngineCore it delegates to.

Three checks (all signature-shape based, so they are stable across Python
versions' annotation formatting):

1. The shim methods (``add_request`` / ``decode_loop`` /
   ``spec_decode_loop``) keep their pinned parameter lists — callers from
   PR 1-3 must keep working unchanged.
2. Each shim's core delegate (``add_legacy`` / ``run_legacy``) accepts the
   shim's parameters, so delegation cannot silently lose an argument.
3. The EngineCore public surface (``submit`` / ``step`` / ``stream`` /
   ``abort`` / ``preempt``) keeps its pinned parameter lists.
4. The legacy engine counters stay thin ``RegistryCounterView`` descriptors
   over their pinned stable registry names (DESIGN.md §8) — renaming a
   stable name or demoting a view back to a plain attribute breaks every
   dashboard/bench that reads the registry.

    PYTHONPATH=src python scripts/check_api_surface.py
"""
from __future__ import annotations

import inspect
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.metrics import STABLE_NAMES  # noqa: E402
from repro.serving.core import EngineCore  # noqa: E402
from repro.serving.engine import (  # noqa: E402
    InferenceEngine,
    RegistryCounterView,
)

#: shim method -> (pinned params, core delegate it must route through)
SHIMS = {
    "add_request": (["req"], "add_legacy"),
    "decode_loop": (["k"], "run_legacy"),
    "spec_decode_loop": (["k", "gamma"], "run_legacy"),
}

#: EngineCore public surface -> pinned params
CORE_SURFACE = {
    "submit": ["prompt", "sampling", "priority", "arrival_time"],
    "step": ["grant"],
    "stream": ["req", "grant"],
    "abort": ["req"],
    "preempt": ["target"],
    "add_legacy": ["req"],
    "run_legacy": ["k", "gamma"],
}

#: legacy engine counter attribute -> pinned stable registry name
ENGINE_COUNTER_VIEWS = {
    "d2h_transfers": "engine/d2h_transfers",
    "steps_executed": "engine/steps_executed",
    "generated_tokens_total": "engine/generated_tokens",
    "prefill_prompt_tokens": "engine/prefill_prompt_tokens",
    "prefill_skipped_tokens": "engine/prefill_skipped_tokens",
    "prefill_metered_tokens": "engine/prefill_metered_tokens",
    "spec_rounds": "engine/spec_rounds",
    "spec_drafted": "engine/spec_drafted",
    "spec_accepted": "engine/spec_accepted",
}


def params_of(fn) -> list[str]:
    return [p for p in inspect.signature(fn).parameters if p != "self"]


def main() -> int:
    failures = []
    for name, (pinned, delegate) in SHIMS.items():
        shim = getattr(InferenceEngine, name, None)
        if shim is None:
            failures.append(f"InferenceEngine.{name} is missing")
            continue
        got = params_of(shim)
        if got != pinned:
            failures.append(
                f"InferenceEngine.{name} signature drifted: "
                f"{got} != pinned {pinned}"
            )
        core_fn = getattr(EngineCore, delegate, None)
        if core_fn is None:
            failures.append(f"EngineCore.{delegate} is missing")
            continue
        missing = [p for p in pinned if p not in params_of(core_fn)]
        if missing:
            failures.append(
                f"EngineCore.{delegate} no longer accepts {missing} "
                f"(shim InferenceEngine.{name} passes them)"
            )
        if delegate not in inspect.getsource(shim):
            failures.append(
                f"InferenceEngine.{name} no longer delegates to "
                f"EngineCore.{delegate}"
            )
    for name, pinned in CORE_SURFACE.items():
        fn = getattr(EngineCore, name, None)
        if fn is None:
            failures.append(f"EngineCore.{name} is missing")
            continue
        got = params_of(fn)
        if got != pinned:
            failures.append(
                f"EngineCore.{name} signature drifted: {got} != pinned "
                f"{pinned}"
            )
    for attr, stable in ENGINE_COUNTER_VIEWS.items():
        view = inspect.getattr_static(InferenceEngine, attr, None)
        if not isinstance(view, RegistryCounterView):
            failures.append(
                f"InferenceEngine.{attr} is no longer a RegistryCounterView"
            )
            continue
        if view.name != stable:
            failures.append(
                f"InferenceEngine.{attr} reads registry name {view.name!r}, "
                f"pinned {stable!r}"
            )
        if STABLE_NAMES.get(stable) != "counter":
            failures.append(
                f"{stable!r} is not registered as a counter in STABLE_NAMES"
            )
    if failures:
        print("API surface drift between the deprecated shim and EngineCore:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"OK: {len(SHIMS)} shim methods, {len(CORE_SURFACE)} core "
          f"methods, and {len(ENGINE_COUNTER_VIEWS)} counter views match "
          "the pinned surface")
    return 0


if __name__ == "__main__":
    sys.exit(main())
