"""CI gate: schema-validate the structured step-trace artifact.

``benchmarks/engine_micro.py`` (``bench_observability``) writes
``TRACE_engine.jsonl`` from a collocated virtual-clock run; this script
re-validates it with the dependency-free validator in ``repro.obs.schema``
(no third-party jsonschema package — nothing may be pip-installed in CI)
and additionally checks the SLO attribution identity on the trace itself:
every finished request's queueing/prefill/decode/preempted segments must
sum to its end-to-end latency on the engine's single clock.

    PYTHONPATH=src python scripts/check_trace_schema.py [TRACE_engine.jsonl]
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import attribute, validate_jsonl  # noqa: E402

TOL = 1e-6  # float-addition tolerance for the telescoping identity


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "TRACE_engine.jsonl"
    n, errors = validate_jsonl(path)
    if errors:
        print(f"{path}: {n} events, {len(errors)} schema errors:")
        for e in errors:
            print(f"  - {e}")
        return 1
    with open(path) as f:
        events = [json.loads(line) for line in f.read().splitlines()[1:]]
    att = attribute(events)
    finished = {r: a for r, a in att.items() if a.finish_time is not None}
    bad = []
    for rid, ra in sorted(finished.items()):
        lat = ra.finish_time - ra.arrival_time
        if abs(ra.total - lat) > TOL:
            bad.append((rid, ra.total, lat))
    if bad:
        print(f"{path}: attribution identity violated for {len(bad)} "
              "requests:")
        for rid, tot, lat in bad[:10]:
            print(f"  - req {rid}: segments sum to {tot}, latency is {lat}")
        return 1
    if not finished:
        print(f"{path}: no finished requests in the trace — the bench "
              "workload has gone stale")
        return 1
    print(
        f"OK: {path} — {n} schema-valid events; attribution identity holds "
        f"for all {len(finished)} finished requests"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
