"""CI gate: seeded chaos sweep over the failure-containment machinery
(DESIGN.md §9).

Two deterministic sweeps, both on the virtual clock so every run is
reproducible from its seed alone:

* **Serving sweep** — a mixed online/offline workload drains through
  ``EngineCore.step()`` with every serving-side fault point armed at
  once (NaN logits, transient page-allocation failures, mid-quantum
  revocation, slow-step overruns).  Pass criteria per seed:

  - zero crashes: the drain completes without an exception or a hang;
  - containment: every request reaches a terminal state, and every
    request that finished normally (not shed/expired, not past its
    retry budget) produced a token stream BYTE-IDENTICAL to the
    fault-free reference run;
  - attribution: the step tracer's SLO segments still telescope to
    end-to-end latency (max residual <= 1e-6) and no events dropped —
    faults must not corrupt the observability layer.

* **Early-resume sweep** — a collocated ``SpecInFRuntime`` run where
  training resumes before the predicted bubble end.  The armed
  revocation must yield the GPU within the documented bound (one
  sub-dispatch of ``revocation_check_steps`` microsteps, 3x slack for
  window granularity) and training's virtual step time must equal the
  no-serving baseline exactly — revocation is how serving pays for the
  overrun, so training never does.

* **Recovery sweep** (DESIGN.md §11) — the same mixed workload with the
  ``process/kill`` fault point armed (both consult sites: between and
  mid-quantum) and a write-ahead journal attached.  Each kill abandons
  the engine, truncates the journal to its fsynced prefix (the real loss
  model), rebuilds a fresh engine, and replays.  Pass criteria per seed,
  for BOTH the paged and dense KV layouts:

  - exactly-once: every submitted request has exactly one durable
    finish record — nothing lost, nothing duplicated;
  - byte-identity: every clean finish's journaled token stream equals
    the uninterrupted (never-killed) reference run's;
  - attribution still telescopes on the final incarnation's tracer.

    JAX_PLATFORMS=cpu PYTHONPATH=src python scripts/check_chaos.py
    # or one sweep only:
    JAX_PLATFORMS=cpu PYTHONPATH=src python scripts/check_chaos.py \\
        --only recovery
"""
from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import SpecInFConfig  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.obs import Observability  # noqa: E402
from repro.resilience import (  # noqa: E402
    FaultInjector,
    FaultSpec,
    ProcessKilled,
    RequestJournal,
    read_journal,
)
from repro.serving.core import (  # noqa: E402
    Grant,
    Priority,
    RevocationSignal,
    SamplingParams,
)
from repro.serving.engine import InferenceEngine, Request  # noqa: E402

SERVE_SEEDS = (1, 2, 3, 4, 5)
RESUME_SEEDS = (1, 2, 3)
STEP_S = 0.002
MAX_QUANTA = 5000  # drain cap: exceeding it counts as a hang (a crash)
ATTRIBUTION_TOL = 1e-6

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))

#: every serving-side fault point, armed together — containment domains
#: must hold when faults overlap, not just one family at a time
SERVE_SPECS = (
    FaultSpec("engine/nan_logits", probability=0.05, max_fires=3),
    FaultSpec("pool/alloc_fail", probability=0.05, after=2, max_fires=3),
    FaultSpec("core/revoke_mid_quantum", probability=0.05, max_fires=3),
    FaultSpec("core/step_overrun", probability=0.05, max_fires=3),
)

#: finish reasons whose token streams must match the fault-free run;
#: "expired" (shed / queue deadline) and "error" (retry budget spent)
#: are legitimate chaos outcomes and are reported, not compared
CLEAN_REASONS = ("length", "stop")


def serve_run(injector):
    """Drain the fixed mixed workload; returns (engine, requests)."""
    vnow = [0.0]
    engine = InferenceEngine(
        CFG, PARAMS, max_slots=2, max_seq=128, clock=lambda: vnow[0],
        kv_pool_pages=24, obs=Observability(tracing=True),
        fault_injector=injector,
    )
    core = engine.core
    core.fault_backoff_s = 0.0  # virtual-clock run: retry immediately
    rng = np.random.default_rng(0)
    reqs = [
        core.submit(
            rng.integers(0, CFG.vocab_size, 8),
            SamplingParams(max_new_tokens=16),
            priority=Priority.OFFLINE, arrival_time=0.0,
        )
        for _ in range(4)
    ]
    for t in np.cumsum(rng.exponential(0.01, 6)):
        reqs.append(core.submit(
            rng.integers(0, CFG.vocab_size, 8),
            SamplingParams(max_new_tokens=4, deadline_s=5.0),
            priority=Priority.ONLINE, arrival_time=float(t),
        ))
    quanta = 0
    while core.has_unfinished:
        quanta += 1
        if quanta > MAX_QUANTA:
            raise RuntimeError(
                f"drain exceeded {MAX_QUANTA} quanta — containment hang"
            )
        base = vnow[0]
        out = core.step(Grant(
            now=base, token_budget=16,
            revocation=RevocationSignal(), revoke_check_steps=2,
            advance_clock=lambda steps, b=base: vnow.__setitem__(
                0, b + steps * STEP_S
            ),
        ))
        if out.cost_steps == 0 and not out.admitted:
            vnow[0] += STEP_S  # idle until the next arrival
    return engine, reqs


def check_attribution(engine) -> float:
    tr = engine.obs.tracer
    if tr.dropped:
        raise AssertionError(f"tracer dropped {tr.dropped} events")
    resid = [
        abs(ra.total - (ra.finish_time - ra.arrival_time))
        for ra in tr.attribution().values()
        if ra.finish_time is not None
    ]
    return max(resid) if resid else 0.0


def serve_sweep() -> int:
    ref_engine, ref = serve_run(None)
    assert all(r.finish_reason in CLEAN_REASONS for r in ref), (
        "fault-free reference must finish every request normally"
    )
    failures = 0
    for seed in SERVE_SEEDS:
        inj = FaultInjector(seed=seed, specs=SERVE_SPECS)
        try:
            engine, reqs = serve_run(inj)
        except Exception:
            traceback.print_exc()
            print(f"FAIL seed={seed}: chaos run crashed")
            failures += 1
            continue
        unfinished = [r for r in reqs if not r.state.finished]
        mismatched = [
            i for i, (r, rr) in enumerate(zip(reqs, ref))
            if r.finish_reason in CLEAN_REASONS
            and (r.finish_reason != rr.finish_reason
                 or r.output_tokens != rr.output_tokens)
        ]
        resid = check_attribution(engine)
        clean = sum(r.finish_reason in CLEAN_REASONS for r in reqs)
        errors = sum(r.finish_reason == "error" for r in reqs)
        expired = sum(r.finish_reason == "expired" for r in reqs)
        print(
            f"seed={seed}: fires={inj.fires} clean={clean}/{len(reqs)} "
            f"error={errors} expired={expired} "
            f"attribution_residual={resid:.2e}"
        )
        if unfinished:
            print(f"FAIL seed={seed}: {len(unfinished)} requests never "
                  f"reached a terminal state")
            failures += 1
        if mismatched:
            print(f"FAIL seed={seed}: requests {mismatched} finished "
                  f"normally but diverged from the fault-free reference")
            failures += 1
        if resid > ATTRIBUTION_TOL:
            print(f"FAIL seed={seed}: SLO attribution residual {resid} "
                  f"> {ATTRIBUTION_TOL}")
            failures += 1
    return failures


def resume_sweep() -> int:
    from repro.core import SpecInFRuntime
    from repro.core.profiles import dp_profile

    iterations = 4
    compute_s, comm_s = 0.02, 0.04
    baseline_s = iterations * (compute_s + comm_s * 0.7)  # overlap 0.3
    failures = 0
    for seed in RESUME_SEEDS:
        eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=128)
        for _ in range(2):
            eng.add_request(Request(prompt=np.arange(8),
                                    max_new_tokens=1000))
        inj = FaultInjector(seed=seed, specs=(
            FaultSpec("runtime/early_resume", probability=0.5, max_fires=2),
        ))
        rt = SpecInFRuntime(
            train_step=lambda s, b: (s, {}),
            train_state=None,
            batch_iter=iter(lambda: {}, None),
            profile=dp_profile("tiny", compute_s=compute_s, comm_s=comm_s),
            engine=eng,
            cfg=SpecInFConfig(),
            decode_microstep_s=0.004,
            faults=inj,
        )
        try:
            rt.run(num_iterations=iterations)
        except Exception:
            traceback.print_exc()
            print(f"FAIL seed={seed}: early-resume run crashed")
            failures += 1
            continue
        m = eng.obs.metrics
        fires = inj.fires.get("runtime/early_resume", 0)
        resumed = m.counter("fault/early_resume").value
        h = m.histogram("fault/revocation_overrun_s")
        worst = max(h.values()) if h.count else 0.0
        bound = rt.decode_microstep_s * 3  # one sub-dispatch + granularity
        print(f"seed={seed}: early_resumes={resumed}/{fires} "
              f"worst_overrun={worst * 1e3:.3f} ms "
              f"(bound {bound * 1e3:.1f} ms) "
              f"train_virtual={rt.metrics.virtual_time_s:.4f} s "
              f"(baseline {baseline_s:.4f} s)")
        if resumed != fires:
            print(f"FAIL seed={seed}: {fires} injected early resumes but "
                  f"{resumed} recorded")
            failures += 1
        if worst > bound + 1e-9:
            print(f"FAIL seed={seed}: revocation overran the yield bound")
            failures += 1
        if abs(rt.metrics.virtual_time_s - baseline_s) > 1e-9:
            print(f"FAIL seed={seed}: training step time diverged from "
                  f"the no-serving baseline under revocation")
            failures += 1
        if rt.metrics.train_iterations != iterations:
            print(f"FAIL seed={seed}: training did not run to completion")
            failures += 1
    return failures


# ---------------------------------------------------------------------------
# Recovery sweep: kill -> restore -> drain (DESIGN.md §11)
# ---------------------------------------------------------------------------

RECOVERY_SEEDS = (1, 2, 3, 4, 5)
MAX_RESTARTS = 10  # a kill budget of 3 can never need more


def _recovery_engine(vnow, injector, paged):
    kw = {"kv_pool_pages": 24} if paged else {"kv_page_size": 0}
    return InferenceEngine(
        CFG, PARAMS, max_slots=2, max_seq=128, clock=lambda: vnow[0],
        obs=Observability(tracing=True), fault_injector=injector, **kw,
    )


def _submit_workload(core):
    """The serve_sweep workload, resubmitted identically per run."""
    rng = np.random.default_rng(0)
    reqs = [
        core.submit(
            rng.integers(0, CFG.vocab_size, 8),
            SamplingParams(max_new_tokens=16),
            priority=Priority.OFFLINE, arrival_time=0.0,
        )
        for _ in range(4)
    ]
    for t in np.cumsum(rng.exponential(0.01, 6)):
        reqs.append(core.submit(
            rng.integers(0, CFG.vocab_size, 8),
            SamplingParams(max_new_tokens=4, deadline_s=5.0),
            priority=Priority.ONLINE, arrival_time=float(t),
        ))
    return reqs


def _drain(core, vnow):
    quanta = 0
    while core.has_unfinished:
        quanta += 1
        if quanta > MAX_QUANTA:
            raise RuntimeError(
                f"drain exceeded {MAX_QUANTA} quanta — containment hang"
            )
        base = vnow[0]
        out = core.step(Grant(
            now=base, token_budget=16,
            revocation=RevocationSignal(), revoke_check_steps=2,
            advance_clock=lambda steps, b=base: vnow.__setitem__(
                0, b + steps * STEP_S
            ),
        ))
        if out.cost_steps == 0 and not out.admitted:
            vnow[0] += STEP_S


def _journal_streams(path):
    """(tokens, finish-records) per request id from the durable journal."""
    records, _ = read_journal(path)
    toks: dict = {}
    fins: dict = {}
    for rec in records:
        if rec["k"] == "delta":
            cur = toks.setdefault(rec["rid"], [])
            if rec["tot"] == len(cur) + len(rec["tok"]):
                cur.extend(rec["tok"])
        elif rec["k"] == "fin":
            fins.setdefault(rec["rid"], []).append(rec)
    return toks, fins


def kill_run(seed, path, paged):
    """Run the workload to completion across simulated process deaths.

    Returns ``(final_engine, rid0, restarts, kills)``: each ProcessKilled
    abandons the engine, truncates the journal to its fsynced prefix, and
    rebuilds from replay — the workload is submitted exactly once, in the
    first incarnation."""
    inj = FaultInjector(seed=seed, specs=(
        FaultSpec("process/kill", probability=0.05, max_fires=3),
    ))
    restarts = 0
    rid0 = None
    while True:
        vnow = [0.0]
        engine = _recovery_engine(vnow, inj, paged)
        core = engine.core
        core.fault_backoff_s = 0.0
        journal = RequestJournal(path, fsync_interval=4)
        journal.recover_into(core)
        journal.attach(core)
        if rid0 is None:
            rid0 = _submit_workload(core)[0].request_id
        try:
            _drain(core, vnow)
        except ProcessKilled:
            journal.crash()
            restarts += 1
            if restarts > MAX_RESTARTS:
                raise RuntimeError("kill/restore loop did not converge")
            continue
        journal.close()
        return engine, rid0, restarts, inj.total_fires


def recovery_sweep(tmpdir) -> int:
    failures = 0
    total_kills = 0
    for paged in (True, False):
        layout = "paged" if paged else "dense"
        vnow = [0.0]
        ref_core = _recovery_engine(vnow, None, paged).core
        ref = _submit_workload(ref_core)
        _drain(ref_core, vnow)
        assert all(r.finish_reason in CLEAN_REASONS for r in ref), (
            "kill-free reference must finish every request normally"
        )
        for seed in RECOVERY_SEEDS:
            path = os.path.join(tmpdir, f"journal_{layout}_s{seed}.jsonl")
            try:
                engine, rid0, restarts, kills = kill_run(seed, path, paged)
            except Exception:
                traceback.print_exc()
                print(f"FAIL {layout} seed={seed}: kill/restore crashed")
                failures += 1
                continue
            total_kills += kills
            toks, fins = _journal_streams(path)
            lost = [i for i in range(len(ref))
                    if len(fins.get(rid0 + i, [])) == 0]
            dup = [i for i in range(len(ref))
                   if len(fins.get(rid0 + i, [])) > 1]
            mismatched = [
                i for i, rr in enumerate(ref)
                if fins.get(rid0 + i)
                and fins[rid0 + i][0]["rsn"] in CLEAN_REASONS
                and (fins[rid0 + i][0]["rsn"] != rr.finish_reason
                     or toks.get(rid0 + i, []) != rr.output_tokens)
            ]
            resid = check_attribution(engine)
            print(
                f"{layout} seed={seed}: kills={kills} restarts={restarts} "
                f"finished={len(ref) - len(lost)}/{len(ref)} "
                f"attribution_residual={resid:.2e}"
            )
            if lost:
                print(f"FAIL {layout} seed={seed}: requests {lost} have no "
                      f"durable finish record (lost)")
                failures += 1
            if dup:
                print(f"FAIL {layout} seed={seed}: requests {dup} finished "
                      f"more than once (duplicated)")
                failures += 1
            if mismatched:
                print(f"FAIL {layout} seed={seed}: requests {mismatched} "
                      f"finished normally but diverged from the "
                      f"uninterrupted reference")
                failures += 1
            if resid > ATTRIBUTION_TOL:
                print(f"FAIL {layout} seed={seed}: SLO attribution residual "
                      f"{resid} > {ATTRIBUTION_TOL}")
                failures += 1
    if total_kills == 0:
        print("FAIL recovery: no process/kill ever fired — the sweep "
              "exercised nothing")
        failures += 1
    return failures


def main(argv=None) -> int:
    import argparse
    import tempfile

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--only", choices=("serve", "resume", "recovery"), default=None,
        help="run a single sweep (default: all three)",
    )
    args = ap.parse_args(argv)
    failures = 0
    if args.only in (None, "serve"):
        print(f"serving chaos sweep: seeds {SERVE_SEEDS}, "
              f"{len(SERVE_SPECS)} fault points armed")
        failures += serve_sweep()
    if args.only in (None, "resume"):
        print(f"early-resume sweep: seeds {RESUME_SEEDS}")
        failures += resume_sweep()
    if args.only in (None, "recovery"):
        print(f"recovery sweep: seeds {RECOVERY_SEEDS}, process/kill armed, "
              f"paged + dense")
        with tempfile.TemporaryDirectory() as tmpdir:
            failures += recovery_sweep(tmpdir)
    if failures:
        print(f"FAIL: {failures} chaos check(s) failed")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
