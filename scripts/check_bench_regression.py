"""CI gate: paged decode throughput must stay within 10% of dense, and
preemption must protect online p95 under mixed load.

Reads the ``paged:*_tokens_per_s(k=8)`` rows ``benchmarks/engine_micro.py``
just wrote to BENCH_engine.json (same process conditions, measured
back-to-back) and fails the job on a >10% decode-throughput regression of
the paged KV path vs the dense layout at equal batch.  Also checks the
``core:online_p95_ms(mixed_load)`` pair (virtual-clock, deterministic):
online p95 with preemption enabled must be <= online p95 without it.

    python scripts/check_bench_regression.py [BENCH_engine.json]
"""
from __future__ import annotations

import json
import sys

THRESHOLD = 0.90  # paged must reach >= 90% of dense tokens/s


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_engine.json"
    with open(path) as f:
        rows = json.load(f)["rows"]
    vals = {case: value for _, case, _, _, value in rows}
    dense = vals.get("paged:dense_tokens_per_s(k=8)")
    paged = vals.get("paged:paged_tokens_per_s(k=8)")
    ratio = vals.get("paged:throughput_ratio_vs_dense")
    if not dense or not paged or not ratio:
        print(f"check_bench_regression: paged/dense rows missing from {path}")
        return 1
    print(
        f"paged {paged:.1f} tok/s vs dense {dense:.1f} tok/s "
        f"(median paired ratio {ratio:.3f}, floor {THRESHOLD})"
    )
    if ratio < THRESHOLD:
        print("FAIL: paged decode regressed >10% vs dense at equal batch")
        return 1
    by_policy = {(case, policy): value for _, case, policy, _, value in rows}
    pre = by_policy.get(("core:online_p95_ms(mixed_load)", "preempt"))
    nopre = by_policy.get(("core:online_p95_ms(mixed_load)", "no_preempt"))
    if pre is None or nopre is None:
        print(f"check_bench_regression: core preemption rows missing from {path}")
        return 1
    print(f"online p95 mixed load: preempt {pre:.2f} ms vs "
          f"no-preempt {nopre:.2f} ms")
    if pre > nopre:
        print("FAIL: preemption made online p95 WORSE under mixed load")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
