"""CI gate: paged decode throughput must stay within 10% of dense,
preemption must protect online p95 under mixed load, and chunked prefill
must honor the unified step's token budget.

Reads the ``paged:*_tokens_per_s(k=8)`` rows ``benchmarks/engine_micro.py``
just wrote to BENCH_engine.json (same process conditions, measured
back-to-back) and fails the job on a >10% decode-throughput regression of
the paged KV path vs the dense layout at equal batch.  Also checks the
``core:online_p95_ms(mixed_load)`` pair (virtual-clock, deterministic):
online p95 with preemption enabled must be <= online p95 without it.

Chunked-prefill gates (DESIGN.md §7; all read deterministic virtual-clock
rows, so they are exact, not noise-tolerant):

* no chunked step's mixed batch (prefill chunk tokens + generated tokens)
  exceeds the granted token budget — the step-time-ceiling guarantee that
  makes SpecInF bubble grants honest;
* the monolithic comparison row DOES exceed it (the overrun being fixed —
  if it stops overrunning, the benchmark workload has gone stale);
* chunked online TTFT p95 under mixed load <= monolithic's;
* unified chunked prefill compiles a small constant number of prefill
  programs (one fixed-width program per model).

Resilience gates (DESIGN.md §9; deterministic virtual-clock rows): the
overload ladder must not worsen served-online p95 under a 10x burst and
must actually shed, and a revocable grant must yield within one
sub-dispatch of the revocation signal while the monolithic comparison
row still overruns (workload-staleness guard).

    python scripts/check_bench_regression.py [BENCH_engine.json]
"""
from __future__ import annotations

import json
import sys

THRESHOLD = 0.90  # paged must reach >= 90% of dense tokens/s
MAX_CHUNKED_PREFILL_PROGRAMS = 2  # target (+ draft when spec is paired)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_engine.json"
    with open(path) as f:
        rows = json.load(f)["rows"]
    vals = {case: value for _, case, _, _, value in rows}
    dense = vals.get("paged:dense_tokens_per_s(k=8)")
    paged = vals.get("paged:paged_tokens_per_s(k=8)")
    ratio = vals.get("paged:throughput_ratio_vs_dense")
    if not dense or not paged or not ratio:
        print(f"check_bench_regression: paged/dense rows missing from {path}")
        return 1
    print(
        f"paged {paged:.1f} tok/s vs dense {dense:.1f} tok/s "
        f"(median paired ratio {ratio:.3f}, floor {THRESHOLD})"
    )
    if ratio < THRESHOLD:
        print("FAIL: paged decode regressed >10% vs dense at equal batch")
        return 1
    by_policy = {(case, policy): value for _, case, policy, _, value in rows}
    pre = by_policy.get(("core:online_p95_ms(mixed_load)", "preempt"))
    nopre = by_policy.get(("core:online_p95_ms(mixed_load)", "no_preempt"))
    if pre is None or nopre is None:
        print(f"check_bench_regression: core preemption rows missing from {path}")
        return 1
    print(f"online p95 mixed load: preempt {pre:.2f} ms vs "
          f"no-preempt {nopre:.2f} ms")
    if pre > nopre:
        print("FAIL: preemption made online p95 WORSE under mixed load")
        return 1

    # --- chunked-prefill unified-step gates (deterministic rows) -------
    budget = vals.get("chunked:granted_token_budget(mixed_load)")
    c_max = by_policy.get(("chunked:max_step_tokens(mixed_load)", "chunked"))
    m_max = by_policy.get(
        ("chunked:max_step_tokens(mixed_load)", "monolithic")
    )
    c_ttft = by_policy.get(
        ("chunked:online_ttft_p95_ms(mixed_load)", "chunked")
    )
    m_ttft = by_policy.get(
        ("chunked:online_ttft_p95_ms(mixed_load)", "monolithic")
    )
    programs = by_policy.get(("prefill:chunked_compiled_programs", "chunked"))
    if None in (budget, c_max, m_max, c_ttft, m_ttft, programs):
        print(f"check_bench_regression: chunked-prefill rows missing from "
              f"{path}")
        return 1
    print(f"step token ceiling: chunked {c_max} vs monolithic {m_max} "
          f"(grant {budget}); TTFT p95 chunked {c_ttft:.2f} ms vs "
          f"monolithic {m_ttft:.2f} ms; {programs} chunked prefill programs")
    if c_max > budget:
        print("FAIL: a chunked step's mixed batch exceeded its granted "
              "token budget")
        return 1
    if m_max <= budget:
        print("FAIL: the monolithic row no longer overruns the grant — the "
              "mixed-load workload has gone stale")
        return 1
    if c_ttft > m_ttft:
        print("FAIL: chunked prefill made online TTFT p95 WORSE under "
              "mixed load")
        return 1
    if programs > MAX_CHUNKED_PREFILL_PROGRAMS:
        print(f"FAIL: chunked prefill compiled {programs} programs "
              f"(> {MAX_CHUNKED_PREFILL_PROGRAMS}) — the one-program "
              "contract regressed")
        return 1

    # --- observability gates (DESIGN.md §8; deterministic rows) --------
    # bench_observability runs the same collocated workload with tracing
    # on vs off on the virtual clock.  Tracing must not perturb the
    # schedule, so the deterministic rows must match exactly (trivially
    # inside the <=5% step-time budget), and the trace's SLO attribution
    # must telescope to the measured end-to-end latencies.
    t_vt = by_policy.get(("obs:virtual_time_s(collocated)", "traced"))
    u_vt = by_policy.get(("obs:virtual_time_s(collocated)", "untraced"))
    t_served = by_policy.get(("obs:online_served(collocated)", "traced"))
    u_served = by_policy.get(("obs:online_served(collocated)", "untraced"))
    t_ttft = by_policy.get(("obs:online_ttft_p95_ms(collocated)", "traced"))
    u_ttft = by_policy.get(
        ("obs:online_ttft_p95_ms(collocated)", "untraced")
    )
    resid = by_policy.get(("obs:attribution_max_residual_s", "traced"))
    dropped = by_policy.get(("obs:trace_dropped", "traced"))
    if None in (t_vt, u_vt, t_served, u_served, t_ttft, u_ttft, resid,
                dropped):
        print(f"check_bench_regression: observability rows missing from "
              f"{path}")
        return 1
    print(f"tracing: virtual time traced {t_vt}s vs untraced {u_vt}s; "
          f"served {t_served}/{u_served}; ttft p95 {t_ttft}/{u_ttft} ms; "
          f"attribution residual {resid}s; {dropped} dropped events")
    if t_served < 1:
        print("FAIL: the collocated observability workload served no "
              "online requests")
        return 1
    if not t_vt <= u_vt * 1.05:
        print("FAIL: tracing cost >5% extra virtual-clock step time")
        return 1
    if t_served != u_served or t_ttft != u_ttft:
        print("FAIL: tracing perturbed the deterministic schedule "
              "(served/TTFT rows differ between traced and untraced)")
        return 1
    if resid > 1e-6:
        print("FAIL: SLO attribution segments do not sum to end-to-end "
              "latency")
        return 1
    if dropped != 0:
        print("FAIL: the tracer dropped events at bench scale")
        return 1

    # --- resilience gates (DESIGN.md §9; deterministic rows) -----------
    # bench_degradation runs the same bursty workload with and without the
    # overload ladder; bench_revocation raises the revocation signal
    # mid-quantum against a revocable vs a monolithic grant.  All rows are
    # virtual-clock deterministic, so the comparisons are exact.
    l_p95 = by_policy.get(("resil:online_p95_ms(burst)", "ladder"))
    n_p95 = by_policy.get(("resil:online_p95_ms(burst)", "no_ladder"))
    shed = by_policy.get(("resil:shed_fraction(burst)", "ladder"))
    r_over = by_policy.get(("resil:revocation_overrun_ms", "revocable"))
    m_over = by_policy.get(("resil:revocation_overrun_ms", "monolithic"))
    bound = by_policy.get(("resil:revocation_overrun_bound_ms", "revocable"))
    if None in (l_p95, n_p95, shed, r_over, m_over, bound):
        print(f"check_bench_regression: resilience rows missing from {path}")
        return 1
    print(f"burst online p95: ladder {l_p95:.2f} ms vs no-ladder "
          f"{n_p95:.2f} ms (shed fraction {shed}); revocation overrun "
          f"{r_over} ms (bound {bound} ms) vs monolithic {m_over} ms")
    if l_p95 > n_p95:
        print("FAIL: the overload ladder made served-online p95 WORSE "
              "under the burst")
        return 1
    if shed <= 0:
        print("FAIL: the ladder never shed under a 10x burst — the "
              "escalation path is dead")
        return 1
    if r_over > bound:
        print("FAIL: a revocable grant overran the documented one-"
              "sub-dispatch yield bound")
        return 1
    if m_over <= bound:
        print("FAIL: the monolithic row no longer overruns the bound — "
              "the revocation workload has gone stale")
        return 1
    base_vt = by_policy.get(
        ("resil:train_virtual_time_s(collocated)", "no_serving_baseline")
    )
    ff_vt = by_policy.get(
        ("resil:train_virtual_time_s(collocated)", "fault_free")
    )
    er_vt = by_policy.get(
        ("resil:train_virtual_time_s(collocated)", "early_resume")
    )
    resumes = by_policy.get(("resil:early_resumes(collocated)",
                             "early_resume"))
    if None in (base_vt, ff_vt, er_vt, resumes):
        print(f"check_bench_regression: early-resume rows missing from "
              f"{path}")
        return 1
    print(f"training virtual time: no-serving baseline {base_vt}s, "
          f"collocated fault-free {ff_vt}s, under {resumes} early "
          f"resume(s) {er_vt}s")
    if not (er_vt <= base_vt and ff_vt <= base_vt):
        print("FAIL: training step time under revocation exceeded the "
              "no-serving baseline — serving overran into training")
        return 1
    if resumes < 1:
        print("FAIL: the early-resume workload injected no resumes — "
              "the revocation-throughput gate has gone stale")
        return 1

    # --- journal + recovery gates (DESIGN.md §11) ----------------------
    # bench_journal runs the same mixed-load workload journaled vs
    # unjournaled on the virtual clock (journal I/O is host-side, so the
    # deterministic rows must match exactly — trivially inside the <=5%
    # step-time budget), then crashes a journaled run and replays the
    # surviving log into a fresh engine.
    j_vt = by_policy.get(("journal:virtual_time_s(mixed_load)", "journaled"))
    u_jvt = by_policy.get(
        ("journal:virtual_time_s(mixed_load)", "unjournaled")
    )
    j_tok = by_policy.get(("journal:tokens(mixed_load)", "journaled"))
    u_jtok = by_policy.get(("journal:tokens(mixed_load)", "unjournaled"))
    j_fin = by_policy.get(("journal:finished(mixed_load)", "journaled"))
    u_jfin = by_policy.get(("journal:finished(mixed_load)", "unjournaled"))
    appends = by_policy.get(("journal:appends", "journaled"))
    rec_req = by_policy.get(("journal:recovered_requests", "recovered"))
    rec_wall = by_policy.get(("journal:recovery_wall_ms", "recovered"))
    if None in (j_vt, u_jvt, j_tok, u_jtok, j_fin, u_jfin, appends,
                rec_req, rec_wall):
        print(f"check_bench_regression: journal/recovery rows missing "
              f"from {path}")
        return 1
    print(f"journal: virtual time journaled {j_vt}s vs unjournaled "
          f"{u_jvt}s; tokens {j_tok}/{u_jtok}; finished {j_fin}/{u_jfin}; "
          f"{appends} appends; recovery replayed {rec_req} requests in "
          f"{rec_wall} ms")
    if not j_vt <= u_jvt * 1.05:
        print("FAIL: journaling cost >5% extra virtual-clock step time")
        return 1
    if j_tok != u_jtok or j_fin != u_jfin:
        print("FAIL: journaling perturbed the deterministic schedule "
              "(token/finished rows differ between journaled and "
              "unjournaled)")
        return 1
    if appends < 1:
        print("FAIL: the journaled run appended no records — the journal "
              "wiring is dead")
        return 1
    if rec_req < 1:
        print("FAIL: replay recovery restored no requests — the crash "
              "workload has gone stale")
        return 1

    # --- proposer + tree-verify gates (DESIGN.md §10) ------------------
    # bench_proposers measures the host n-gram proposer on prefix-heavy
    # offline traffic (simulated acceptance, same rationale as the spec
    # rows); bench_tree_verify compares one ancestor-mask tree pass against
    # sequential linear verification at equal candidate coverage.  The
    # spec:speedup_vs_plain row doubles as a staleness canary: if the
    # draft/verify machinery the proposers build on stops beating plain
    # decode, the proposer rows above it are measuring a dead subsystem.
    ngram_speedup = vals.get("proposer:ngram_speedup_vs_plain")
    coverage = vals.get("proposer:ngram_match_coverage(greedy)")
    tree_equal = vals.get("tree:accepted_equals_linear(width=2)")
    tree_speedup = vals.get("tree:speedup_at_equal_candidates")
    spec_speedup = vals.get("spec:speedup_vs_plain")
    if None in (ngram_speedup, coverage, tree_equal, tree_speedup,
                spec_speedup):
        print(f"check_bench_regression: proposer/tree rows missing from "
              f"{path}")
        return 1
    print(f"proposers: ngram {ngram_speedup}x vs plain (match coverage "
          f"{coverage}); tree verify {tree_speedup}x vs linear at equal "
          f"candidates (accepted-equal={tree_equal}); spec canary "
          f"{spec_speedup}x")
    if ngram_speedup < 1.3:
        print("FAIL: the n-gram proposer fell below 1.3x plain decode on "
              "prefix-heavy offline traffic")
        return 1
    if coverage <= 0:
        print("FAIL: prompt-lookup never matched on prefix-heavy traffic — "
              "the proposer workload has gone stale")
        return 1
    if tree_equal != 1:
        print("FAIL: the tree-verify round diverged from linear "
              "verification on the fully-accepted candidate")
        return 1
    if tree_speedup <= 1.0:
        print("FAIL: one tree pass is no cheaper than sequential linear "
              "passes at equal candidate coverage")
        return 1
    if spec_speedup <= 1.0:
        print("FAIL: the spec loop no longer beats plain decode — the "
              "proposer comparisons above are against a dead baseline "
              "(staleness canary)")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
