"""Dev driver: exercise every smoke arch fwd/loss/prefill/decode on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T

archs = sys.argv[1:] or list(configs.ARCH_IDS)
key = jax.random.PRNGKey(0)
for arch in archs:
    cfg = configs.smoke_config(arch)
    p = T.init_params(cfg, key)
    n_analytic = cfg.param_count()
    n_real = sum(x.size for x in jax.tree.leaves(p))
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    if cfg.embed_inputs:
        inputs = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    else:
        inputs = tokens
    loss, metrics = jax.jit(
        lambda p, i, t: T.lm_loss(cfg, p, i, t, remat_policy="dots")
    )(p, inputs, tokens)
    logits, cache = jax.jit(lambda p, i: T.prefill(cfg, p, i, 64))(p, inputs)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = jax.jit(lambda p, t, c: T.decode_step(cfg, p, t, c))(p, nxt, cache)
    ok_nan = bool(jnp.isfinite(loss)) and bool(jnp.all(jnp.isfinite(logits2)))
    print(
        f"{arch:24s} loss={float(loss):8.4f} params real={n_real} analytic={n_analytic} "
        f"diff={abs(n_real-n_analytic)} decode_ok={ok_nan} logits={logits2.shape}"
    )
