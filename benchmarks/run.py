"""Benchmark orchestrator — one section per paper table/figure plus the
roofline table.  Prints ``figure,case,policy,metric,value`` CSV.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run --only fig4a roofline
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", nargs="*", default=None,
                    help="figure prefixes to run (fig4a ... fig8, headline, "
                         "roofline, micro)")
    ap.add_argument("--results-dir", default="results/dryrun")
    ap.add_argument("--bench-json", default="BENCH_engine.json",
                    help="where to write the engine microstep rows as JSON "
                         "(perf trajectory for future PRs); '' disables")
    args = ap.parse_args()

    want = lambda name: args.only is None or any(
        name.startswith(o) for o in args.only
    )

    t0 = time.time()
    rows = []

    from benchmarks import paper_fidelity as PF

    for mode, fa, fb in (("dp", "fig4a", "fig4b"),
                         ("mp", "fig5a", "fig5b"),
                         ("pp", "fig6a", "fig6b")):
        if want(fa):
            rows += PF.bench_offline(mode)
        if want(fb):
            rows += PF.bench_online(mode)
    if want("fig7"):
        rows += PF.bench_multi_instance()
    if want("fig8"):
        rows += PF.bench_overhead()
    if want("headline"):
        rows += PF.bench_headline()
    if want("micro"):
        from benchmarks import engine_micro

        t_micro = time.time()
        micro_rows = engine_micro.all_rows()
        rows += micro_rows
        if args.bench_json:
            import json

            with open(args.bench_json, "w") as f:
                json.dump(
                    {
                        "schema": ["figure", "case", "policy", "metric", "value"],
                        "rows": [list(r) for r in micro_rows],
                        "elapsed_s": round(time.time() - t_micro, 2),
                    },
                    f,
                    indent=2,
                )
            print(f"# wrote {args.bench_json} ({len(micro_rows)} rows)",
                  file=sys.stderr)
    if want("roofline"):
        from benchmarks import roofline

        for mesh in ("single", "multi"):
            try:
                rows += roofline.table_rows(args.results_dir, mesh)
            except FileNotFoundError:
                print(f"# roofline/{mesh}: no dry-run artifacts, skipping",
                      file=sys.stderr)

    print("figure,case,policy,metric,value")
    for r in rows:
        print(",".join(str(x) for x in r))
    print(f"# {len(rows)} rows in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
