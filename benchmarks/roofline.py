"""Roofline table + perf-iteration helpers over the dry-run artifacts.

Reads ``results/dryrun/<mesh>/<arch>__<shape>.json`` (written by
``repro.launch.dryrun``) and emits the §Roofline table: three terms,
dominant bottleneck, 6ND/HLO useful-FLOPs ratio, and the HBM fit.
"""
from __future__ import annotations

import glob
import json
import os

MESH_DIRS = {"single": "pod_16x16", "multi": "multipod_2x16x16"}


def load_cells(results_dir: str = "results/dryrun", mesh: str = "single"):
    cells = {}
    pattern = os.path.join(results_dir, MESH_DIRS[mesh], "*.json")
    for path in sorted(glob.glob(pattern)):
        r = json.load(open(path))
        cells[(r["arch"], r["shape"])] = r
    return cells


def table_rows(results_dir: str = "results/dryrun", mesh: str = "single"):
    rows = []
    for (arch, shape), r in load_cells(results_dir, mesh).items():
        if r.get("skipped"):
            rows.append((f"roofline/{mesh}", f"{arch}:{shape}", "-", "skipped", 1))
            continue
        ro = r["roofline"]
        case = f"{arch}:{shape}"
        fig = f"roofline/{mesh}"
        rows.append((fig, case, ro["dominant"], "compute_ms",
                     round(ro["compute_s"] * 1e3, 2)))
        rows.append((fig, case, ro["dominant"], "memory_ms",
                     round(ro["memory_s"] * 1e3, 2)))
        rows.append((fig, case, ro["dominant"], "collective_ms",
                     round(ro["collective_s"] * 1e3, 2)))
        rows.append((fig, case, ro["dominant"], "useful_flops_ratio",
                     round(ro["useful_flops_ratio"], 4)))
        rows.append((fig, case, ro["dominant"], "hbm_gib",
                     round(r["memory"]["peak_bytes_per_device"] / 2**30, 2)))
    return rows


def roofline_fraction(r: dict) -> float:
    """Useful-work fraction of the roofline bound: what share of the
    bound-step time is irreducible model compute at peak.

      fraction = (model_flops / (chips * peak)) / max(compute, memory, coll)
    """
    ro = r["roofline"]
    ideal_s = ro["model_flops"] / (r["n_devices"] * 197e12)
    return ideal_s / max(ro["bound_s"], 1e-30)


def summarize(results_dir: str = "results/dryrun") -> str:
    lines = []
    for mesh in ("single", "multi"):
        cells = load_cells(results_dir, mesh)
        ok = [r for r in cells.values() if not r.get("skipped")]
        if not ok:
            continue
        fits = sum(1 for r in ok if r.get("hbm_ok"))
        lines.append(
            f"{mesh}: {len(ok)} compiled cells, {fits}/{len(ok)} fit 16GiB HBM"
        )
        worst = sorted(ok, key=roofline_fraction)[:3]
        for r in worst:
            lines.append(
                f"  worst roofline fraction: {r['arch']}:{r['shape']}"
                f" = {roofline_fraction(r):.4f} (dominant "
                f"{r['roofline']['dominant']})"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    print(summarize())
