"""Paper-fidelity benchmarks: one per SpecInF figure (Fig. 4-8).

Workloads mirror §5.1: DP trains BERT-base / RoBERTa-large, MP/PP fine-tune
LLaMA2-7B / ChatGLM-6B; collocated inference uses ResNet152 / VGG19 /
BERT-base / RoBERTa-large / GPT2-large.  All five policies run on the same
calibrated timeline (A100-40G constants, bubble fractions from Fig. 1);
SpecInF runs the REAL BubbleMonitor + Algorithm-1 scheduler.

Each function returns CSV-ish rows: (figure, case, policy, metric, value).
"""
from __future__ import annotations

from repro import configs
from repro.configs.base import SpecInFConfig
from repro.core.hardware import A100_40G
from repro.core.profiles import (
    analytic_inference_profile,
    analytic_iteration,
    cv_profile,
)
from repro.core.queues import RequestQueue, poisson_arrivals
from repro.core.simulator import Calibration, make_policy, simulate

CAL = Calibration()
# A100-40GB testbed; busy_hold_ms=0 -> hold for the profiled max bubble
# (the paper's CKS "preemptively sets the status to busy, according to
# profiling information on training iteration time", §3.3)
SPEC = SpecInFConfig(hbm_limit_bytes=40 * 1024**3, busy_hold_ms=0.0)
POLICIES = ("specinf", "mps", "tgs", "co-exec", "exclusive")
DURATION = 40.0

# Fig. 1 measured bubble fractions per mode
BUBBLE_FRACTION = {"dp": 0.30, "mp": 0.35, "pp": 0.15}

# training workloads per parallel mode (paper §5.1)
TRAIN_CASES = {
    "dp": ["bert-base", "roberta-large"],
    "mp": ["llama2-7b", "chatglm-6b"],
    "pp": ["llama2-7b", "chatglm-6b"],
}
# collocated inference workloads: (name, microstep seconds source)
INFER_CASES = ["resnet152", "bert-base", "gpt2-large"]


def _profile(mode: str, train_name: str, target_compute_s: float = 0.0):
    """Iteration profile sized to the paper's testbed: Fig. 1a shows ~1-1.5s
    DP iterations, Fig. 1b ~3s LLaMA2 MP iterations (§3.3 cites 1.5s);
    per-device batch solved from the model size."""
    if not target_compute_s:
        target_compute_s = 1.0 if mode == "dp" else 3.0
    cfg = configs.PAPER_MODELS[train_name]
    n = cfg.param_count()
    tokens = target_compute_s * A100_40G.peak_flops * A100_40G.mfu_assumption / (6 * n)
    pdb = max(4, int(tokens / 512))
    return analytic_iteration(
        cfg, seq_len=512, per_device_batch=pdb, num_devices=4, mode=mode,
        hw=A100_40G, target_bubble_fraction=BUBBLE_FRACTION[mode],
    )


# Measured-magnitude A100 microstep latencies (batch-8 for CV, batch-8/128
# tokens for NLP).  The paper reports its collocated inferences at "the 50ms
# level" (§2.2); the pure-FLOPs estimate is 10-30x optimistic for small-batch
# inference (launch overheads, low MFU), so the simulator uses these
# calibrated values and keeps the analytic model as a lower-bound fallback.
MICROSTEP_S = {
    "resnet152": 0.025,
    "vgg19": 0.035,
    "bert-base": 0.015,
    "roberta-large": 0.040,
    "gpt2-large": 0.050,
}


def _microstep_s(infer_name: str) -> float:
    if infer_name in MICROSTEP_S:
        return MICROSTEP_S[infer_name]
    if infer_name in ("resnet152", "vgg19"):
        return cv_profile(infer_name, A100_40G).min_exec_time_s
    cfg = configs.PAPER_MODELS[infer_name]
    return analytic_inference_profile(
        cfg, batch=8, seq_or_context=128, hw=A100_40G, kind="batch_infer"
    ).min_exec_time_s


def _sim(policy, profile, **kw):
    return simulate(
        profile, make_policy(policy, SPEC), duration_s=DURATION,
        cal=CAL, specinf_cfg=SPEC, **kw,
    )


# ---------------------------------------------------------------------------
# Fig. 4(a)/5(a)/6(a): offline inference filling per parallel mode
# ---------------------------------------------------------------------------


def bench_offline(mode: str):
    rows = []
    fig = {"dp": "fig4a", "mp": "fig5a", "pp": "fig6a"}[mode]
    for train_name in TRAIN_CASES[mode]:
        profile = _profile(mode, train_name)
        for infer_name in INFER_CASES:
            ms = _microstep_s(infer_name)
            case = f"{mode}:{train_name}+{infer_name}"
            for pol in POLICIES:
                r = _sim(pol, profile, offline_instances=1,
                         offline_microstep_s=ms)
                rows.append((fig, case, pol, "train_norm",
                             round(r.train_throughput_norm, 4)))
                rows.append((fig, case, pol, "offline_norm",
                             round(r.offline_norm, 4)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 4(b)/5(b)/6(b): online inference p95 per parallel mode
# ---------------------------------------------------------------------------


def bench_online(mode: str):
    """Two load points per case: ``light`` (5 rps — queueing-free, measures
    scheduling latency) and ``paper`` (33 rps — the paper's RoBERTa-CV
    'mean value ... set to 30' saturating regime, measures effective
    bubble-service capacity).  3 collocated online instances per §3.3:
    after a pull flips one instance busy, 'requests are handled by other
    inference instances'."""
    rows = []
    fig = {"dp": "fig4b", "mp": "fig5b", "pp": "fig6b"}[mode]
    for train_name in TRAIN_CASES[mode]:
        profile = _profile(mode, train_name)
        for infer_name in INFER_CASES[:2]:
            service = _microstep_s(infer_name)
            for load, interval, n_req in (
                ("light", 0.200, 200), ("paper", 0.030, 1000),
            ):
                case = f"{mode}:{train_name}+{infer_name}:{load}"
                for pol in POLICIES:
                    q = RequestQueue(poisson_arrivals(
                        mean_interval_s=interval, num_requests=n_req,
                        service_s=service, seed=7,
                    ))
                    r = _sim(pol, profile, online_queue=q, online_instances=3)
                    rows.append((fig, case, pol, "train_norm",
                                 round(r.train_throughput_norm, 4)))
                    rows.append((fig, case, pol, "online_p95_ms",
                                 round(r.online_p95_s * 1e3, 2)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 7: multi-instance scaling (RoBERTa-ResNet DP, ChatGLM-BERT MP)
# ---------------------------------------------------------------------------


def bench_multi_instance():
    rows = []
    cases = [
        ("fig7a", "dp", "roberta-large", "resnet152", 30),
        ("fig7b", "mp", "chatglm-6b", "bert-base", 30),
    ]
    for fig, mode, train_name, infer_name, _mean in cases:
        profile = _profile(mode, train_name)
        ms = _microstep_s(infer_name)
        for m in (1, 2, 3, 4):
            for pol in ("specinf", "co-exec", "exclusive"):
                r = _sim(pol, profile, offline_instances=m,
                         offline_microstep_s=ms)
                case = f"{mode}:{train_name}+{infer_name}x{m}"
                rows.append((fig, case, pol, "train_norm",
                             round(r.train_throughput_norm, 4)))
                rows.append((fig, case, pol, "offline_agg_norm",
                             round(r.offline_norm, 4)))
    return rows


# ---------------------------------------------------------------------------
# Fig. 8: system overhead (collocated but idle inference)
# ---------------------------------------------------------------------------


def bench_overhead():
    rows = []
    for mode, train_name in (("dp", "bert-base"), ("mp", "chatglm-6b")):
        profile = _profile(mode, train_name)
        base = _sim("exclusive", profile)
        idle = _sim("specinf", profile)  # monitor active, no inference work
        overhead = 1.0 - idle.train_iterations / base.train_iterations
        rows.append(("fig8", f"{mode}:{train_name}", "specinf",
                     "overhead_frac", round(overhead, 4)))
    return rows


# ---------------------------------------------------------------------------
# Headline derived claims (abstract): vs TGS / MPS
# ---------------------------------------------------------------------------


def bench_headline():
    rows = []
    # offline multiple vs TGS / MPS across DP cases
    best_tgs, best_mps = 0.0, 0.0
    for train_name in TRAIN_CASES["dp"]:
        profile = _profile("dp", train_name)
        for infer_name in INFER_CASES:
            ms = _microstep_s(infer_name)
            spec = _sim("specinf", profile, offline_instances=1,
                        offline_microstep_s=ms)
            tgs = _sim("tgs", profile, offline_instances=1,
                       offline_microstep_s=ms)
            mps = _sim("mps", profile, offline_instances=1,
                       offline_microstep_s=ms)
            if tgs.offline_throughput_per_s > 0:
                best_tgs = max(
                    best_tgs,
                    spec.offline_throughput_per_s / tgs.offline_throughput_per_s,
                )
            best_mps = max(
                best_mps,
                spec.offline_throughput_per_s
                / max(mps.offline_throughput_per_s, 1e-9),
            )
    rows.append(("headline", "dp", "specinf", "offline_vs_tgs_max_x",
                 round(best_tgs, 2)))
    rows.append(("headline", "dp", "specinf", "offline_vs_mps_max_x",
                 round(best_mps, 2)))
    # online p95 reduction vs MPS (best case)
    best_red = 0.0
    for train_name in TRAIN_CASES["dp"]:
        profile = _profile("dp", train_name)
        for infer_name in ("bert-base", "resnet152", "gpt2-large"):
            service = _microstep_s(infer_name)
            qs = {}
            for pol in ("specinf", "mps"):
                q = RequestQueue(poisson_arrivals(
                    mean_interval_s=0.030, num_requests=1000,
                    service_s=service, seed=11,
                ))
                qs[pol] = _sim(pol, profile, online_queue=q,
                               online_instances=3)
            red = 1.0 - qs["specinf"].online_p95_s / qs["mps"].online_p95_s
            best_red = max(best_red, red)
    rows.append(("headline", "dp", "specinf", "p95_reduction_vs_mps_max",
                 round(best_red, 3)))
    return rows


def all_rows():
    rows = []
    for mode in ("dp", "mp", "pp"):
        rows += bench_offline(mode)
        rows += bench_online(mode)
    rows += bench_multi_instance()
    rows += bench_overhead()
    rows += bench_headline()
    return rows
