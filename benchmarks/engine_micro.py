"""Microbenchmarks of the real schedulable units (engine microsteps) and the
control plane — backs the paper's '<1ms kernels / 2ms windows / ~1%
overhead' granularity claims with measured numbers on this host."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import SpecInFConfig
from repro.core import AdaptiveKernelScheduler, BubbleMonitor
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request


def _time_us(fn, n=50, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _fresh_engine(cfg, params, max_seq=256, **kw):
    # kv_page_size=0 pins the legacy dense layout so the historical
    # legacy/fused rows keep their meaning across PRs; the paged rows come
    # from bench_paged_kv's explicit side-by-side.
    kw.setdefault("kv_page_size", 0)
    engine = InferenceEngine(cfg, params, max_slots=4, max_seq=max_seq, **kw)
    for _ in range(4):
        engine.add_request(Request(prompt=np.arange(8), max_new_tokens=10**9))
    return engine


def bench_engine_microstep():
    """Old synced path vs the fused sync-free decode loop, plus the prefill
    compile-cache row — the before/after evidence for the flash-decode fast
    path (DESIGN.md §3).  Capacity (max_seq=256) comfortably exceeds the
    total microsteps timed, so no slot retires mid-measurement."""
    rows = []
    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def measure(label, policy, engine, call, steps_per_call):
        t0, s0 = engine.d2h_transfers, engine.steps_executed
        us = _time_us(call, n=25) / steps_per_call
        d2h = (engine.d2h_transfers - t0) / max(engine.steps_executed - s0, 1)
        assert engine.num_active == 4, "slots retired mid-benchmark"
        rows.append(("micro", f"engine:{label}", policy,
                     "us_per_microstep", round(us, 1)))
        rows.append(("micro", f"engine:tokens_per_s({label})", policy,
                     "tok_per_s", round(4 / (us * 1e-6), 1)))
        rows.append(("micro", f"engine:d2h_per_microstep({label})", policy,
                     "count", round(d2h, 3)))

    # legacy path: one decode step, host sync every microstep
    engine = _fresh_engine(cfg, params)
    measure("decode_microstep(4 slots)", "legacy", engine,
            lambda: engine.decode_microstep(), 1)
    # fused path: k microsteps on-device, one transfer per loop
    for k in (1, 8):
        eng = _fresh_engine(cfg, params)
        measure(f"decode_loop(k={k})", "fused", eng,
                lambda: eng.decode_loop(k), k)
    return rows


def bench_prefill_buckets():
    """Prefill compile-cache control: 20 distinct prompt lengths through the
    power-of-two buckets compile a handful of programs (the seed engine
    compiled one per distinct length); unified chunked prefill collapses
    them further to ONE fixed-width program regardless of the prompt-length
    distribution (``scripts/check_bench_regression.py`` gates it)."""
    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    # prefill_chunk=0 pins the historical bucketed rows' meaning
    engine = InferenceEngine(cfg, params, max_slots=4, max_seq=128,
                             prefill_chunk=0)
    chunked = InferenceEngine(cfg, params, max_slots=4, max_seq=128)
    lengths = list(range(3, 23))  # 20 distinct prompt lengths
    for n in lengths:
        # benchmark measures prefill compiles only; recycle the slots freely
        for eng in (engine, chunked):
            eng.slots = [None] * eng.max_slots
            eng._prefill_left = [None] * eng.max_slots
            eng._draft_prefill_left = [None] * eng.max_slots
            eng.add_request(Request(prompt=np.arange(n), max_new_tokens=1))
    return [
        ("micro", "prefill:compiled_programs_20_lengths", "bucketed",
         "count", engine.prefill_compile_count),
        ("micro", "prefill:compiled_programs_20_lengths", "seed_equiv",
         "count", len(set(lengths))),
        ("micro", "prefill:compiled_programs_20_lengths", "chunked",
         "count", chunked.prefill_compile_count),
    ]


def bench_spec_decode(accept_p=0.9, gamma=4):
    """Verified-token throughput of the fused speculative loop
    (``spec_decode_loop``) vs the plain fused ``decode_loop`` on the same
    target model — the before/after evidence for the draft/verify subsystem
    (DESIGN.md §4).

    Runs in simulated-acceptance mode: the draft steps, chunk-verify pass,
    rollback, and host accounting are all the real code paths; only the
    per-token accept/reject outcome is drawn from a Bernoulli(p) stream, so
    CPU CI can measure the loop's cost profile at a chosen acceptance rate
    without a genuinely-aligned draft model (random-init drafts accept ~0)."""
    from repro.configs.base import SpecDecodeConfig, draft_config

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    spec = SpecDecodeConfig(mode="simulated", sim_accept_p=accept_p)
    dcfg = draft_config(cfg, spec)
    dparams = T.init_params(dcfg, jax.random.PRNGKey(1))
    max_seq = 2048
    rows = []

    def throughput(engine, call, n=20, warmup=3):
        for _ in range(warmup):
            call()
        g0, d0 = engine.generated_tokens_total, engine.d2h_transfers
        t0 = time.perf_counter()
        for _ in range(n):
            call()
        dt = time.perf_counter() - t0
        assert engine.num_active == 4, "slots retired mid-benchmark"
        return (engine.generated_tokens_total - g0) / dt, (
            engine.d2h_transfers - d0
        ) / n

    plain = _fresh_engine(cfg, params, max_seq=max_seq)
    plain_tps, _ = throughput(plain, lambda: plain.decode_loop(8))
    # dense-pinned like _fresh_engine: the spec rows' trajectory predates
    # the paged pool (bench_paged_kv holds the paged-vs-dense comparison)
    eng = InferenceEngine(
        cfg, params, max_slots=4, max_seq=max_seq, kv_page_size=0,
        draft_cfg=dcfg, draft_params=dparams, spec=spec,
    )
    for _ in range(4):
        eng.add_request(Request(prompt=np.arange(8), max_new_tokens=10**9))
    spec_tps, spec_d2h = throughput(
        eng, lambda: eng.spec_decode_loop(4, gamma)
    )
    tokens_per_round = eng.generated_tokens_total / max(eng.spec_rounds, 1) / 4
    rows.append(("micro", "spec:verified_tokens_per_s(gamma=%d)" % gamma,
                 "spec", "tok_per_s", round(spec_tps, 1)))
    rows.append(("micro", "spec:plain_tokens_per_s(decode_loop k=8)",
                 "fused", "tok_per_s", round(plain_tps, 1)))
    rows.append(("micro", "spec:speedup_vs_plain", "spec", "ratio",
                 round(spec_tps / plain_tps, 3)))
    rows.append(("micro", "spec:acceptance_rate(simulated p=%g)" % accept_p,
                 "spec", "fraction", round(eng.spec_acceptance_rate, 3)))
    rows.append(("micro", "spec:verified_tokens_per_round_per_slot", "spec",
                 "count", round(tokens_per_round, 2)))
    rows.append(("micro", "spec:d2h_per_loop", "spec", "count",
                 round(spec_d2h, 3)))
    return rows


def bench_proposers(accept_p=0.9, gamma=4):
    """Model-free proposal on prefix-heavy offline traffic (DESIGN.md §10):
    prompt-lookup n-gram vs the draft-model path vs plain fused decode, on
    the same target model.

    Prefix-heavy prompts are the regime the host proposers exist for —
    trailing n-grams recur, so candidate continuations come from the slot's
    own history at ZERO model cost (no draft forwards at all); the target
    only pays the one tree-verify pass per round.  Acceptance outcomes are
    simulated (same rationale as ``bench_spec_decode``: the proposal
    machinery, tree-verify kernel, rollback, and host accounting are the
    real code paths; only the per-token accept decision is Bernoulli so a
    random-init smoke model doesn't decide the measurement), plus one
    real-greedy row reporting how often the n-gram table actually matches.
    ``scripts/check_bench_regression.py`` gates the n-gram speedup."""
    from repro.configs.base import SpecDecodeConfig, draft_config

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    max_seq = 2048
    prompt = np.tile([3, 5, 7, 9, 11], 8)  # prefix-heavy: period-5 tail
    rows = []

    def fresh(**kw):
        eng = InferenceEngine(cfg, params, max_slots=4, max_seq=max_seq,
                              kv_page_size=0, **kw)
        for _ in range(4):
            eng.add_request(Request(prompt=prompt, max_new_tokens=10**9))
        return eng

    def throughput(engine, call, n=20, warmup=3):
        for _ in range(warmup):
            call()
        g0 = engine.generated_tokens_total
        t0 = time.perf_counter()
        for _ in range(n):
            call()
        dt = time.perf_counter() - t0
        assert engine.num_active == 4, "slots retired mid-benchmark"
        return (engine.generated_tokens_total - g0) / dt

    plain = fresh()
    plain_tps = throughput(plain, lambda: plain.decode_loop(8))

    sim = SpecDecodeConfig(mode="simulated", sim_accept_p=accept_p,
                           proposer="ngram")
    ngram = fresh(spec=sim)
    ngram_tps = throughput(
        ngram, lambda: ngram._drive_proposed_loop(4, gamma, "ngram")
    )

    dspec = SpecDecodeConfig(mode="simulated", sim_accept_p=accept_p,
                             proposer="draft")
    dcfg = draft_config(cfg, dspec)
    draft = fresh(spec=dspec, draft_cfg=dcfg,
                  draft_params=T.init_params(dcfg, jax.random.PRNGKey(1)))
    draft_tps = throughput(
        draft, lambda: draft._drive_proposed_loop(4, gamma, "draft")
    )

    rows.append(("micro", "proposer:plain_tokens_per_s(decode_loop k=8)",
                 "fused", "tok_per_s", round(plain_tps, 1)))
    rows.append(("micro", "proposer:ngram_tokens_per_s(sim p=%g gamma=%d)"
                 % (accept_p, gamma), "ngram", "tok_per_s",
                 round(ngram_tps, 1)))
    rows.append(("micro", "proposer:draft_tokens_per_s(sim p=%g gamma=%d)"
                 % (accept_p, gamma), "draft", "tok_per_s",
                 round(draft_tps, 1)))
    rows.append(("micro", "proposer:ngram_speedup_vs_plain", "ngram",
                 "ratio", round(ngram_tps / plain_tps, 3)))
    rows.append(("micro", "proposer:draft_speedup_vs_plain", "draft",
                 "ratio", round(draft_tps / plain_tps, 3)))

    # real greedy acceptance (no simulation): how often does prompt-lookup
    # find a candidate at all on prefix-heavy traffic, and how much of what
    # it proposes does the target keep?
    real = fresh(spec=SpecDecodeConfig(proposer="ngram"))
    for _ in range(12):
        real._drive_proposed_loop(1, gamma, "ngram")
    m = real.obs.metrics
    matched = m.counter("spec/proposer/rounds/ngram").value
    fallbacks = m.counter("spec/proposer/no_match_fallbacks").value
    rows.append(("micro", "proposer:ngram_match_coverage(greedy)", "ngram",
                 "fraction",
                 round(matched / max(matched + fallbacks, 1), 3)))
    rows.append(("micro", "proposer:ngram_acceptance(greedy)", "ngram",
                 "fraction",
                 round(m.gauge("spec/proposer/acceptance/ngram").value, 3)))
    return rows


def bench_tree_verify(width=2, depth=4):
    """Tree verification vs sequential linear verification at equal
    candidate coverage (DESIGN.md §10): scoring ``width`` candidate chains
    of ``depth`` tokens takes ONE tree-verify pass (ancestor-mask kernel,
    width*depth+1 packed nodes) where chain verification needs ``width``
    sequential passes — and each pass is a device round-trip on the host
    proposal path.

    Also checks the equal-accepted-tokens invariant that makes the
    comparison meaningful: a tree whose branch 0 is the target's own greedy
    chain accepts exactly what the linear verify of that chain accepts
    (byte-identical output; ``tests/test_tree_verify.py`` proves the
    general property)."""
    import jax.numpy as jnp

    from repro.configs.base import SpecDecodeConfig
    from repro.spec.tree import branching_tree, linear_chain

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.tile([3, 5, 7, 9, 11], 4)
    spec = SpecDecodeConfig(proposer="ngram")
    rows = []

    def fresh():
        eng = InferenceEngine(cfg, params, max_slots=4, max_seq=2048,
                              kv_page_size=0, spec=spec)
        for _ in range(4):
            eng.add_request(Request(prompt=prompt, max_new_tokens=10**9))
        return eng

    # the target's own greedy continuation: the fully-accepted candidate.
    # generated[0] came from prefill (it is the fresh engines' CURRENT
    # token — tree node 0), so the proposals start at generated[1]
    ref = fresh()
    ref.decode_loop(depth + 1)
    chains = np.array(
        [r.generated[1:] for r in ref.slots], np.int32
    )

    lin_parents = linear_chain(depth)
    tree_parents = branching_tree(width, depth)

    def round_fn(eng, parents, tail):
        fn = eng._tree_round_fn(parents, "greedy")

        def call():
            out = fn(eng.params, eng.tokens, eng.cache, jnp.asarray(tail),
                     jnp.asarray(np.full(4, 1 << 20, np.int32)),
                     eng._spec_key)
            (eng.tokens, eng.cache, _rem, eng._spec_key) = out[:4]
            return jax.device_get(out[4:])

        return call

    # equal-accepted-tokens check: branch 0 = greedy chain -> the tree
    # round and the linear round absorb the SAME depth+1 tokens
    lin_tail = chains[:, :depth]
    tree_tail = np.concatenate(
        [lin_tail] + [np.full_like(lin_tail, 2)] * (width - 1), axis=1
    )
    e_lin, e_tree = fresh(), fresh()
    toks_l, n_l = round_fn(e_lin, lin_parents, lin_tail)()[:2]
    toks_t, n_t = round_fn(e_tree, tree_parents, tree_tail)()[:2]
    equal = bool(
        np.array_equal(n_l, n_t)
        and all(
            np.array_equal(toks_l[i, : n_l[i]], toks_t[i, : n_t[i]])
            for i in range(4)
        )
        and np.array_equal(toks_l[0, : n_l[0]], chains[0, : int(n_l[0])])
    )
    rows.append(("micro", "tree:accepted_equals_linear(width=%d)" % width,
                 "tree", "bool", int(equal)))

    # cost at equal candidate coverage: 1 tree pass vs width linear passes
    e_lin, e_tree = fresh(), fresh()
    lin_call = round_fn(e_lin, lin_parents, lin_tail)
    tree_call = round_fn(e_tree, tree_parents, tree_tail)
    lin_us = _time_us(lambda: [lin_call() for _ in range(width)], n=25)
    tree_us = _time_us(tree_call, n=25)
    rows.append(("micro", "tree:verify_passes_for_%d_chains" % width,
                 "tree", "count", 1))
    rows.append(("micro", "tree:verify_passes_for_%d_chains" % width,
                 "linear", "count", width))
    rows.append(("micro", "tree:us_per_round(%d chains depth=%d)"
                 % (width, depth), "tree", "us", round(tree_us, 1)))
    rows.append(("micro", "tree:us_per_round(%d chains depth=%d)"
                 % (width, depth), "linear", "us", round(lin_us, 1)))
    rows.append(("micro", "tree:speedup_at_equal_candidates", "tree",
                 "ratio", round(lin_us / tree_us, 3)))
    return rows


def bench_paged_kv():
    """Paged KV pool vs the dense per-slot layout (DESIGN.md §5): decode
    throughput at equal batch, HBM per slot, concurrent slots at equal cache
    HBM, and TTFT under prompt prefix sharing.

    The equal-batch rows are the CI regression gate's input
    (``scripts/check_bench_regression.py``): paged decode must stay within
    10% of dense.  The capacity and TTFT rows are the paging payoff — more
    slots per HBM byte and prefill skipped in proportion to the shared
    prefix."""
    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    max_seq, slots = 256, 4
    rows = []

    def timed_loop(engine):
        g0 = engine.generated_tokens_total
        t0 = time.perf_counter()
        engine.decode_loop(8)
        dt = time.perf_counter() - t0
        assert engine.num_active == slots, "slots retired mid-benchmark"
        return (engine.generated_tokens_total - g0) / dt

    # -- equal batch: same 4 slots, dense rows vs paged pool.  Fused loops
    # are timed in adjacent dense/paged PAIRS and the gate ratio is the
    # median of per-pair ratios: adjacent calls share the machine's load,
    # so CPU scheduling noise cancels out of the ratio even when absolute
    # throughput swings run to run.  The tok/s rows keep each side's best
    # loop for the cross-PR trajectory.  Capacity (max_seq=256) comfortably
    # exceeds the total microsteps timed.
    dense = _fresh_engine(cfg, params, max_seq=max_seq)
    paged = _fresh_engine(cfg, params, max_seq=max_seq, kv_page_size=None)
    for e in (dense, paged):
        e.decode_loop(8)  # warmup / compile
    dense_tps = paged_tps = 0.0
    ratios = []
    for _ in range(24):
        d_t, p_t = timed_loop(dense), timed_loop(paged)
        dense_tps = max(dense_tps, d_t)
        paged_tps = max(paged_tps, p_t)
        ratios.append(p_t / d_t)
    ratios.sort()
    rows.append(("micro", "paged:dense_tokens_per_s(k=8)", "dense",
                 "tok_per_s", round(dense_tps, 1)))
    rows.append(("micro", "paged:paged_tokens_per_s(k=8)", "paged",
                 "tok_per_s", round(paged_tps, 1)))
    rows.append(("micro", "paged:throughput_ratio_vs_dense", "paged",
                 "ratio", round(ratios[len(ratios) // 2], 3)))
    rows.append(("micro", "paged:hbm_bytes_per_slot", "dense", "bytes",
                 dense.kv_cache_bytes() // slots))
    rows.append(("micro", "paged:hbm_bytes_per_slot", "paged", "bytes",
                 paged.kv_cache_bytes() // slots))

    # -- equal cache HBM: how many short requests fit concurrently -----
    page = paged.kv_page_size
    cap = InferenceEngine(
        cfg, params, max_slots=64, max_seq=max_seq,
        kv_pool_pages=slots * (max_seq // page) + 1,  # == dense KV HBM
    )

    def fill(engine):
        n = 0
        while engine.add_request(
            Request(prompt=np.arange(8), max_new_tokens=24)
        ):
            n += 1
        return n

    # dense comparator: the same cache HBM buys exactly ``slots`` rows
    dense_cap = InferenceEngine(
        cfg, params, max_slots=slots, max_seq=max_seq, kv_page_size=0,
    )
    assert cap.kv_cache_bytes() <= dense_cap.kv_cache_bytes() * 1.1
    rows.append(("micro", "paged:max_slots_at_equal_hbm", "dense", "count",
                 fill(dense_cap)))
    rows.append(("micro", "paged:max_slots_at_equal_hbm", "paged", "count",
                 fill(cap)))

    # -- TTFT under prefix sharing -------------------------------------
    plen = 160  # 10 pages at the default page size of 16
    base = np.arange(1, plen + 1)
    for frac, shared_tokens in ((0.0, 0), (0.5, 80), (0.9, 144)):
        eng = InferenceEngine(cfg, params, max_slots=3, max_seq=max_seq)
        # warm the compile caches for the exact programs the measured
        # admission will run, so TTFT times compute, not XLA compilation
        if shared_tokens:
            eng.add_request(Request(
                prompt=base[: shared_tokens + 8], max_new_tokens=1
            ))  # seeds the radix tree with the shared prefix
            eng.add_request(Request(
                prompt=np.concatenate([
                    base[:shared_tokens],
                    np.arange(2000, 2000 + plen - shared_tokens),
                ]),
                max_new_tokens=1,
            ))  # compiles the suffix-prefill bucket
        else:
            eng.add_request(Request(
                prompt=np.arange(5000, 5000 + plen), max_new_tokens=1
            ))  # compiles the cold-prefill bucket
        eng.decode_loop(1)  # retire the warmups
        prompt = np.concatenate(
            [base[:shared_tokens], np.arange(1000, 1000 + plen - shared_tokens)]
        )
        skipped0 = eng.prefill_skipped_tokens
        t0 = time.perf_counter()
        eng.add_request(Request(prompt=prompt, max_new_tokens=1))
        ttft_ms = (time.perf_counter() - t0) * 1e3
        rows.append(("micro", f"paged:ttft_ms(prefix_share={frac:g})",
                     "paged", "ms", round(ttft_ms, 2)))
        rows.append(("micro", f"paged:prefill_skipped(prefix_share={frac:g})",
                     "paged", "tokens",
                     eng.prefill_skipped_tokens - skipped0))
    return rows


def bench_engine_core(num_online=10, offline_budget=48):
    """Online p95 under mixed online/offline load through the
    ``EngineCore.step()`` lifecycle (DESIGN.md §6), with preemption enabled
    vs disabled — the acceptance evidence that evicting a RUNNING offline
    slot protects online latency instead of queueing behind offline decode.

    Runs on a virtual clock (one microstep == 2 ms) so the comparison is
    deterministic: identical arrivals, prompts, and token budgets; the ONLY
    difference is whether the policy may preempt.  Offline work is
    re-admitted after eviction and always completes, so both runs serve
    the same total token volume."""
    from repro.serving.core import (
        EngineCore, Grant, Priority, PriorityPolicy, SamplingParams,
    )

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    step_s = 0.002
    rows = []

    def run(preemption):
        vnow = [0.0]
        engine = InferenceEngine(
            cfg, params, max_slots=2, max_seq=128, clock=lambda: vnow[0],
        )
        core = EngineCore(engine, policy=PriorityPolicy(preemption=preemption))
        rng = np.random.default_rng(0)
        offline = [
            core.submit(
                rng.integers(0, cfg.vocab_size, 8),
                SamplingParams(max_new_tokens=offline_budget),
                priority=Priority.OFFLINE, arrival_time=0.0,
            )
            for _ in range(2)
        ]
        arrivals = np.cumsum(rng.exponential(0.02, num_online))
        online = [
            core.submit(
                rng.integers(0, cfg.vocab_size, 8),
                SamplingParams(max_new_tokens=4),
                priority=Priority.ONLINE, arrival_time=float(t),
            )
            for t in arrivals
        ]
        while core.has_unfinished:
            out = core.step(Grant(
                now=vnow[0],
                advance_clock=lambda steps: vnow.__setitem__(
                    0, vnow[0] + steps * step_s
                ),
            ))
            if out.cost_steps == 0 and not out.admitted:
                vnow[0] += step_s  # idle until the next arrival
        assert all(r.state.finished for r in offline + online)
        # percentiles come from the registry's core-recorded histograms
        # (DESIGN.md §8) — the bench no longer re-derives them from the
        # request objects, so there is exactly one stamping path to trust
        m = engine.obs.metrics
        return (
            m.histogram("core/online_latency_s").percentile(95),
            m.histogram("core/online_ttft_s").percentile(95),
            core.preemption_count,
        )

    for policy, preemption in (("preempt", True), ("no_preempt", False)):
        p95, ttft95, n_preempt = run(preemption)
        rows.append(("micro", "core:online_p95_ms(mixed_load)", policy,
                     "ms", round(p95 * 1e3, 2)))
        rows.append(("micro", "core:online_ttft_p95_ms(mixed_load)", policy,
                     "ms", round(ttft95 * 1e3, 2)))
        rows.append(("micro", "core:preemptions(mixed_load)", policy,
                     "count", n_preempt))
    return rows


def bench_chunked_prefill(num_online=12, budget=32, plen=160):
    """Chunked vs monolithic prefill under mixed load through the unified
    token-budget step (DESIGN.md §7) — the acceptance evidence that
    splitting prompts into chunks bounds worst-case step time (so bubble
    grants can never be overrun by a long prompt) and cuts TTFT-under-load
    for online requests queueing behind long admissions.

    Workload: a churn of long-prompt OFFLINE requests (160 tokens each —
    the work whose admission monopolizes a monolithic step) collocated
    with short-prompt ONLINE arrivals, on a virtual clock (one microstep
    == 2 ms, prefill priced at the profiled per-token cost) so the
    comparison is deterministic: identical arrivals, prompts, and budgets;
    the ONLY difference is whether a long admission runs as one monolithic
    dispatch (one step consumes 160+ tokens, blowing through the 32-token
    grant and stalling every online arrival behind it) or streams as
    budgeted chunks (no step's mixed batch — prefill chunk tokens plus
    generated tokens — ever exceeds the grant).  The wall-clock worst-step
    rows are informational (they include first-compile steps); the CI gate
    reads the deterministic token ceilings and the TTFT pair
    (``scripts/check_bench_regression.py``)."""
    from repro.serving.core import (
        EngineCore, Grant, Priority, PriorityPolicy, SamplingParams,
    )

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    step_s = 0.002
    ptc = 1.0 / 16.0  # profiled: one 16-token chunk ~ one decode microstep
    rows = [("micro", "chunked:granted_token_budget(mixed_load)", "grant",
             "tokens", budget)]

    def run(chunk):
        vnow = [0.0]
        engine = InferenceEngine(
            cfg, params, max_slots=2, max_seq=256, clock=lambda: vnow[0],
            prefill_chunk=chunk,
        )
        core = EngineCore(engine, policy=PriorityPolicy(
            prefill_token_cost_steps=ptc,
        ))
        rng = np.random.default_rng(0)
        for i in range(6):  # long-prompt offline churn (distinct prompts)
            core.submit(
                rng.integers(0, cfg.vocab_size, plen),
                SamplingParams(max_new_tokens=12),
                priority=Priority.OFFLINE, arrival_time=0.0,
            )
        arrivals = np.cumsum(rng.exponential(0.02, num_online))
        online = [
            core.submit(
                rng.integers(0, cfg.vocab_size, 8),
                SamplingParams(max_new_tokens=4),
                priority=Priority.ONLINE, arrival_time=float(t),
            )
            for t in arrivals
        ]
        max_step_tokens, worst_wall_ms, worst_cost_ms = 0, 0.0, 0.0
        while core.has_unfinished:
            g0 = engine.generated_tokens_total
            t0 = time.perf_counter()
            out = core.step(Grant(
                now=vnow[0], token_budget=budget,
                advance_clock=lambda steps: vnow.__setitem__(
                    0, vnow[0] + steps * step_s
                ),
            ))
            wall = (time.perf_counter() - t0) * 1e3
            step_tokens = out.prefill_tokens + (
                engine.generated_tokens_total - g0
            )
            max_step_tokens = max(max_step_tokens, step_tokens)
            worst_wall_ms = max(worst_wall_ms, wall)
            worst_cost_ms = max(worst_cost_ms, out.cost_steps * step_s * 1e3)
            if out.cost_steps == 0 and not out.admitted:
                vnow[0] += step_s  # idle until the next arrival
        assert all(r.state.finished for r in online)
        # registry-recorded distributions, same cells FillingMetrics reads
        m = engine.obs.metrics
        return (
            m.histogram("core/online_ttft_s").percentile(95),
            m.histogram("core/online_latency_s").percentile(95),
            max_step_tokens, worst_cost_ms, worst_wall_ms, engine,
        )

    for policy, chunk in (("chunked", None), ("monolithic", 0)):
        ttft95, p95, max_tokens, cost_ms, wall_ms, engine = run(chunk)
        rows.append(("micro", "chunked:online_ttft_p95_ms(mixed_load)",
                     policy, "ms", round(ttft95 * 1e3, 2)))
        rows.append(("micro", "chunked:online_p95_ms(mixed_load)", policy,
                     "ms", round(p95 * 1e3, 2)))
        rows.append(("micro", "chunked:max_step_tokens(mixed_load)", policy,
                     "tokens", max_tokens))
        rows.append(("micro", "chunked:max_step_cost_ms(mixed_load)", policy,
                     "ms", round(cost_ms, 2)))
        rows.append(("micro", "chunked:worst_step_wall_ms(mixed_load)",
                     policy, "ms", round(wall_ms, 2)))
        if policy == "chunked":
            rows.append(("micro", "prefill:chunked_compiled_programs",
                         "chunked", "count", engine.prefill_compile_count))
    return rows


def bench_observability(num_iterations=6):
    """Tracing overhead + trace artifacts (DESIGN.md §8): the SAME
    collocated SpecInF workload runs twice — step tracer enabled vs
    disabled — on the virtual clock.  Tracing must never perturb
    scheduling or the virtual timebase, so the deterministic rows
    (virtual completion time, served counts, TTFT p95) are REQUIRED to be
    identical across the pair; ``scripts/check_bench_regression.py``
    enforces that (trivially within the <=5% budget) plus the SLO
    attribution identity (segments sum to end-to-end latency).  The wall
    rows are informational (host-load noise).

    The traced run's artifacts are written as ``TRACE_engine.jsonl`` and
    ``TRACE_engine.chrome.json`` — CI schema-validates the JSONL
    (``scripts/check_trace_schema.py``) and uploads both."""
    import itertools

    from repro.core import SpecInFRuntime
    from repro.core.profiles import dp_profile
    from repro.obs import Observability
    from repro.serving.core import Priority, SamplingParams

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rows = []

    def run(tracing):
        engine = InferenceEngine(
            cfg, params, max_slots=2, max_seq=96,
            obs=Observability(tracing=tracing),
        )
        core = engine.core
        for _ in range(2):
            core.submit(
                np.arange(8), SamplingParams(max_new_tokens=48),
                priority=Priority.OFFLINE, arrival_time=0.0,
            )
        online = [
            Request(prompt=np.arange(4), max_new_tokens=3,
                    arrival_time=0.03 * i, online=True)
            for i in range(8)
        ]
        rt = SpecInFRuntime(
            train_step=lambda state, batch: (state, {"loss": 0.0}),
            train_state={}, batch_iter=itertools.repeat({}),
            profile=dp_profile("tiny", compute_s=0.03, comm_s=0.04),
            engine=engine, online_requests=online, cfg=SpecInFConfig(),
            decode_microstep_s=0.002,
        )
        t0 = time.perf_counter()
        metrics = rt.run(num_iterations=num_iterations)
        return engine, metrics, time.perf_counter() - t0

    traced = {}
    for mode, tracing in (("traced", True), ("untraced", False)):
        engine, metrics, wall = run(tracing)
        if tracing:
            traced = {"engine": engine, "metrics": metrics}
        rows.append(("micro", "obs:virtual_time_s(collocated)", mode, "s",
                     round(metrics.virtual_time_s, 6)))
        rows.append(("micro", "obs:online_served(collocated)", mode,
                     "count", metrics.online_served))
        rows.append(("micro", "obs:online_ttft_p95_ms(collocated)", mode,
                     "ms", round(metrics.p95_ttft_s() * 1e3, 3)))
        rows.append(("micro", "obs:run_wall_ms(collocated)", mode, "ms",
                     round(wall * 1e3, 1)))
    tr = traced["engine"].obs.tracer
    att = tr.attribution()
    resid = [
        abs(ra.total - (ra.finish_time - ra.arrival_time))
        for ra in att.values() if ra.finish_time is not None
    ]
    rows.append(("micro", "obs:trace_events", "traced", "count",
                 len(tr.events)))
    rows.append(("micro", "obs:trace_dropped", "traced", "count",
                 tr.dropped))
    rows.append(("micro", "obs:attribution_requests", "traced", "count",
                 len(resid)))
    rows.append(("micro", "obs:attribution_max_residual_s", "traced", "s",
                 float(max(resid)) if resid else 0.0))
    tr.write_jsonl(
        "TRACE_engine.jsonl",
        metrics=traced["engine"].obs.metrics.snapshot(),
    )
    tr.write_chrome("TRACE_engine.chrome.json")
    return rows


def bench_control_plane():
    """Monitor + Algorithm 1 cost per 2ms window — must be tiny vs the
    window itself for the ~1% overhead claim to hold."""
    rows = []
    cfg = SpecInFConfig()
    mon = BubbleMonitor(cfg)
    sched = AdaptiveKernelScheduler(cfg, num_instances=4)
    i = [0]

    def one_window():
        zc = mon.observe(i[0] % 7)
        sched.update(zc)
        i[0] += 1

    us = _time_us(one_window, n=10_000)
    rows.append(("micro", "control:monitor+alg1_per_window", "real",
                 "us_per_call", round(us, 2)))
    rows.append(("micro", "control:overhead_vs_2ms_window", "real",
                 "fraction", round(us / 2000.0, 5)))
    return rows


def bench_degradation(num_online=20, offline_backlog=10, step_s=0.002):
    """Overload-ladder payoff under a bursty arrival spike (DESIGN.md §9):
    the SAME workload — an OFFLINE backlog plus a burst of deadline-bearing
    ONLINE arrivals at 10x the slot concurrency — runs twice on the virtual
    clock, with and without the graceful-degradation ladder installed.
    Identical arrivals, prompts, and budgets; the ONLY difference is
    whether ``core.ladder`` may disable spec, shrink k, and shed work.

    The CI gate (``scripts/check_bench_regression.py``) reads the pair:
    the ladder must not worsen served-online p95 and must actually shed
    (a ladder that never fires is dead code, one that fires and still
    loses on latency is a regression).  The stage-occupancy rows record
    which rungs the run visited — the hysteresis evidence."""
    from repro.resilience import LadderConfig, LadderStage, OverloadLadder
    from repro.serving.core import (
        EngineCore, Grant, Priority, PriorityPolicy, SamplingParams,
    )

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rows = []

    def run(ladder):
        vnow = [0.0]
        engine = InferenceEngine(
            cfg, params, max_slots=2, max_seq=128, clock=lambda: vnow[0],
        )
        # no preemption: degradation is the mitigation under test, not
        # eviction (bench_engine_core holds the preemption comparison)
        core = EngineCore(engine, policy=PriorityPolicy(preemption=False))
        if ladder:
            core.ladder = OverloadLadder(LadderConfig(
                high_queue_depth=6, low_queue_depth=2, up_dwell=2,
                down_dwell=6, offline_keep_depth=2, online_slack_s=0.05,
            ))
        rng = np.random.default_rng(0)
        for _ in range(offline_backlog):
            core.submit(
                rng.integers(0, cfg.vocab_size, 8),
                SamplingParams(max_new_tokens=32),
                priority=Priority.OFFLINE, arrival_time=0.0,
            )
        # the burst: 10x the slot concurrency inside ~40ms, every request
        # carrying a queue deadline (satellite: SamplingParams.deadline_s)
        arrivals = 0.05 + np.cumsum(rng.exponential(0.002, num_online))
        for t in arrivals:
            core.submit(
                rng.integers(0, cfg.vocab_size, 8),
                SamplingParams(max_new_tokens=4, deadline_s=0.25),
                priority=Priority.ONLINE, arrival_time=float(t),
            )

        def grant():
            base = vnow[0]
            return Grant(
                now=base, token_budget=16,
                advance_clock=lambda steps, b=base: vnow.__setitem__(
                    0, b + steps * step_s
                ),
            )

        while core.has_unfinished:
            out = core.step(grant())
            if out.cost_steps == 0 and not out.admitted:
                vnow[0] += step_s  # idle until the next arrival
        return engine.obs.metrics

    for policy, ladder in (("ladder", True), ("no_ladder", False)):
        m = run(ladder)
        lat = m.histogram("core/online_latency_s")
        rows.append(("micro", "resil:online_p95_ms(burst)", policy, "ms",
                     round(lat.percentile(95) * 1e3, 2)))
        rows.append(("micro", "resil:online_served(burst)", policy,
                     "count", lat.count))
        rows.append(("micro", "resil:expired(burst)", policy, "count",
                     m.counter("core/finish_reason/expired").value))
        if ladder:
            shed = (m.counter("fault/shed/offline").value
                    + m.counter("fault/shed/online").value)
            rows.append(("micro", "resil:shed_fraction(burst)", policy,
                         "fraction",
                         round(shed / (num_online + offline_backlog), 3)))
            rows.append(("micro", "resil:ladder_escalations(burst)", policy,
                         "count", m.counter("fault/ladder_escalations").value))
            for stage in LadderStage:
                name = stage.name.lower()
                rows.append((
                    "micro", f"resil:ladder_quanta({name})", policy, "count",
                    m.counter("fault/ladder_steps/" + name).value,
                ))
    return rows


def bench_revocation(step_s=0.002):
    """Revocable-grant yield bound (DESIGN.md §9): a quantum is granted,
    then the training side raises the revocation signal mid-quantum (the
    early-resume case).  Measured on the virtual clock: how far past the
    signal does the engine run before yielding the GPU?

    The monolithic row is the historical contract — a grant runs its
    full fused dispatch, so the training step eats the whole remaining
    quantum as overrun.  The revocable row splits the quantum into
    ``revoke_check_steps`` sub-dispatches and must yield within one
    sub-dispatch of the signal — the documented bound the CI gate
    enforces (``scripts/check_bench_regression.py``)."""
    from repro.serving.core import (
        EngineCore, Grant, Priority, PriorityPolicy, RevocationSignal,
        SamplingParams,
    )

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    check_steps = 1
    rows = []

    def run(revocable):
        vnow = [0.0]
        engine = InferenceEngine(
            cfg, params, max_slots=4, max_seq=128, clock=lambda: vnow[0],
        )
        core = EngineCore(engine, policy=PriorityPolicy())
        for _ in range(4):
            core.submit(
                np.arange(8), SamplingParams(max_new_tokens=64),
                priority=Priority.OFFLINE, arrival_time=0.0,
            )

        def grant(sig=None):
            base = vnow[0]
            return Grant(
                now=base, revocation=sig, revoke_check_steps=check_steps,
                advance_clock=lambda steps, b=base: vnow.__setitem__(
                    0, b + steps * step_s
                ),
            )

        core.step(grant())  # admission + prefill
        core.step(grant())  # steady-state decode (compile warm)
        base = vnow[0]
        revoke_at = base + 2.5 * step_s  # signal lands mid-quantum
        sig = RevocationSignal()
        sig.arm(revoke_at)
        out = core.step(grant(sig if revocable else None))
        assert out.k > 0 and out.revoked == (revocable and True)
        return vnow[0] - revoke_at, out

    for policy, revocable in (("revocable", True), ("monolithic", False)):
        overrun_s, out = run(revocable)
        rows.append(("micro", "resil:revocation_overrun_ms", policy, "ms",
                     round(overrun_s * 1e3, 3)))
        if revocable:
            # one sub-dispatch of plain decode = check_steps microsteps
            rows.append(("micro", "resil:revocation_overrun_bound_ms",
                         policy, "ms", round(check_steps * step_s * 1e3, 3)))
            rows.append(("micro", "resil:revocation_partial_k", policy,
                         "count", out.k))
    return rows


def bench_early_resume(num_iterations=6):
    """Training-side cost of revocation (DESIGN.md §9): the collocated
    SpecInF runtime runs with and without injected early training
    resumes (``runtime/early_resume`` — the bubble-misprediction fault).
    Revocation is how serving pays for the overrun, so training's
    virtual step time must stay AT the no-serving analytic baseline in
    both runs — the CI gate (``scripts/check_bench_regression.py``)
    enforces it exactly (virtual clock, deterministic).  The overrun row
    is the serving-side price: how far past the resume instant the
    revoked quantum ran."""
    import itertools

    from repro.core import SpecInFRuntime
    from repro.core.profiles import dp_profile
    from repro.resilience import FaultInjector, FaultSpec

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    compute_s, comm_s = 0.02, 0.04
    # dp_profile exposes comm_s * (1 - overlap) per iteration (overlap 0.3)
    baseline_s = num_iterations * (compute_s + comm_s * 0.7)

    def run(faults):
        engine = InferenceEngine(cfg, params, max_slots=2, max_seq=128)
        for _ in range(2):
            engine.add_request(
                Request(prompt=np.arange(8), max_new_tokens=10**9)
            )
        rt = SpecInFRuntime(
            train_step=lambda state, batch: (state, {"loss": 0.0}),
            train_state={}, batch_iter=itertools.repeat({}),
            profile=dp_profile("tiny", compute_s=compute_s, comm_s=comm_s),
            engine=engine, cfg=SpecInFConfig(), decode_microstep_s=0.004,
            faults=faults,
        )
        metrics = rt.run(num_iterations=num_iterations)
        return rt, metrics

    rows = [("micro", "resil:train_virtual_time_s(collocated)",
             "no_serving_baseline", "s", round(baseline_s, 6))]
    inj = FaultInjector(seed=4, specs=(
        FaultSpec("runtime/early_resume", probability=1.0, max_fires=2),
    ))
    for policy, faults in (("fault_free", None), ("early_resume", inj)):
        rt, metrics = run(faults)
        rows.append(("micro", "resil:train_virtual_time_s(collocated)",
                     policy, "s", round(metrics.virtual_time_s, 6)))
        if faults is not None:
            h = rt.engine.obs.metrics.histogram("fault/revocation_overrun_s")
            worst = max(h.values()) if h.count else 0.0
            rows.append(("micro", "resil:early_resumes(collocated)", policy,
                         "count",
                         rt.engine.obs.metrics.counter(
                             "fault/early_resume").value))
            rows.append(("micro", "resil:early_resume_overrun_ms", policy,
                         "ms", round(worst * 1e3, 3)))
    return rows


def bench_journal(num_online=8, offline_budget=32):
    """Write-ahead journal overhead + replay recovery (DESIGN.md §11):
    the SAME mixed online/offline EngineCore workload runs twice —
    journal attached vs detached — on the virtual clock.  Journal I/O
    happens on the host between quanta and must never perturb the
    schedule, so the deterministic rows (virtual completion time, total
    tokens, finished count) are REQUIRED to be identical across the pair;
    ``scripts/check_bench_regression.py`` enforces that (trivially within
    the <=5% step-time budget).  The wall rows are informational
    (host-load + fsync noise).

    The recovery rows replay the journaled run's log into a FRESH engine
    after a simulated crash (truncate to the last fsync) and report the
    wall cost and volume of deterministic replay recovery."""
    import os
    import tempfile

    from repro.resilience import RequestJournal
    from repro.serving.core import (
        EngineCore, Grant, Priority, PriorityPolicy, SamplingParams,
    )

    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    step_s = 0.002
    rows = []

    def fresh_core():
        vnow = [0.0]
        engine = InferenceEngine(
            cfg, params, max_slots=2, max_seq=128, clock=lambda: vnow[0],
        )
        return EngineCore(engine, policy=PriorityPolicy()), vnow

    def submit(core):
        rng = np.random.default_rng(0)
        for _ in range(2):
            core.submit(
                rng.integers(0, cfg.vocab_size, 8),
                SamplingParams(max_new_tokens=offline_budget),
                priority=Priority.OFFLINE, arrival_time=0.0,
            )
        for t in np.cumsum(rng.exponential(0.02, num_online)):
            core.submit(
                rng.integers(0, cfg.vocab_size, 8),
                SamplingParams(max_new_tokens=4),
                priority=Priority.ONLINE, arrival_time=float(t),
            )

    def drain(core, vnow, max_quanta=None):
        quanta = 0
        while core.has_unfinished:
            if max_quanta is not None and quanta >= max_quanta:
                return False
            out = core.step(Grant(
                now=vnow[0],
                advance_clock=lambda steps: vnow.__setitem__(
                    0, vnow[0] + steps * step_s
                ),
            ))
            quanta += 1
            if out.cost_steps == 0 and not out.admitted:
                vnow[0] += step_s
        return True

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "bench.journal.jsonl")
        for policy, journaled in (("journaled", True), ("unjournaled", False)):
            core, vnow = fresh_core()
            journal = None
            if journaled:
                journal = RequestJournal(path, fsync_interval=8)
                journal.attach(core)
            t0 = time.perf_counter()
            submit(core)
            drain(core, vnow)
            wall = time.perf_counter() - t0
            if journal is not None:
                journal.close()
            tokens = sum(
                len(r.output_tokens) for r in core.requests.values()
            )
            finished = sum(
                1 for r in core.requests.values() if r.state.finished
            )
            rows.append(("micro", "journal:virtual_time_s(mixed_load)",
                         policy, "s", round(vnow[0], 6)))
            rows.append(("micro", "journal:tokens(mixed_load)", policy,
                         "count", tokens))
            rows.append(("micro", "journal:finished(mixed_load)", policy,
                         "count", finished))
            rows.append(("micro", "journal:run_wall_ms(mixed_load)", policy,
                         "ms", round(wall * 1e3, 1)))
            if journal is not None:
                m = core.obs.metrics
                appends = m.counter("journal/appends").value
                rows.append(("micro", "journal:appends", policy, "count",
                             appends))
                rows.append(("micro", "journal:bytes", policy, "count",
                             m.counter("journal/bytes").value))

        # crash mid-run, then replay the surviving journal into a fresh
        # engine: the recovery rows the CI gate requires to be non-trivial
        crash_path = os.path.join(tmp, "crash.journal.jsonl")
        core, vnow = fresh_core()
        journal = RequestJournal(crash_path, fsync_interval=4)
        journal.attach(core)
        submit(core)
        drain(core, vnow, max_quanta=6)
        journal.crash()
        core2, vnow2 = fresh_core()
        journal2 = RequestJournal(crash_path, fsync_interval=4)
        report = journal2.recover_into(core2)
        journal2.attach(core2)
        drain(core2, vnow2)
        journal2.close()
        rows.append(("micro", "journal:recovery_wall_ms", "recovered",
                     "ms", round(report.duration_s * 1e3, 3)))
        rows.append(("micro", "journal:recovered_requests", "recovered",
                     "count", report.restored))
        rows.append(("micro", "journal:replayed_tokens", "recovered",
                     "count", report.replayed_tokens))
        rows.append(("micro", "journal:resumed_inflight", "recovered",
                     "count", report.resumed_inflight))
    return rows


def all_rows():
    return (
        bench_engine_microstep()
        + bench_prefill_buckets()
        + bench_spec_decode()
        + bench_proposers()
        + bench_tree_verify()
        + bench_paged_kv()
        + bench_engine_core()
        + bench_chunked_prefill()
        + bench_observability()
        + bench_control_plane()
        + bench_degradation()
        + bench_revocation()
        + bench_early_resume()
        + bench_journal()
    )
