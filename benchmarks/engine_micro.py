"""Microbenchmarks of the real schedulable units (engine microsteps) and the
control plane — backs the paper's '<1ms kernels / 2ms windows / ~1%
overhead' granularity claims with measured numbers on this host."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import SpecInFConfig
from repro.core import AdaptiveKernelScheduler, BubbleMonitor
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request


def _time_us(fn, n=50, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_engine_microstep():
    rows = []
    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, max_slots=4, max_seq=64)
    for i in range(4):
        engine.add_request(Request(prompt=np.arange(8), max_new_tokens=10**9))

    us = _time_us(lambda: engine.decode_microstep())
    rows.append(("micro", "engine:decode_microstep(4 slots)", "real",
                 "us_per_call", round(us, 1)))
    return rows


def bench_control_plane():
    """Monitor + Algorithm 1 cost per 2ms window — must be tiny vs the
    window itself for the ~1% overhead claim to hold."""
    rows = []
    cfg = SpecInFConfig()
    mon = BubbleMonitor(cfg)
    sched = AdaptiveKernelScheduler(cfg, num_instances=4)
    i = [0]

    def one_window():
        zc = mon.observe(i[0] % 7)
        sched.update(zc)
        i[0] += 1

    us = _time_us(one_window, n=10_000)
    rows.append(("micro", "control:monitor+alg1_per_window", "real",
                 "us_per_call", round(us, 2)))
    rows.append(("micro", "control:overhead_vs_2ms_window", "real",
                 "fraction", round(us / 2000.0, 5)))
    return rows


def all_rows():
    return bench_engine_microstep() + bench_control_plane()
