"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T

ARCHS = list(configs.ARCH_IDS)


def _inputs(cfg, key, batch=2, seq=32):
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    if cfg.embed_inputs:
        return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32), tokens
    return tokens, tokens


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = configs.smoke_config(arch)
    params = T.init_params(cfg, key)
    inputs, _ = _inputs(cfg, key)
    logits, metrics = jax.jit(
        lambda p, i: T.forward(cfg, p, i)
    )(params, inputs)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert np.isfinite(float(metrics["moe_aux"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_analytic(arch, key):
    cfg = configs.smoke_config(arch)
    params = T.init_params(cfg, key)
    n_real = sum(x.size for x in jax.tree.leaves(params))
    assert n_real == cfg.param_count(), (
        f"{arch}: real {n_real} != analytic {cfg.param_count()}"
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, key):
    """One real gradient step moves the loss and stays finite."""
    cfg = configs.smoke_config(arch)
    params = T.init_params(cfg, key)
    inputs, labels = _inputs(cfg, key)

    def loss_fn(p):
        loss, _ = T.lm_loss(cfg, p, inputs, labels, remat_policy="dots")
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # SGD step reduces loss on the same batch
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2 = jax.jit(loss_fn)(params2)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, key):
    """decode_step after prefill(s) must match forward at position s."""
    cfg = configs.smoke_config(arch)
    params = T.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    if cfg.embed_inputs:
        # feed the same embeddings decode_step will produce for these tokens
        inputs = params["embed"][tokens].astype(jnp.float32)
    else:
        inputs = tokens

    logits_full, _ = T.forward(cfg, params, inputs, compute_dtype=jnp.float32)
    # prefill on the first 15 positions, decode token 15
    pre_in = inputs[:, :15] if not cfg.embed_inputs else inputs[:, :15, :]
    _, cache = jax.jit(
        lambda p, i: T.prefill(cfg, p, i, 32, compute_dtype=jnp.float32)
    )(params, pre_in)
    logits_dec, _ = jax.jit(
        lambda p, t, c: T.decode_step(cfg, p, t, c, compute_dtype=jnp.float32)
    )(params, tokens[:, 15], cache)
    ref = logits_full[:, 15, :]
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(ref), rtol=2e-2, atol=2e-2
    )
