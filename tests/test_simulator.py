"""Calibrated-timeline simulator tests: the paper's §5.2 orderings must hold
on every parallel mode (these are the claims EXPERIMENTS.md §Paper-fidelity
reports against Fig. 4/5/6/7)."""
import pytest

from repro.configs.base import SpecInFConfig
from repro.core.profiles import dp_profile, mp_profile, pp_profile
from repro.core.queues import RequestQueue, poisson_arrivals
from repro.core.simulator import Calibration, make_policy, simulate

CAL = Calibration()
# busy_hold_ms=0 -> profile-informed pull gating (the benchmark config)
SPECINF = SpecInFConfig(busy_hold_ms=0.0)

PROFILES = {
    # communication-heavy DP (40% exposed): the regime the paper's Fig. 1a
    # motivates filling in
    "dp": dp_profile("dp", compute_s=0.9, comm_s=0.6, overlap=0.0),
    # 12 TP stages -> ~40ms per-layer bubbles (a 24-layer profile leaves
    # 20ms bubbles no 20ms service can speculatively fit)
    "mp": mp_profile("mp", compute_s=1.0, comm_s=0.5, num_layers=12),
    "pp": pp_profile("pp", compute_s=0.8, comm_s=0.15),
}


def _run(policy_name, profile, *, offline=1, duration=30.0, online_q=None,
         online_instances=0):
    return simulate(
        profile,
        make_policy(policy_name, SPECINF),
        duration_s=duration,
        offline_instances=offline,
        offline_microstep_s=0.010,
        online_queue=online_q,
        online_instances=online_instances,
        cal=CAL,
        specinf_cfg=SPECINF,
    )


@pytest.mark.parametrize("mode", ["dp", "mp", "pp"])
def test_specinf_preserves_training_throughput(mode):
    """Headline guarantee: collocated training stays within a few % of
    exclusive (paper: <= ~7% worst case, typically ~1%)."""
    r = _run("specinf", PROFILES[mode])
    assert r.train_throughput_norm >= 0.93, r


@pytest.mark.parametrize("mode", ["dp", "mp"])
def test_coexec_hurts_training(mode):
    spec = _run("specinf", PROFILES[mode])
    coex = _run("co-exec", PROFILES[mode])
    assert coex.train_throughput_norm < spec.train_throughput_norm


@pytest.mark.parametrize("mode", ["dp", "mp", "pp"])
def test_specinf_beats_tgs_offline(mode):
    spec = _run("specinf", PROFILES[mode])
    tgs = _run("tgs", PROFILES[mode])
    assert spec.offline_throughput_per_s > tgs.offline_throughput_per_s, (spec, tgs)


@pytest.mark.parametrize("mode", ["dp", "mp"])
def test_specinf_beats_mps_offline(mode):
    """Paper Fig. 4/5(a): 1.23x-3.5x (DP) and up to 1.8x (MP) over MPS."""
    spec = _run("specinf", PROFILES[mode])
    mps = _run("mps", PROFILES[mode])
    assert spec.offline_throughput_per_s > mps.offline_throughput_per_s


def test_exclusive_upper_bounds_offline():
    """One dedicated device is the normalization point (norm == 1)."""
    r = _run("exclusive", PROFILES["dp"])
    assert r.offline_norm == pytest.approx(1.0, rel=0.05)


def test_specinf_offline_fraction_of_exclusive():
    """Paper: SpecInF reaches 23-84% of Exclusive's offline throughput."""
    spec = _run("specinf", PROFILES["dp"])
    assert 0.15 <= spec.offline_norm <= 1.0


def _online_queue(seed=0):
    reqs = poisson_arrivals(
        mean_interval_s=0.1, num_requests=150, service_s=0.020, seed=seed,
    )
    return RequestQueue(reqs)


@pytest.mark.parametrize("mode", ["dp", "mp"])
def test_specinf_online_p95_beats_coexec_and_mps(mode):
    """Paper Fig. 4/5(b): SpecInF lowest p95 except Exclusive.  Measured in
    the paper's saturating-load regime (p95 reflects effective bubble
    service capacity) with 3 collocated online instances (§3.3)."""
    results = {}
    for pol in ("specinf", "co-exec", "mps"):
        q = RequestQueue(poisson_arrivals(
            mean_interval_s=0.040, num_requests=600, service_s=0.020, seed=0,
        ))
        results[pol] = _run(
            pol, PROFILES[mode], offline=0, online_q=q, online_instances=3,
            duration=30.0,
        )
    assert results["specinf"].online_p95_s < results["co-exec"].online_p95_s
    assert results["specinf"].online_p95_s < results["mps"].online_p95_s


def test_multi_instance_sublinear_scaling():
    """Paper Fig. 7: offline throughput grows sub-linearly with instances
    while training throughput stays guarded."""
    prev = 0.0
    for m in (1, 2, 4):
        r = _run("specinf", PROFILES["dp"], offline=m)
        assert r.offline_throughput_per_s >= prev * 0.98
        assert r.train_throughput_norm >= 0.90
        prev = r.offline_throughput_per_s
    r1 = _run("specinf", PROFILES["dp"], offline=1)
    r4 = _run("specinf", PROFILES["dp"], offline=4)
    assert r4.offline_throughput_per_s < 4 * r1.offline_throughput_per_s


def test_monitor_overhead_is_small():
    """Paper Fig. 8: collocation machinery without requests costs ~1%."""
    base = _run("exclusive", PROFILES["dp"], offline=0)
    idle = _run("specinf", PROFILES["dp"], offline=0)
    overhead = 1.0 - idle.train_iterations / base.train_iterations
    assert overhead <= 0.02, overhead


def test_pp_gains_are_marginal():
    """Paper §5.2: PP's short per-microbatch gaps shrink SpecInF's edge —
    'comparable to MPS' in PP vs a clear win in DP."""
    dp_gain = (
        _run("specinf", PROFILES["dp"]).offline_throughput_per_s
        / max(_run("mps", PROFILES["dp"]).offline_throughput_per_s, 1e-9)
    )
    pp_gain = (
        _run("specinf", PROFILES["pp"]).offline_throughput_per_s
        / max(_run("mps", PROFILES["pp"]).offline_throughput_per_s, 1e-9)
    )
    assert pp_gain < dp_gain
    assert pp_gain < 2.0, "PP advantage should be marginal (comparable to MPS)"


def test_queue_pull_is_priority_aware():
    """Regression: an online arrival must never wait behind the offline
    queue head.  The old strictly-FIFO pull handed out the earlier-arrived
    offline request first; priority-aware pull serves the online request
    the moment it is visible, while offline order stays FIFO."""
    from repro.core.queues import SimRequest

    reqs = [
        SimRequest(arrival_s=0.0, service_s=1.0, request_id=0, online=False),
        SimRequest(arrival_s=0.1, service_s=1.0, request_id=1, online=False),
        SimRequest(arrival_s=0.2, service_s=0.1, request_id=2, online=True),
    ]
    q = RequestQueue(reqs)
    assert q.pull(0.05).request_id == 0  # only the offline head has arrived
    assert q.available(0.25) == 2
    assert q.pull(0.25).request_id == 2, "online must jump the offline head"
    assert q.pull(0.25).request_id == 1
    assert q.pull(0.25) is None and q.remaining == 0


def test_queue_pull_fifo_within_class():
    qs = poisson_arrivals(mean_interval_s=0.1, num_requests=5,
                          service_s=0.1, online=True)
    q = RequestQueue(qs)
    ids = []
    while (r := q.pull(10.0)) is not None:
        ids.append(r.request_id)
    assert ids == sorted(ids), "pull must stay FIFO inside a priority class"
