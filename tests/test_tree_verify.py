"""Tree verification correctness (DESIGN.md §10).

Three layers, mirroring how the subsystem is built:

* the ancestor-mask tree-verify attention op: Pallas kernel vs the XLA
  oracle across tree shapes (linear chains, balanced branching, irregular
  topologies, GQA, empty slots);
* the structural guarantee that a linear-chain ancestor mask reproduces
  the chunk-verify op EXACTLY (the tree kernel generalizes the causal
  triangle, it does not approximate it);
* the end-to-end property: driving an engine through host-proposed
  tree-verify rounds emits the byte-identical greedy token stream as the
  plain fused decode loop — on dense AND paged KV layouts — no matter what
  the proposer proposes.  The proposer here is adversarial junk, so nearly
  every candidate is rejected and the rollback/compaction path runs every
  round; acceptance correctness is what keeps the streams identical.
"""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels import ops
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request
from repro.spec.proposers.base import Proposer, TokenTree
from repro.spec.tree import (
    branching_tree,
    linear_chain,
    tree_ancestor_masks,
)

TREES = [
    linear_chain(3),
    branching_tree(2, 3),
    branching_tree(3, 2),
    (-1, 0, 0, 1, 1, 2),  # irregular: uneven branch depths
]


def _tree_inputs(b, n, s_max, h, kvh, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, n, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s_max, kvh, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s_max, kvh, hd), jnp.float32)
    lengths = jax.random.randint(ks[3], (b,), n, s_max + 1).astype(jnp.int32)
    lengths = lengths.at[0].set(0)  # empty slot: defined-zero output row
    return q, kc, vc, lengths


@pytest.mark.parametrize("parents", TREES)
@pytest.mark.parametrize("h,kvh", [(2, 2), (4, 2)])  # MHA + GQA grouping
def test_tree_kernel_matches_xla_oracle(parents, h, kvh):
    b, n, s_max, hd = 3, len(parents), 48, 16
    q, kc, vc, lengths = _tree_inputs(b, n, s_max, h, kvh, hd)
    anc = jnp.asarray(
        np.broadcast_to(tree_ancestor_masks(parents), (b, n)).copy()
    )
    ref = ops.tree_verify_attention(q, kc, vc, lengths, anc, impl="xla")
    out = ops.tree_verify_attention(q, kc, vc, lengths, anc, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
    )


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_linear_chain_reproduces_chunk_verify(impl):
    """A linear-chain ancestor mask admits exactly the intra-chunk causal
    triangle, so the tree op must equal ``verify_attention`` bit-for-bit
    in spirit (same masking -> same math, to float tolerance)."""
    gamma = 3
    parents = linear_chain(gamma)
    b, n, s_max, h, kvh, hd = 3, len(parents), 48, 4, 2, 16
    q, kc, vc, lengths = _tree_inputs(b, n, s_max, h, kvh, hd, seed=7)
    anc = jnp.asarray(
        np.broadcast_to(tree_ancestor_masks(parents), (b, n)).copy()
    )
    chain = ops.verify_attention(q, kc, vc, lengths, impl="xla")
    tree = ops.tree_verify_attention(q, kc, vc, lengths, anc, impl=impl)
    np.testing.assert_allclose(
        np.asarray(tree), np.asarray(chain), atol=2e-5, rtol=2e-5
    )


# ---------------------------------------------------------------------------
# End-to-end byte-identity property
# ---------------------------------------------------------------------------

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
MAX_SEQ = 64


class _JunkProposer(Proposer):
    """Adversarial candidate source: proposes a constant junk token
    everywhere, so verification rejects nearly everything and every round
    exercises rollback (and, paged, sibling-page trimming).  ``token`` is
    reassigned per hypothesis example."""

    kind = "host"
    name = "junk"

    def __init__(self, width: int):
        self.width = width
        self.token = 0

    def propose(self, ctx):
        parents = (
            linear_chain(ctx.gamma)
            if self.width == 1
            else branching_tree(self.width, ctx.gamma)
        )
        tail = np.full(
            (len(ctx.histories), len(parents) - 1), self.token, np.int32
        )
        return TokenTree(
            parents=parents, tail=tail,
            matched=np.asarray(ctx.active, bool).copy(),
        )


_ENGINES: dict = {}


def _engines(width, paged):
    key = (width, paged)
    if key not in _ENGINES:
        kw = {"kv_page_size": 8 if paged else 0}
        plain = InferenceEngine(
            CFG, PARAMS, max_slots=3, max_seq=MAX_SEQ,
            compute_dtype=jnp.float32, **kw,
        )
        spec = InferenceEngine(
            CFG, PARAMS, max_slots=3, max_seq=MAX_SEQ,
            compute_dtype=jnp.float32, **kw,
        )
        spec.register_proposer(_JunkProposer(width))
        _ENGINES[key] = (plain, spec)
    return _ENGINES[key]


def _check_tree_rounds_equal_plain(
    width, paged, lens, budgets, first_budget, gamma, token
):
    plain, spec = _engines(width, paged)
    assert plain.num_active == 0 and spec.num_active == 0
    spec._proposers["junk"].token = token
    budgets = [first_budget] + budgets[1:]  # >= 5 decoded tokens guaranteed
    rp, rs = [], []
    for n, m in zip(lens, budgets):
        rp.append(Request(prompt=np.arange(1, n + 1), max_new_tokens=m))
        rs.append(Request(prompt=np.arange(1, n + 1), max_new_tokens=m))
    for r in rp:
        assert plain.add_request(r)
    for r in rs:
        assert spec.add_request(r)
    while plain.num_active:
        plain.decode_loop(4)
    drafted0, accepted0 = spec.spec_drafted, spec.spec_accepted
    guard = 0
    while spec.num_active:
        spec._drive_proposed_loop(2, gamma, "junk")
        guard += 1
        assert guard < 64
    for a, b in zip(rp, rs):
        assert b.generated == a.generated, (
            f"stream diverges: prompt len {len(a.prompt)}, "
            f"budget {a.max_new_tokens}, gamma {gamma}, width {width}, "
            f"paged {paged}"
        )
        assert len(b.generated) == b.max_new_tokens
    # rollback was exercised: junk candidates cannot all equal the target
    # argmax across the >= 5 proposals this run made
    assert (spec.spec_drafted - drafted0) > (spec.spec_accepted - accepted0), (
        "no tree candidate was rejected — rollback untested"
    )


# a fixed example matrix so the byte-identity property holds coverage even
# where hypothesis is unavailable: mixed prompt lengths and budgets, slots
# finishing at different rounds, both gammas, junk tokens in- and
# out-of-distribution
_EXAMPLES = [
    ([1, 4, 10], [6, 1, 9], 1, 0),
    ([7], [12, 3, 3], 2, 2),
    ([2, 2], [8, 5, 1], 1, CFG.vocab_size - 1),
    ([10, 3, 5], [7, 2, 6], 2, 11),
]


@pytest.mark.parametrize("width,paged", [(1, False), (2, True)])
@pytest.mark.parametrize("lens,budgets,gamma,token", _EXAMPLES)
def test_tree_rounds_equal_plain_greedy(
    width, paged, lens, budgets, gamma, token
):
    _check_tree_rounds_equal_plain(
        width, paged, lens, budgets, budgets[0], gamma, token
    )


@pytest.mark.parametrize("width,paged", [(1, False), (2, True)])
def test_tree_rounds_equal_plain_greedy_property(width, paged):
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @given(
        lens=st.lists(st.integers(1, 10), min_size=1, max_size=3),
        budgets=st.lists(st.integers(1, 9), min_size=3, max_size=3),
        first_budget=st.integers(6, 12),
        gamma=st.sampled_from((1, 2)),
        token=st.integers(0, CFG.vocab_size - 1),
    )
    @settings(max_examples=8, deadline=None, derandomize=True)
    def prop(lens, budgets, first_budget, gamma, token):
        _check_tree_rounds_equal_plain(
            width, paged, lens, budgets, first_budget, gamma, token
        )

    prop()
