"""Paged KV pool validation: allocator/radix invariants, paged-vs-dense
kernel equality on random ragged batches, engine-level byte-identical
generation (cold, prefix-hit, and speculative), capacity-based admission,
and the Principle-I memory accounting fix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import draft_config
from repro.kernels import ops
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kv_pool import PageAllocError, PagePool, RadixCache

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# PagePool / RadixCache unit behavior
# ---------------------------------------------------------------------------


def test_pool_alloc_refcount_free():
    pool = PagePool(num_pages=6, page_size=4)
    assert pool.free_pages == 5  # sentinel page 0 excluded
    a = pool.alloc(3)
    assert len(a) == 3 and 0 not in a
    pool.incref(a[:1])
    assert pool.decref(a) == a[1:]  # a[0] still tree/slot-held
    assert pool.decref(a[:1]) == a[:1]
    assert pool.free_pages == 5
    assert pool.pages_for(9) == 3


def test_pool_reservations_gate_allocation():
    pool = PagePool(num_pages=5, page_size=4)
    pool.reserve(3)
    assert pool.available == 1
    pool.alloc(2, reserved=True)  # converts promise to pages
    assert pool.reserved == 1 and pool.free_pages == 2
    # exhaustion is a recoverable runtime condition (DESIGN.md §9), not a bug
    with pytest.raises(PageAllocError):
        pool.alloc(2)  # only 1 available (1 free page is still promised)
    pool.unreserve(1)
    assert pool.available == 2


def test_radix_match_insert_evict():
    pool = PagePool(num_pages=10, page_size=2)
    tree = RadixCache(pool)
    toks = [1, 2, 3, 4, 5, 6]
    pages = pool.alloc(3)
    tree.insert(toks, pages)  # tree increfs all three
    assert tree.pages_cached == 3
    assert tree.match(toks) == pages
    assert tree.match([1, 2, 3, 9]) == pages[:1]
    assert tree.match([9, 9]) == []
    # probe mode leaves counters alone
    h, m = tree.hits, tree.misses
    tree.match(toks, record=False)
    assert (tree.hits, tree.misses) == (h, m)
    # slot releases its refs; pages survive via the tree, then evict LRU
    pool.decref(pages)
    assert pool.free_pages == 10 - 1 - 3
    assert tree.evictable_pages() == 3
    assert tree.evict(2) == 2
    assert tree.match(toks) == pages[:1]  # deepest chunks evicted first
    assert tree.evict(5) == 1
    assert pool.free_pages == 9


def test_radix_never_shares_partial_pages():
    pool = PagePool(num_pages=8, page_size=4)
    tree = RadixCache(pool)
    pages = pool.alloc(1)
    tree.insert([1, 2, 3, 4, 5, 6], pages)  # only one FULL page
    assert tree.pages_cached == 1
    assert tree.match([1, 2, 3, 4, 5, 6, 7, 8]) == pages


# ---------------------------------------------------------------------------
# Paged kernels == dense kernels on random ragged batches
# ---------------------------------------------------------------------------


def _paged_from_dense(k, v, page, rng):
    """Scatter a dense [B, S, kvH, hd] cache into a randomly-permuted page
    pool + block tables (one sentinel-padded column, as the engine lays
    them out)."""
    b, s, kvh, hd = k.shape
    npages = s // page
    pool_n = 1 + b * npages
    perm = rng.permutation(np.arange(1, pool_n))
    bt = perm.reshape(b, npages)
    k_pool = np.zeros((pool_n, page, kvh, hd), np.float32)
    v_pool = np.zeros((pool_n, page, kvh, hd), np.float32)
    for i in range(b):
        for j in range(npages):
            k_pool[bt[i, j]] = np.asarray(k[i, j * page:(j + 1) * page])
            v_pool[bt[i, j]] = np.asarray(v[i, j * page:(j + 1) * page])
    bt = np.concatenate([bt, np.zeros((b, 1), np.int64)], axis=1)
    return (jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(bt, jnp.int32))


def _rand_case(seed, b, h, kvh, s, hd, t=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    qs = (b, h, hd) if t is None else (b, t, h, hd)
    q = jax.random.normal(ks[0], qs, jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, hd), jnp.float32)
    return q, k, v


def _ragged_lengths(rng, b, s):
    """Random per-slot lengths biased toward the boundary cases (empty
    slot, single token, page-edge, full)."""
    picks = [0, 1, s, max(s - 1, 0)] + list(rng.randint(0, s + 1, size=b))
    return jnp.asarray([picks[rng.randint(0, len(picks))] for _ in range(b)],
                       jnp.int32)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_decode_matches_dense_kernel(impl):
    """Property (seeded sweep): paged decode attention is element-wise equal
    to the dense kernel on random ragged batches with randomly-permuted
    physical page placement."""
    geoms = [(4, 2, 16), (8, 2, 32), (4, 4, 16), (2, 1, 16)]
    for seed in range(12):
        rng = np.random.RandomState(seed)
        h, kvh, hd = geoms[seed % len(geoms)]
        b = rng.randint(1, 5)
        page = int(rng.choice([8, 16]))
        s = page * rng.randint(2, 6)
        q, k, v = _rand_case(seed, b, h, kvh, s, hd)
        lengths = _ragged_lengths(rng, b, s)
        k_pool, v_pool, bt = _paged_from_dense(k, v, page, rng)
        ref = ops.decode_attention(q, k, v, lengths, impl="xla")
        out = ops.paged_decode_attention(
            q, k_pool, v_pool, bt, lengths, impl=impl
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5,
            err_msg=f"seed={seed} b={b} page={page} s={s} "
                    f"lengths={np.asarray(lengths)}",
        )


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_verify_matches_dense_kernel(impl):
    """Property (seeded sweep): paged chunk-verify attention equals the
    dense verify kernel on random ragged batches, including chunks larger
    than a slot's causal window."""
    geoms = [(4, 2, 16), (4, 4, 16), (2, 1, 32)]
    for seed in range(10):
        rng = np.random.RandomState(1000 + seed)
        h, kvh, hd = geoms[seed % len(geoms)]
        b = rng.randint(1, 4)
        t = rng.randint(1, 5)
        page = int(rng.choice([8, 16]))
        s = page * rng.randint(2, 5)
        q, k, v = _rand_case(seed, b, h, kvh, s, hd, t=t)
        lengths = _ragged_lengths(rng, b, s)
        k_pool, v_pool, bt = _paged_from_dense(k, v, page, rng)
        ref = ops.verify_attention(q, k, v, lengths, impl="xla")
        out = ops.paged_verify_attention(
            q, k_pool, v_pool, bt, lengths, impl=impl
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5,
            err_msg=f"seed={seed} b={b} t={t} page={page} s={s} "
                    f"lengths={np.asarray(lengths)}",
        )


# ---------------------------------------------------------------------------
# Engine equivalence: paged layout is invisible in the token stream
# ---------------------------------------------------------------------------


def _drain(engine, k=4, guard=200):
    while engine.num_active and guard:
        engine.decode_loop(k)
        guard -= 1
    assert engine.num_active == 0


def _run_engine(paged, cases, **kw):
    eng = InferenceEngine(
        CFG, PARAMS, max_slots=3, max_seq=64,
        kv_page_size=None if paged else 0, **kw,
    )
    reqs = [Request(prompt=np.arange(1, n + 1), max_new_tokens=m)
            for n, m in cases]
    for r in reqs:
        assert eng.add_request(r)
    _drain(eng)
    return [r.generated for r in reqs], eng


def test_paged_engine_stream_equals_dense():
    cases = [(5, 12), (17, 7), (33, 40)]  # ragged; one hits the seq horizon
    gp, ep = _run_engine(True, cases)
    gd, _ = _run_engine(False, cases)
    assert gp == gd
    # full retirement releases every page except the radix-cached prefixes
    assert ep.pool.pages_in_use == ep.prefix_cache.pages_cached
    assert ep.pool.reserved == 0


def test_prefix_hit_skips_prefill_and_is_byte_identical():
    eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=64)
    prompt = np.arange(1, 40)  # 39 tokens -> 2 full pages (page=16) cacheable
    cold = Request(prompt=prompt, max_new_tokens=10)
    assert eng.add_request(cold)
    _drain(eng)
    assert eng.prefill_skipped_tokens == 0
    assert eng.prefix_cache.pages_cached == 2

    warm = Request(prompt=prompt, max_new_tokens=10)
    assert eng.add_request(warm)
    # the shared length ran zero prefill FLOPs (counter-verified)
    assert eng.prefill_skipped_tokens == 32
    assert eng.prefill_skip_fraction == pytest.approx(32 / 78)
    _drain(eng)
    assert warm.generated == cold.generated


def test_prefix_hit_shares_pages_physically():
    eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=64)
    prompt = np.arange(1, 40)
    assert eng.add_request(Request(prompt=prompt, max_new_tokens=4))
    shared_pages = eng._slot_pages[0][:2]
    assert eng.add_request(Request(prompt=prompt, max_new_tokens=4))
    # the second slot's first two logical pages ARE the first slot's
    assert eng._slot_pages[1][:2] == shared_pages
    assert all(eng.pool.refcount[p] == 3 for p in shared_pages)  # 2 slots + tree
    _drain(eng)
    assert all(eng.pool.refcount[p] == 1 for p in shared_pages)  # tree only


def test_partial_prefix_hit_prefills_only_suffix():
    eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=64)
    a = np.arange(1, 40)
    b = np.concatenate([a[:32], np.arange(100, 110)])  # diverges after 2 pages
    r_a = Request(prompt=a, max_new_tokens=6)
    assert eng.add_request(r_a)
    _drain(eng)
    r_b = Request(prompt=b, max_new_tokens=6)
    assert eng.add_request(r_b)
    assert eng.prefill_skipped_tokens == 32
    _drain(eng)
    # cross-check against a cold engine: the shared-prefix suffix prefill
    # must not change the stream
    cold = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=64)
    r_cold = Request(prompt=b, max_new_tokens=6)
    assert cold.add_request(r_cold)
    _drain(cold)
    assert r_b.generated == r_cold.generated


# ---------------------------------------------------------------------------
# Capacity-based admission (pool pages, not dense rows)
# ---------------------------------------------------------------------------


def test_admission_is_capacity_based_and_recovers():
    # 8 real pages of 16 tokens; each request needs ceil(24/16) = 2 pages
    eng = InferenceEngine(
        CFG, PARAMS, max_slots=8, max_seq=64, kv_pool_pages=9,
        enable_prefix_cache=False,
    )
    reqs = [Request(prompt=np.arange(1, 9), max_new_tokens=16)
            for _ in range(5)]
    admitted = [eng.add_request(r) for r in reqs]
    # 4 * 2 pages exhaust the pool although 4 more dense slots are free
    assert admitted == [True] * 4 + [False]
    assert not eng.can_admit(reqs[4])
    _drain(eng)
    assert eng.can_admit(reqs[4]) and eng.add_request(reqs[4])
    _drain(eng)


def test_admission_evicts_cached_prefixes_when_full():
    eng = InferenceEngine(
        CFG, PARAMS, max_slots=4, max_seq=64, kv_pool_pages=6,  # 5 real pages
    )
    warm = Request(prompt=np.arange(1, 33), max_new_tokens=2)  # 2 pages cached
    assert eng.add_request(warm)
    _drain(eng)
    assert eng.prefix_cache.pages_cached == 2
    assert eng.pool.available == 3
    # needs 4 pages: only admittable by evicting part of the cached prefix
    big = Request(prompt=np.arange(100, 140), max_new_tokens=24)
    assert eng.can_admit(big)
    assert eng.add_request(big)
    assert len(eng.prefix_cache.match(np.arange(1, 33), record=False)) < 2
    _drain(eng)


def test_paged_engine_fits_more_slots_at_equal_hbm():
    """The headline capacity claim: at the HBM of a 4-slot dense cache, the
    paged engine holds >= 2x the concurrent short requests."""
    max_seq = 64
    dense = InferenceEngine(CFG, PARAMS, max_slots=4, max_seq=max_seq,
                            kv_page_size=0)
    paged = InferenceEngine(
        CFG, PARAMS, max_slots=32, max_seq=max_seq,
        kv_pool_pages=4 * (max_seq // 16) + 1,  # == dense KV HBM
    )
    assert paged.kv_cache_bytes() <= dense.kv_cache_bytes() * 1.1

    def fill(eng):
        n = 0
        while True:
            r = Request(prompt=np.arange(1, 9), max_new_tokens=8)
            if not eng.add_request(r):
                return n
            n += 1

    dense_slots, paged_slots = fill(dense), fill(paged)
    assert dense_slots == 4
    assert paged_slots >= 2 * dense_slots


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_verify_lengths_past_capacity_keep_causal_bound(impl):
    """Regression: suffix prefill passes lengths = shared + T_bucket, which
    can exceed the pool's logical capacity when the bucket's pad tail
    spills past max_seq.  Clamping lengths inside the kernel would shift
    the causal bound (length - chunk + t_row) and silently mask real
    prefix positions for the real rows."""
    h, kvh, hd, page, npages, t = 4, 2, 16, 16, 4, 16
    s = page * npages  # logical capacity 64
    q, k, v = _rand_case(7, 2, h, kvh, s, hd, t=t)
    # lengths exceed capacity by part of the chunk's pad tail; real rows
    # (small t) still attend only in-capacity positions
    lengths = jnp.asarray([s + 8, s + 3], jnp.int32)
    k_pool, v_pool, bt = _paged_from_dense(k, v, page, np.random.RandomState(7))
    ref = ops.verify_attention(q, k, v, lengths, impl="xla")
    out = ops.paged_verify_attention(q, k_pool, v_pool, bt, lengths, impl=impl)
    # rows whose causal window fits the capacity must match exactly
    for b in range(2):
        real_rows = s - 1 - (int(lengths[b]) - t)  # bound <= s-1 for t < this
        np.testing.assert_allclose(
            np.asarray(out[b, :real_rows]), np.asarray(ref[b, :real_rows]),
            rtol=2e-5, atol=2e-5,
        )


def test_spec_engine_admits_on_unaligned_max_seq():
    """Regression: the paged bucket cap (max_seq rounded up to a page
    multiple) must not leak into the dense draft cache's prefill, whose
    K/V pad width is exactly max_seq."""
    eng = InferenceEngine(
        CFG, PARAMS, max_slots=1, max_seq=200,
        draft_cfg=DCFG, draft_params=DPARAMS,
    )
    r = Request(prompt=np.arange(1, 151), max_new_tokens=4)
    assert eng.add_request(r)
    while eng.num_active:
        eng.spec_decode_loop(2, 2)
    assert len(r.generated) == 4


def test_unaligned_max_seq_buckets_stay_page_aligned():
    """Regression: a paged engine whose max_seq is not a page multiple must
    still admit prompts whose bucket clamps at max_seq (the clamp rounds up
    to a page multiple; positions past max_seq are pad)."""
    paged = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=200)
    dense = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=200,
                            kv_page_size=0)
    rp = Request(prompt=np.arange(1, 151), max_new_tokens=5)
    rd = Request(prompt=np.arange(1, 151), max_new_tokens=5)
    assert paged.add_request(rp) and dense.add_request(rd)
    _drain(paged)
    _drain(dense)
    assert rp.generated == rd.generated


def test_request_fits_flags_structural_impossibility():
    eng = InferenceEngine(
        CFG, PARAMS, max_slots=4, max_seq=64, kv_pool_pages=3,  # 2 real pages
    )
    assert not eng.request_fits(
        Request(prompt=np.arange(100), max_new_tokens=1)  # prompt > max_seq
    )
    assert not eng.request_fits(
        Request(prompt=np.arange(8), max_new_tokens=60)  # 4 pages > pool
    )
    ok = Request(prompt=np.arange(8), max_new_tokens=8)  # 1 page
    assert eng.request_fits(ok) and eng.can_admit(ok)


# ---------------------------------------------------------------------------
# Speculative decoding on the paged cache
# ---------------------------------------------------------------------------


DCFG = draft_config(CFG)
DPARAMS = T.init_params(DCFG, jax.random.PRNGKey(5))


def test_spec_greedy_paged_identical_with_rollback():
    plain = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=64,
                            compute_dtype=jnp.float32)
    spec = InferenceEngine(
        CFG, PARAMS, max_slots=2, max_seq=64, compute_dtype=jnp.float32,
        draft_cfg=DCFG, draft_params=DPARAMS,
    )
    assert plain.paged and spec.paged
    cases = [(5, 11), (18, 9)]
    rp = [Request(prompt=np.arange(1, n + 1), max_new_tokens=m)
          for n, m in cases]
    rs = [Request(prompt=np.arange(1, n + 1), max_new_tokens=m)
          for n, m in cases]
    for r in rp:
        assert plain.add_request(r)
    for r in rs:
        assert spec.add_request(r)
    _drain(plain)
    guard = 60
    while spec.num_active and guard:
        spec.spec_decode_loop(2, 2)
        guard -= 1
    assert [r.generated for r in rs] == [r.generated for r in rp]
    # random-init draft: ~every round rejects, so rollback page-trims ran
    assert spec.spec_drafted > 0 and spec.spec_acceptance_rate < 0.5
    assert spec.pool.reserved == 0
    assert spec.pool.pages_in_use == spec.prefix_cache.pages_cached


def test_retirement_resets_draft_index_on_all_paths():
    """Regression: plain decode_loop / decode_microstep retirements left the
    draft cache index stale on spec-enabled engines."""
    for path in ("loop", "microstep"):
        eng = InferenceEngine(
            CFG, PARAMS, max_slots=1, max_seq=64,
            draft_cfg=DCFG, draft_params=DPARAMS,
        )
        assert eng.add_request(
            Request(prompt=np.arange(1, 6), max_new_tokens=3)
        )
        guard = 20
        while eng.num_active and guard:
            eng.decode_loop(2) if path == "loop" else eng.decode_microstep()
            guard -= 1
        assert int(np.asarray(eng.draft_cache["index"])[0]) == 0, path
        # slot reuse after the reset must still be exact
        plain = InferenceEngine(CFG, PARAMS, max_slots=1, max_seq=64)
        r_ref = Request(prompt=np.arange(3, 9), max_new_tokens=4)
        assert plain.add_request(r_ref)
        _drain(plain)
        r2 = Request(prompt=np.arange(3, 9), max_new_tokens=4)
        assert eng.add_request(r2)
        while eng.num_active:
            eng.spec_decode_loop(2, 2)
        assert r2.generated == r_ref.generated, path


# ---------------------------------------------------------------------------
# Principle-I memory accounting
# ---------------------------------------------------------------------------


def test_memory_bytes_counts_draft_and_pool():
    plain = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=64)
    spec = InferenceEngine(
        CFG, PARAMS, max_slots=2, max_seq=64,
        draft_cfg=DCFG, draft_params=DPARAMS,
    )
    leaf_bytes = lambda t: sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(t)
    )
    assert plain.memory_bytes() == (
        leaf_bytes(PARAMS) + leaf_bytes(plain.cache)
    )
    # the pool (inside cache) is accounted, and the draft side no longer
    # disappears from the capacity input
    assert spec.memory_bytes() == (
        leaf_bytes(PARAMS) + leaf_bytes(spec.cache)
        + leaf_bytes(DPARAMS) + leaf_bytes(spec.draft_cache)
    )
    assert spec.memory_bytes() > plain.memory_bytes()
