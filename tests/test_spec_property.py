"""Property test: greedy speculative decoding is an *exact* accelerator.

For any mix of prompt lengths, token budgets, and draft lengths, the fused
``spec_decode_loop`` in greedy mode must emit the byte-identical token
stream as the plain greedy ``decode_loop`` on the same target parameters —
accepted drafts equal the target argmax by construction, and every
correction/bonus token *is* the target argmax, so divergence anywhere means
a bug in chunk scoring, acceptance, or rollback.  The draft is a different
random-init model, so acceptance is near zero and every run rejects (and
therefore rolls back) draft tokens.

Engines are module-cached per draft length: requests finish between
examples, which is exactly the continuous-batching reuse the engine
supports, and it keeps one set of compiled programs per gamma bucket.
"""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import draft_config
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
DCFG = draft_config(CFG)
DPARAMS = T.init_params(DCFG, jax.random.PRNGKey(5))
MAX_SEQ = 64  # ample: prompts + budgets below never hit the seq horizon

_ENGINES: dict = {}


def _engines(gamma):
    if gamma not in _ENGINES:
        _ENGINES[gamma] = (
            InferenceEngine(
                CFG, PARAMS, max_slots=3, max_seq=MAX_SEQ,
                compute_dtype=jnp.float32,
            ),
            InferenceEngine(
                CFG, PARAMS, max_slots=3, max_seq=MAX_SEQ,
                compute_dtype=jnp.float32, draft_cfg=DCFG,
                draft_params=DPARAMS,
            ),
        )
    return _ENGINES[gamma]


@given(
    lens=st.lists(st.integers(1, 10), min_size=1, max_size=3),
    budgets=st.lists(st.integers(1, 9), min_size=3, max_size=3),
    first_budget=st.integers(6, 12),
    gamma=st.sampled_from((1, 2)),
)
@settings(max_examples=8, deadline=None, derandomize=True)
def test_greedy_spec_equals_plain_greedy(lens, budgets, first_budget, gamma):
    plain, spec = _engines(gamma)
    assert plain.num_active == 0 and spec.num_active == 0
    budgets = [first_budget] + budgets[1:]  # >= 5 decoded tokens guaranteed
    rp, rs = [], []
    for n, m in zip(lens, budgets):
        rp.append(Request(prompt=np.arange(1, n + 1), max_new_tokens=m))
        rs.append(Request(prompt=np.arange(1, n + 1), max_new_tokens=m))
    for r in rp:
        assert plain.add_request(r)
    for r in rs:
        assert spec.add_request(r)
    while plain.num_active:
        plain.decode_loop(4)
    drafted0, accepted0 = spec.spec_drafted, spec.spec_accepted
    guard = 0
    while spec.num_active:
        d2h0 = spec.d2h_transfers
        spec.spec_decode_loop(2, gamma)
        assert spec.d2h_transfers - d2h0 == 1, "one transfer per fused loop"
        guard += 1
        assert guard < 64
    for a, b in zip(rp, rs):
        assert b.generated == a.generated, (
            f"stream diverges: prompt len {len(a.prompt)}, "
            f"budget {a.max_new_tokens}, gamma {gamma}"
        )
        assert len(b.generated) == b.max_new_tokens
    # rollback was exercised: the random draft cannot match the target on
    # every one of the >= 5 proposals this run made
    assert (spec.spec_drafted - drafted0) > (spec.spec_accepted - accepted0), (
        "no draft token was rejected — rollback untested"
    )
