"""Sharding-rule coverage: every FULL-config parameter/cache leaf gets a
spec, every sharded dim divides its mesh axis (jit argument requirement),
and the batch/activation tables resolve for all 10 archs x 4 shapes.

Runs against abstract shapes only (no allocation) on a symbolic 16x16 mesh —
safe under the single CPU device because meshes are never materialized into
device_puts here.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.runtime import sharding as S
from repro.runtime.step import abstract_cache, abstract_params

ARCHS = list(configs.ARCH_IDS)


class FakeMesh:
    """Shape/axis-name stand-in (rule logic only reads these)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisibility(tree_specs, tree_shapes, mesh):
    leaves_sp = jax.tree.leaves(tree_specs, is_leaf=lambda x: isinstance(x, P))
    leaves_sh = jax.tree.leaves(tree_shapes)
    assert len(leaves_sp) == len(leaves_sh)
    for spec, leaf in zip(leaves_sp, leaves_sh):
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (spec, leaf.shape, dim, size)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multi"])
def test_param_specs_cover_and_divide(arch, mesh):
    cfg = configs.get_config(arch)
    params = abstract_params(cfg)
    specs = S.param_specs(cfg, params, mesh=mesh, fsdp=True)  # raises on gap
    _check_divisibility(specs, params, mesh)


@pytest.mark.parametrize("arch", ARCHS)
def test_opt_state_specs_zero1(arch):
    cfg = configs.get_config(arch)
    params = abstract_params(cfg)
    specs = S.opt_state_specs(cfg, params, True, MESH, fsdp=True)
    _check_divisibility(specs["mu"], params, MESH)
    assert specs["step"] == P()


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_cover_and_divide(arch, shape_name):
    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    ok, _ = configs.shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("shape not applicable")
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    specs = S.cache_specs(cfg, cache, shape, MESH)
    _check_divisibility(specs["layers"], cache["layers"], MESH)


@pytest.mark.parametrize("arch", ARCHS)
def test_activation_specs_complete(arch):
    cfg = configs.get_config(arch)
    specs = S.activation_specs(cfg, MESH)
    for kind in ("btd", "bthd", "btkv", "btf", "btv", "bti", "bv"):
        assert kind in specs


def test_fsdp_shards_large_free_dims():
    cfg = configs.get_config("deepseek-coder-33b")
    params = abstract_params(cfg)
    specs = S.param_specs(cfg, params, mesh=MESH, fsdp=True)
    flat = {
        "/".join(str(getattr(p, "key", p)) for p in path): s
        for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        )[0]
    }
    # the big dense FFN weight must carry both model (TP) and data (FSDP)
    wg = [s for k, s in flat.items() if k.endswith("ffn/wg")][0]
    axes = set()
    for part in tuple(wg):
        if part is not None:
            axes |= set(part if isinstance(part, tuple) else (part,))
    assert "model" in axes and "data" in axes, wg


def test_nondivisible_heads_fall_back_to_replication():
    """qwen2 (28H / kv4) cannot shard heads 16 ways -> replicated attention
    weights (documented baseline limitation, see DESIGN.md)."""
    cfg = configs.get_config("qwen2-7b")
    plan = S.ShardingPlan(cfg, MESH)
    assert not plan.heads_shardable and not plan.kv_shardable
    olmo = S.ShardingPlan(configs.get_config("olmo-1b"), MESH)
    assert olmo.heads_shardable and olmo.kv_shardable


def test_kv_cache_seq_sharding_when_heads_do_not_divide():
    cfg = configs.get_config("qwen2-7b")  # kv=4, model=16
    shape = configs.get_shape("decode_32k")
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    specs = S.cache_specs(cfg, cache, shape, MESH)
    k_spec = specs["layers"]["k"]
    assert tuple(k_spec)[2] == "model"  # seq dim carries model
    assert tuple(k_spec)[3] is None  # kv-head dim replicated


def test_unknown_parameter_fails_loudly():
    cfg = configs.get_config("olmo-1b")
    bogus = {"layers": {"mystery_weight": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
    with pytest.raises(ValueError, match="no sharding rule"):
        S.param_specs(cfg, bogus, mesh=MESH)


def test_batch_specs_modalities():
    dense = configs.get_config("qwen2-7b")
    vlm = configs.get_config("pixtral-12b")
    bd = S.batch_specs(dense, None, MESH)
    bv = S.batch_specs(vlm, None, MESH)
    assert bd["inputs"] == P(("data",), None)
    assert bv["inputs"] == P(("data",), None, None)  # embeddings input
