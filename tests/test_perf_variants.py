"""Correctness of the §Perf variants: physical head padding must be
bit-exact vs the unpadded model; dp256 layout specs must be duplicate-free
and divisible; MoE dispatch variants must agree."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.runtime import sharding as S
from repro.runtime.step import abstract_params


def _pad_logical_weights(cfg, pad_cfg, params):
    """Embed logical attention weights into the padded physical slots."""
    h, hd, kv = cfg.num_heads, cfg.resolved_head_dim, cfg.num_kv_heads
    gl, gp = h // kv, pad_cfg.num_heads_physical // kv

    def padq(w, axis):
        segs = jnp.split(w, kv, axis=axis)
        width = [(0, 0)] * w.ndim
        width[axis] = (0, gp - gl)
        return jnp.concatenate([jnp.pad(s, width) for s in segs], axis=axis)

    attn = dict(params["layers"]["attn"])
    attn["wq"] = padq(attn["wq"], 2)  # [L, d, H, hd]
    attn["wo"] = padq(attn["wo"], 1)  # [L, H, hd, d]
    if "bq" in attn:
        attn["bq"] = padq(attn["bq"], 1)
    out = dict(params)
    out["layers"] = dict(params["layers"])
    out["layers"]["attn"] = attn
    return out


def test_head_padding_bit_exact():
    cfg = configs.smoke_config("qwen2-7b")
    cfg = dataclasses.replace(cfg, num_heads=4, num_kv_heads=2)
    pad_cfg = cfg.padded_for_tp(3)  # group 2 -> 3 slots, H_phys 6
    assert pad_cfg.num_heads_physical == 6
    assert pad_cfg.num_heads == 4  # logical arch unchanged

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    padded = _pad_logical_weights(cfg, pad_cfg, params)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)

    l1, _ = T.forward(cfg, params, toks, compute_dtype=jnp.float32)
    l2, _ = T.forward(pad_cfg, padded, toks, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    # decode path too
    _, c1 = T.prefill(cfg, params, toks[:, :8], 16, compute_dtype=jnp.float32)
    _, c2 = T.prefill(pad_cfg, padded, toks[:, :8], 16,
                      compute_dtype=jnp.float32)
    d1, _ = T.decode_step(cfg, params, toks[:, 8], c1,
                          compute_dtype=jnp.float32)
    d2, _ = T.decode_step(pad_cfg, padded, toks[:, 8], c2,
                          compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_padded_for_tp_assignments():
    qwen2 = configs.get_config("qwen2-7b").padded_for_tp(16)
    assert qwen2.num_heads_physical == 32  # 28 -> 8 slots x 4 kv groups
    deepseek = configs.get_config("deepseek-coder-33b").padded_for_tp(16)
    assert deepseek.num_heads_physical == 64  # 56 -> 8 slots x 8 kv groups
    olmo = configs.get_config("olmo-1b").padded_for_tp(16)
    assert not olmo.padded_heads  # 16 % 16 == 0: untouched


def test_padding_masks_gradients():
    """Padded slots must receive exactly zero gradient (arch-equivalence
    holds throughout training, not just at init)."""
    cfg = dataclasses.replace(
        configs.smoke_config("qwen2-7b"), num_heads=4, num_kv_heads=2
    ).padded_for_tp(3)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)

    def loss(p):
        l, _ = T.lm_loss(cfg, p, toks, toks, compute_dtype=jnp.float32)
        return l

    grads = jax.grad(loss)(params)
    gq = np.asarray(grads["layers"]["attn"]["wq"])  # [L, d, 6, hd]
    go = np.asarray(grads["layers"]["attn"]["wo"])  # [L, 6, hd, d]
    # slots 2 and 5 are padding (group_phys=3, group_log=2)
    assert np.abs(gq[:, :, [2, 5], :]).max() == 0.0
    assert np.abs(go[:, [2, 5], :, :]).max() == 0.0
    assert np.abs(gq[:, :, [0, 1, 3, 4], :]).max() > 0.0


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen3-1.7b", "falcon-mamba-7b"])
def test_dp256_layout_specs_valid(arch):
    """dp256 specs: no duplicate axis use, all sharded dims divide."""
    cfg = configs.get_config(arch)
    params = abstract_params(cfg)
    specs = S.param_specs(cfg, params, mesh=MESH, fsdp=True, layout="dp256")
    opt = S.opt_state_specs(cfg, params, True, MESH, fsdp=True, layout="dp256")
    for tree in (specs, opt["mu"]):
        for spec, leaf in zip(
            jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(params),
        ):
            used = []
            for part, dim in zip(tuple(spec), leaf.shape):
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                used += list(axes)
                size = 1
                for a in axes:
                    size *= MESH.shape[a]
                assert dim % size == 0, (spec, leaf.shape)
            assert len(used) == len(set(used)), f"duplicate axes in {spec}"
    assert S.dp_axes(MESH, "dp256") == ("data", "model")


def test_moe_dispatch_variants_agree():
    cfg = configs.smoke_config("moonshot-v1-16b-a3b")
    p = MOE.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, cfg.d_model),
                          jnp.float32)
    outs = {}
    for mode in ("vmap", "batched"):
        MOE.set_dispatch(mode)
        try:
            outs[mode] = MOE.moe_block(cfg, p, x)
        finally:
            MOE.set_dispatch("vmap")
    y_v, aux_v, drop_v = outs["vmap"]
    y_b, aux_b, drop_b = outs["batched"]
    np.testing.assert_allclose(np.asarray(y_v), np.asarray(y_b),
                               rtol=1e-5, atol=1e-5)
    assert float(aux_v) == pytest.approx(float(aux_b), rel=1e-5)


def test_fp8_kv_cache_decode_runs():
    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    _, cache_bf16 = T.prefill(cfg, params, toks, 16)
    _, cache_fp8 = T.prefill(cfg, params, toks, 16,
                             cache_dtype=jnp.float8_e4m3fn)
    assert cache_fp8["layers"]["k"].dtype == jnp.float8_e4m3fn
    l16, _ = T.decode_step(cfg, params, toks[:, -1], cache_bf16)
    l8, c8 = T.decode_step(cfg, params, toks[:, -1], cache_fp8)
    assert c8["layers"]["k"].dtype == jnp.float8_e4m3fn
    # fp8 cache is lossy but must stay close on a short context
    a = np.asarray(l16, np.float32)
    b = np.asarray(l8, np.float32)
    cos = np.sum(a * b) / (np.linalg.norm(a) * np.linalg.norm(b))
    assert cos > 0.98, cos
