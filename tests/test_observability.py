"""Engine observability layer (DESIGN.md §8): metrics registry, structured
step tracer, and SLO attribution.

The collocated fixture runs a real virtual-clock SpecInF fill over a real
engine so the trace/attribution tests exercise the actual emission sites;
the unit tests below cover the registry/histogram/tracer/schema contracts
in isolation.
"""
import itertools
import json

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SpecInFConfig
from repro.core import SpecInFRuntime
from repro.core.profiles import dp_profile
from repro.models import transformer as T
from repro.obs import (
    STABLE_NAMES,
    MetricsRegistry,
    Observability,
    StepTracer,
    StreamingHistogram,
    attribute,
    validate_events,
    validate_jsonl,
)
from repro.serving.core import Priority, SamplingParams
from repro.serving.engine import InferenceEngine, RegistryCounterView, Request


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.smoke_config("olmo-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def traced_run(tiny):
    """One collocated virtual-clock run with tracing on: 2 offline
    requests filling bubbles, 6 online Poisson-ish arrivals."""
    cfg, params = tiny
    engine = InferenceEngine(cfg, params, max_slots=2, max_seq=96)
    assert engine.obs.tracer.enabled, "engines trace by default"
    for _ in range(2):
        engine.core.submit(
            np.arange(8), SamplingParams(max_new_tokens=32),
            priority=Priority.OFFLINE, arrival_time=0.0,
        )
    reqs = [
        Request(prompt=np.arange(4), max_new_tokens=3,
                arrival_time=0.03 * i, online=True)
        for i in range(6)
    ]
    rt = SpecInFRuntime(
        train_step=lambda s, b: (s, {"loss": 0.0}), train_state=None,
        batch_iter=itertools.repeat({}),
        profile=dp_profile("tiny", compute_s=0.03, comm_s=0.04),
        engine=engine, online_requests=reqs,
        cfg=SpecInFConfig(busy_hold_ms=5.0), decode_microstep_s=0.002,
    )
    metrics = rt.run(num_iterations=12)
    return engine, metrics


# ----------------------------------------------------------------------
# streaming histogram
# ----------------------------------------------------------------------
def test_streaming_histogram_exact_regime_is_bit_for_bit():
    rng = np.random.default_rng(0)
    xs = [float(x) for x in rng.exponential(0.05, 500)]
    h = StreamingHistogram("t")
    for x in xs:
        h.record(x)
    assert h.exact
    assert h.values() == xs, "the historical unbounded-list view"
    for q in (50, 90, 95, 99):
        assert h.percentile(q) == float(np.percentile(xs, q))
    assert h.count == 500
    assert h.min == min(xs) and h.max == max(xs)
    assert h.mean() == pytest.approx(np.mean(xs))


def test_streaming_histogram_collapse_bounds_memory():
    h = StreamingHistogram("t", exact_cap=64, num_bins=32)
    rng = np.random.default_rng(1)
    xs = rng.uniform(0.0, 1.0, 1000)
    for x in xs:
        h.record(float(x))
    assert not h.exact, "past the cap the raw samples are gone"
    with pytest.raises(RuntimeError):
        h.values()
    # exact aggregates survive the collapse; percentiles stay within a
    # few bin widths of the true value
    assert h.count == 1000
    assert h.sum == pytest.approx(float(xs.sum()))
    assert h.min == float(xs.min()) and h.max == float(xs.max())
    for q in (50, 95):
        assert abs(h.percentile(q) - float(np.percentile(xs, q))) < 0.1


def test_streaming_histogram_empty_is_nan():
    h = StreamingHistogram("t")
    assert np.isnan(h.percentile(95))
    assert np.isnan(h.mean())


# ----------------------------------------------------------------------
# registry + thin counter views
# ----------------------------------------------------------------------
def test_registry_get_or_create_and_type_conflict():
    r = MetricsRegistry()
    c = r.counter("a")
    assert r.counter("a") is c, "get-or-create returns the same cell"
    with pytest.raises(TypeError):
        r.gauge("a")
    g = r.gauge("g")
    g.set(3)
    g.set(1)
    assert (g.value, g.min, g.max, g.samples) == (1.0, 1.0, 3.0, 2)
    snap = r.snapshot()
    assert snap["a"]["type"] == "counter"
    assert snap["g"] == {"type": "gauge", "value": 1.0, "samples": 2,
                         "min": 1.0, "max": 3.0}


def test_counter_view_shares_the_registry_cell():
    class Holder:
        steps = RegistryCounterView("engine/steps_executed")

        def __init__(self):
            self.obs = Observability(tracing=False)

    h = Holder()
    cell = h.obs.metrics.counter("engine/steps_executed")
    assert h.steps == 0 and cell.value == 0
    h.steps += 5
    assert cell.value == 5, "attribute writes hit the registry cell"
    cell.inc(2)
    assert h.steps == 7, "registry writes are visible through the attribute"


def test_engine_counter_attrs_are_pinned_views():
    import inspect

    for attr in ("d2h_transfers", "steps_executed", "generated_tokens_total",
                 "prefill_prompt_tokens", "spec_rounds", "spec_accepted"):
        view = inspect.getattr_static(InferenceEngine, attr)
        assert isinstance(view, RegistryCounterView)
        assert STABLE_NAMES.get(view.name) == "counter"


# ----------------------------------------------------------------------
# tracer mechanics
# ----------------------------------------------------------------------
def test_tracer_bounds_memory_and_counts_drops():
    tr = StepTracer(max_events=10)
    for i in range(25):
        tr.instant("tick", float(i))
    assert len(tr.events) == 10 and tr.dropped == 15
    off = StepTracer(enabled=False)
    off.quantum(0.0, 1.0)
    assert off.events == [] and off.dropped == 0


def test_restamp_arrival_rewrites_only_the_waiting_edge():
    tr = StepTracer()
    tr.transition(7, None, "waiting", 123.4, priority="offline")
    tr.transition(7, "waiting", "running", 123.5)
    tr.restamp_arrival(7, 0.0)
    assert tr.events[0]["t"] == 0.0
    assert tr.events[1]["t"] == 123.5


# ----------------------------------------------------------------------
# schema validator
# ----------------------------------------------------------------------
def test_schema_validator_accepts_tracer_output_and_rejects_junk():
    tr = StepTracer()
    tr.quantum(0.0, 0.1, k=2)
    tr.transition(1, None, "waiting", 0.0, priority="online")
    tr.span("decode", "slot0", 0.0, 0.1, tokens=2)
    tr.instant("first_token", 0.1, request_id=1)
    assert validate_events(tr.events) == []

    bad = [
        {"type": "nope", "seq": 0},
        {"type": "quantum", "t0": 0.0, "seq": 1, "args": {}},  # no t1
        {"type": "transition", "request_id": 1, "frm": None, "to": "zombie",
         "t": 0.0, "seq": 2, "priority": None},
        {"type": "span", "name": "s", "track": "t", "t0": 1.0, "t1": 0.5,
         "seq": 3, "args": {}},  # t1 < t0
    ]
    errs = validate_events(bad)
    assert len(errs) >= 4

    dup_seq = [
        {"type": "quantum", "t0": 0.0, "t1": 1.0, "seq": 5, "args": {}},
        {"type": "quantum", "t0": 1.0, "t1": 2.0, "seq": 5, "args": {}},
    ]
    assert any("not increasing" in e for e in validate_events(dup_seq))


# ----------------------------------------------------------------------
# attribution unit cases
# ----------------------------------------------------------------------
def test_attribution_monolithic_first_token_splits_running():
    tr = StepTracer()
    tr.transition(1, None, "waiting", 0.0, priority="online")
    tr.transition(1, "waiting", "running", 1.0)
    tr.instant("first_token", 1.25, request_id=1)
    tr.transition(1, "running", "finished_stopped", 2.0)
    ra = attribute(tr.events)[1]
    assert ra.queueing == pytest.approx(1.0)
    assert ra.prefill == pytest.approx(0.25)
    assert ra.decode == pytest.approx(0.75)
    assert ra.ttft_s == pytest.approx(1.25)
    assert ra.total == pytest.approx(ra.latency_s)
    assert ra.finish_state == "finished_stopped"


def test_attribution_charges_preempted_time():
    tr = StepTracer()
    tr.transition(2, None, "waiting", 0.0, priority="offline")
    tr.transition(2, "waiting", "running", 1.0)
    tr.transition(2, "running", "preempted", 2.0)
    tr.transition(2, "preempted", "running", 3.0)
    tr.transition(2, "running", "finished_length", 4.0)
    ra = attribute(tr.events)[2]
    assert ra.queueing == pytest.approx(1.0)
    assert ra.decode == pytest.approx(2.0)
    assert ra.preempted == pytest.approx(1.0)
    assert ra.preemptions == 1
    assert ra.total == pytest.approx(ra.latency_s)


# ----------------------------------------------------------------------
# collocated virtual-clock run: timebase integrity + derived views
# ----------------------------------------------------------------------
def test_collocated_trace_stays_on_the_virtual_timebase(traced_run):
    """Regression: no wall-clock (``time.monotonic``) timestamp may leak
    into a collocated trace.  Wall time since boot is orders of magnitude
    beyond the sub-second virtual horizon, so a single leaked stamp blows
    the bound."""
    engine, metrics = traced_run
    tr = engine.obs.tracer
    assert tr.events and tr.dropped == 0
    assert validate_events(tr.events) == []
    # bubble spans may extend one profiled bubble past the final quantum
    horizon = metrics.virtual_time_s + 0.05 + 1e-9
    for ev in tr.events:
        for key in ("t", "t0", "t1"):
            if key in ev:
                assert 0.0 <= ev[key] <= horizon, (ev["type"], key, ev[key])


def test_collocated_attribution_sums_to_latency(traced_run):
    engine, metrics = traced_run
    att = engine.obs.tracer.attribution()
    finished = [ra for ra in att.values() if ra.finish_time is not None]
    assert finished
    for ra in finished:
        assert abs(ra.total - ra.latency_s) < 1e-9, ra.as_dict()
    online = [ra for ra in finished if ra.priority == "online"]
    assert len(online) == metrics.online_served >= 2
    # the trace's TTFT view and the registry histogram are two projections
    # of the same stamped events
    from_trace = sorted(ra.ttft_s for ra in online)
    from_registry = sorted(metrics.online_ttft_s)
    assert from_trace == pytest.approx(from_registry, abs=1e-12)


def test_filling_metrics_are_registry_views(traced_run):
    engine, metrics = traced_run
    m = engine.obs.metrics
    assert metrics.online_latencies_s == \
        m.histogram("core/online_latency_s").values()
    assert metrics.online_ttft_s == m.histogram("core/online_ttft_s").values()
    assert metrics.online_served == m.counter("core/finished/online").value
    assert metrics.preemptions == m.counter("core/preemptions").value
    # bit-for-bit with the historical list-based percentiles
    assert metrics.p95_latency_s() == \
        float(np.percentile(metrics.online_latencies_s, 95))
    assert metrics.p95_ttft_s() == \
        float(np.percentile(metrics.online_ttft_s, 95))
    # per-quantum gauges were sampled
    assert m.gauge("engine/slots_active").samples > 0
    assert m.gauge("core/queue_depth/online").samples > 0
    assert m.gauge("engine/pool/pages_in_use").samples > 0


def test_trace_export_roundtrip(traced_run, tmp_path):
    engine, _ = traced_run
    tr = engine.obs.tracer
    p = tmp_path / "trace.jsonl"
    tr.write_jsonl(str(p), metrics=engine.obs.metrics.snapshot())
    n, errors = validate_jsonl(str(p))
    assert errors == []
    assert n == len(tr.events)
    head = json.loads(p.read_text().splitlines()[0])
    assert head["version"] == 1 and "metrics" in head

    cp = tmp_path / "trace.chrome.json"
    tr.write_chrome(str(cp))
    doc = json.loads(cp.read_text())
    threads = {e["args"]["name"] for e in doc["traceEvents"]
               if e.get("name") == "thread_name"}
    assert {"control", "train"} <= threads
    assert any(t.startswith("slot") for t in threads), \
        "per-slot tracks must exist"
    assert any(e.get("name") == "quantum" for e in doc["traceEvents"])
    assert any(e.get("name") == "train_compute" for e in doc["traceEvents"])
