"""Unit + property tests for Algorithm 1 (Adaptive Kernel Scheduling) and
the Bubble Monitor — the paper's §3.3 invariants."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs.base import SpecInFConfig
from repro.core import AdaptiveKernelScheduler, BubbleMonitor, Phase, Status


CFG = SpecInFConfig(alpha=2, beta=3, gamma=2.0, lower_limit=8.0,
                    upper_limit=64.0, token_seed=1.0)


# ---------------------------------------------------------------------------
# Algorithm 1 phase semantics (paper listing, lines 9-15)
# ---------------------------------------------------------------------------


def test_conservative_phase_blocks_everything():
    s = AdaptiveKernelScheduler(CFG)
    for zc in range(CFG.alpha):
        d = s.update(zc)
        assert d.phase is Phase.CONSERVATIVE
        assert d.tokens == 0.0
        assert d.status is Status.BUSY


def test_incremental_phase_grows_to_lower_limit():
    s = AdaptiveKernelScheduler(CFG)
    seen = []
    for _ in range(10):
        d = s.update(CFG.alpha)  # alpha <= Z_c <= beta
        assert d.phase is Phase.INCREMENTAL
        assert d.status is Status.BUSY
        seen.append(d.tokens)
    assert seen == sorted(seen), "token grant must grow monotonically"
    assert seen[-1] == CFG.lower_limit
    assert all(t <= CFG.lower_limit for t in seen)


def test_stable_phase_grows_to_upper_limit_and_signals_idle():
    s = AdaptiveKernelScheduler(CFG)
    last = 0.0
    for _ in range(12):
        d = s.update(CFG.beta + 5)
        assert d.phase is Phase.STABLE
        assert d.status is Status.IDLE
        assert d.tokens >= last
        last = d.tokens
    assert last == CFG.upper_limit


def test_conservative_resets_token_growth():
    s = AdaptiveKernelScheduler(CFG)
    for _ in range(10):
        s.update(CFG.beta + 1)
    assert s.update(0).tokens == 0.0
    # growth restarts from seed, not from the old high-water mark
    d = s.update(CFG.beta + 1)
    assert d.tokens == CFG.token_seed * CFG.gamma


def test_tokens_divided_among_instances():
    s1 = AdaptiveKernelScheduler(CFG, num_instances=1)
    s4 = AdaptiveKernelScheduler(CFG, num_instances=4)
    for _ in range(10):
        d1 = s1.update(CFG.beta + 1)
        d4 = s4.update(CFG.beta + 1)
    assert d4.tokens == pytest.approx(d1.tokens / 4)


@given(
    zcs=st.lists(st.integers(min_value=0, max_value=50), min_size=1, max_size=200),
    alpha=st.integers(min_value=1, max_value=5),
    beta_extra=st.integers(min_value=0, max_value=5),
    m=st.integers(min_value=1, max_value=8),
)
@settings(max_examples=200, deadline=None)
def test_algorithm1_invariants(zcs, alpha, beta_extra, m):
    """Properties that must hold for ANY zero-count trace:
    * tokens == 0 and busy whenever Z_c < alpha
    * tokens bounded by UL/m always, by LL/m while Z_c <= beta
    * status idle iff Z_c > beta
    * tokens never negative
    """
    cfg = SpecInFConfig(alpha=alpha, beta=alpha + beta_extra)
    s = AdaptiveKernelScheduler(cfg, num_instances=m)
    for zc in zcs:
        d = s.update(zc)
        assert d.tokens >= 0
        assert d.tokens <= cfg.upper_limit / m + 1e-9
        if zc < alpha:
            assert d.tokens == 0 and d.status is Status.BUSY
        elif zc <= cfg.beta:
            assert d.tokens <= cfg.lower_limit / m + 1e-9
            assert d.status is Status.BUSY
        else:
            assert d.status is Status.IDLE


def test_alpha_beta_validation():
    with pytest.raises(AssertionError):
        AdaptiveKernelScheduler(SpecInFConfig(alpha=5, beta=2))


def test_alpha_equals_beta_boundary():
    """alpha == beta collapses the incremental band to a single zero-count:
    Z_c < alpha conservative, Z_c == alpha incremental (busy, LL-capped),
    Z_c > alpha stable (idle)."""
    cfg = SpecInFConfig(alpha=3, beta=3)
    s = AdaptiveKernelScheduler(cfg)
    assert s.update(2).phase is Phase.CONSERVATIVE
    d = s.update(3)
    assert d.phase is Phase.INCREMENTAL and d.status is Status.BUSY
    assert 0 < d.tokens <= cfg.lower_limit
    d = s.update(4)
    assert d.phase is Phase.STABLE and d.status is Status.IDLE
    # dropping back to the boundary re-enters incremental and re-applies LL
    for _ in range(10):
        d = s.update(3)
    assert d.phase is Phase.INCREMENTAL and d.tokens == cfg.lower_limit


@pytest.mark.parametrize("m", [2, 3, 5, 8])
def test_multi_instance_split_preserves_pool(m):
    """The per-instance grant is exactly the shared pool divided by the
    instance count — across both capped phases, for any m."""
    s1 = AdaptiveKernelScheduler(CFG, num_instances=1)
    sm = AdaptiveKernelScheduler(CFG, num_instances=m)
    for zc in [CFG.alpha] * 6 + [CFG.beta + 1] * 8:
        d1 = s1.update(zc)
        dm = sm.update(zc)
        assert dm.tokens == pytest.approx(d1.tokens / m)
        assert dm.tokens * m <= CFG.upper_limit + 1e-9


def test_regrowth_from_token_seed_after_reset():
    """After a conservative zeroing (via Z_c < alpha or an explicit
    reset()), growth restarts from token_seed — never from the previous
    high-water mark, and never pinned at zero (the paper-listing bug the
    seed deviation fixes)."""
    expected_ramp = []
    t = CFG.token_seed
    while t * CFG.gamma < CFG.upper_limit:
        t *= CFG.gamma
        expected_ramp.append(t)
    expected_ramp.append(CFG.upper_limit)

    s = AdaptiveKernelScheduler(CFG)
    for _ in range(8):
        s.update(CFG.beta + 1)  # saturate at UL
    s.update(0)  # conservative cut
    ramp = [s.update(CFG.beta + 1).tokens for _ in range(len(expected_ramp))]
    assert ramp == pytest.approx(expected_ramp)

    s.reset()
    assert s.last_decision.tokens == 0.0
    assert s.last_decision.phase is Phase.CONSERVATIVE
    ramp = [s.update(CFG.beta + 1).tokens for _ in range(len(expected_ramp))]
    assert ramp == pytest.approx(expected_ramp)


# ---------------------------------------------------------------------------
# Bubble Monitor: sliding-window zero-run statistic
# ---------------------------------------------------------------------------


def test_monitor_zero_run_counting():
    m = BubbleMonitor(CFG)
    assert m.observe(5) == 0
    assert m.observe(0) == 1
    assert m.observe(0) == 2
    assert m.observe(3) == 0  # any activity resets the run
    assert m.observe(0) == 1


@given(trace=st.lists(st.integers(min_value=0, max_value=3), max_size=300))
@settings(max_examples=100, deadline=None)
def test_monitor_matches_reference_semantics(trace):
    m = BubbleMonitor(CFG)
    run = 0
    for count in trace:
        run = run + 1 if count == 0 else 0
        assert m.observe(count) == run


def test_monitor_utilization():
    m = BubbleMonitor(CFG)
    for c in [1, 0, 1, 0]:
        m.observe(c)
    assert m.utilization() == pytest.approx(0.5)


def test_end_to_end_bubble_to_tokens():
    """A communication window (zero activity) ramps tokens; compute
    (non-zero) slams them shut — the paper's core control loop."""
    mon = BubbleMonitor(CFG)
    sched = AdaptiveKernelScheduler(CFG)
    # 1. compute phase: no grants
    for _ in range(5):
        d = sched.update(mon.observe(7))
    assert d.tokens == 0 and d.status is Status.BUSY
    # 2. bubble: grants ramp up, eventually idle
    grants = [sched.update(mon.observe(0)) for _ in range(10)]
    assert grants[-1].status is Status.IDLE
    assert grants[-1].tokens == CFG.upper_limit
    # 3. training resumes: immediate conservative cut
    d = sched.update(mon.observe(9))
    assert d.tokens == 0 and d.status is Status.BUSY
