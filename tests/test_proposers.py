"""Model-free proposers and adaptive routing (DESIGN.md §10).

Pins the behaviors the subsystem's contract names: prompt-lookup proposals
are a pure deterministic function of the histories (with ``None`` on
no-match so the engine can fall back), the static-suffix table is built
first-occurrence-wins, the router's per-slot acceptance EWMA converges away
from a proposer that stops delivering (and prices host rounds cheaper than
draft-model rounds), and an engine driven end-to-end through the routed
n-gram path emits the byte-identical greedy stream as plain decode while
the ``spec/proposer/*`` metrics flow.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SpecDecodeConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request
from repro.spec.proposers import (
    NgramProposer,
    ProposerRouter,
    StaticSuffixProposer,
)
from repro.spec.proposers.base import ProposeContext
from repro.spec.tree import branching_tree, linear_chain


def _ctx(hists, gamma, width=1):
    return ProposeContext(
        histories=hists,
        active=np.array([len(h) > 0 for h in hists], bool),
        gamma=gamma,
        width=width,
    )


# ---------------------------------------------------------------------------
# NgramProposer
# ---------------------------------------------------------------------------

def test_ngram_is_deterministic_and_matches_history():
    p = NgramProposer(order=3)
    hist = [1, 2, 3, 4, 5, 1, 2, 3, 4, 5]  # trailing [3,4,5] recurs at 2..4
    t1 = p.propose(_ctx([hist], gamma=3))
    t2 = p.propose(_ctx([hist], gamma=3))
    assert t1 is not None
    assert t1.parents == linear_chain(3) == t2.parents
    np.testing.assert_array_equal(t1.tail, t2.tail)
    np.testing.assert_array_equal(t1.matched, [True])
    # the earlier occurrence of [3,4,5] ends at index 4; what followed it
    # is the proposal
    np.testing.assert_array_equal(t1.tail[0], [1, 2, 3])


def test_ngram_no_match_returns_none():
    p = NgramProposer(order=3)
    assert p.propose(_ctx([[1, 2, 3, 4, 5, 6]], gamma=2)) is None
    assert p.propose(_ctx([[1, 2]], gamma=2)) is None  # shorter than order
    # inactive slots never match even with a repetitive history
    ctx = ProposeContext(
        histories=[[1, 2, 3, 1, 2, 3]], active=np.array([False]), gamma=2,
    )
    assert p.propose(ctx) is None


def test_ngram_width_proposes_distinct_branches():
    p = NgramProposer(order=3)
    # trailing [7,8,9] recurs twice with different continuations; most
    # recent occurrence proposes branch 0
    hist = [7, 8, 9, 1, 7, 8, 9, 2, 7, 8, 9]
    t = p.propose(_ctx([hist], gamma=1, width=2))
    assert t is not None
    assert t.parents == branching_tree(2, 1)
    np.testing.assert_array_equal(t.tail[0], [2, 1])


# ---------------------------------------------------------------------------
# StaticSuffixProposer
# ---------------------------------------------------------------------------

def test_suffix_table_completes_known_prefixes():
    p = StaticSuffixProposer([[1, 2, 3, 4, 5]], order=2)
    t = p.propose(_ctx([[9, 9, 1, 2]], gamma=3))
    assert t is not None
    np.testing.assert_array_equal(t.tail[0], [3, 4, 5])
    assert p.propose(_ctx([[9, 9, 9, 9]], gamma=3)) is None


def test_suffix_table_first_occurrence_wins():
    p = StaticSuffixProposer([[1, 2, 9], [1, 2, 3]], order=2)
    t = p.propose(_ctx([[1, 2]], gamma=1))
    np.testing.assert_array_equal(t.tail[0], [9])


# ---------------------------------------------------------------------------
# ProposerRouter
# ---------------------------------------------------------------------------

def test_router_prices_host_rounds_cheaper_than_draft():
    r = ProposerRouter(["draft", "ngram"], device_names=("draft",),
                       draft_cost_ratio=0.25)
    assert r.round_cost("ngram", 4) == 1.0
    assert r.round_cost("draft", 4) == 1.0 + 5 * 0.25
    # equal (optimistic) acceptance -> the model-free proposer wins
    assert r.pick(0, gamma=4) == "ngram"


def test_router_ewma_converges_away_from_a_dead_proposer():
    r = ProposerRouter(["draft", "ngram"], device_names=("draft",),
                       ewma=0.5, init_acceptance=0.7)
    assert r.pick(0, gamma=4) == "ngram"
    picks = []
    for _ in range(6):
        r.observe(0, "ngram", accepted=0, proposed=4)
        picks.append(r.pick(0, gamma=4))
    assert picks[-1] == "draft", "router never abandoned the dead proposer"
    assert r.switches >= 1
    assert r.acceptance(0, "ngram") < 0.2 < r.acceptance(0, "draft")
    # zero-proposal rounds are not evidence (nothing was verified)
    before = r.acceptance(0, "draft")
    r.observe(0, "draft", accepted=0, proposed=0)
    assert r.acceptance(0, "draft") == before


def test_router_reset_slot_restores_optimism():
    r = ProposerRouter(["ngram"], device_names=(), init_acceptance=0.7)
    for _ in range(4):
        r.observe(2, "ngram", accepted=0, proposed=4)
    assert r.acceptance(2, "ngram") < 0.7
    r.reset_slot(2)
    assert r.acceptance(2, "ngram") == 0.7


def test_router_pick_majority_routes_one_choice_for_the_batch():
    r = ProposerRouter(["draft", "ngram"], device_names=("draft",))
    # slot 0 loves ngram, slot 1 hates it; majority is by summed score
    for _ in range(6):
        r.observe(0, "ngram", accepted=4, proposed=4)
        r.observe(1, "ngram", accepted=0, proposed=4)
    assert r.pick_majority([0, 1], gamma=4) in ("draft", "ngram")
    # an empty slot list still routes (registration order)
    assert r.pick_majority([], gamma=4) == "draft"


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))


def test_auto_stays_inert_on_plain_engines():
    """``proposer="auto"`` must not change an engine without a draft
    pairing: no proposers, no router, no host spec — plain engines behave
    exactly as before the subsystem existed."""
    eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=32)
    assert not eng.host_spec_enabled
    assert eng.proposer_router is None
    assert eng.route_proposer(2) is None


@pytest.mark.parametrize("paged", [False, True])
def test_engine_ngram_stream_matches_plain_greedy(paged):
    """Host-only speculation end to end: the routed n-gram path (tree
    verify, rollback, history absorption) emits the byte-identical stream
    as plain fused decode on prefix-heavy traffic, and the proposer
    metrics family records the rounds."""
    kw = {"kv_page_size": 8 if paged else 0}
    prompt = np.tile([3, 5, 7, 9, 11], 6)
    plain = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=128,
                            compute_dtype=jnp.float32, **kw)
    spec = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=128,
                           compute_dtype=jnp.float32,
                           spec=SpecDecodeConfig(proposer="ngram"), **kw)
    assert spec.host_spec_enabled and not spec.spec_enabled
    rp = [Request(prompt=prompt, max_new_tokens=12) for _ in range(2)]
    rs = [Request(prompt=prompt, max_new_tokens=12) for _ in range(2)]
    for r in rp:
        assert plain.add_request(r)
    for r in rs:
        assert spec.add_request(r)
    while plain.num_active:
        plain.decode_loop(4)
    guard = 0
    while spec.num_active:
        spec._drive_proposed_loop(2, 3)  # routes (ngram is the only one)
        guard += 1
        assert guard < 64
    for a, b in zip(rp, rs):
        assert b.generated == a.generated
        assert len(b.generated) == 12
    m = spec.obs.metrics
    rounds = m.counter("spec/proposer/rounds/ngram").value
    fallbacks = m.counter("spec/proposer/no_match_fallbacks").value
    assert rounds + fallbacks > 0
    assert rounds > 0, "prompt-lookup never matched on periodic traffic"
    assert m.counter("spec/proposer/proposed/ngram").value > 0
    assert (
        m.counter("spec/proposer/accepted/ngram").value
        <= m.counter("spec/proposer/proposed/ngram").value
    )
