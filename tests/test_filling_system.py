"""End-to-end behaviour of the paper's system over REAL JAX compute:
the SpecInF runtime collocating a real training loop with a real
continuous-batching inference engine, plus the beyond-paper fused step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SpecInFConfig, TrainConfig
from repro.core import SpecInFRuntime, make_collocated_step, pick_bucket
from repro.core.profiles import dp_profile
from repro.data.pipeline import SyntheticDataset
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, make_schedule
from repro.serving.engine import InferenceEngine, Request


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.smoke_config("olmo-1b")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    return cfg, params


def _make_train(cfg, params):
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=2, total_steps=50)
    sched = make_schedule(tcfg)
    state = {"params": params, "opt": adamw_init(params)}

    @jax.jit
    def step(state, batch):
        def loss_fn(p):
            loss, m = T.lm_loss(cfg, p, batch["inputs"], batch["labels"])
            return loss, m

        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(state["params"])
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        new_p, new_opt = adamw_update(
            g, state["opt"], state["params"], lr=sched(state["opt"]["step"]),
            cfg=tcfg,
        )
        return {"params": new_p, "opt": new_opt}, {"loss": loss}

    ds = SyntheticDataset(cfg=cfg, seq_len=32, global_batch=4)

    def batches():
        while True:
            b = ds.next_batch()
            yield {"inputs": jnp.asarray(b["inputs"]),
                   "labels": jnp.asarray(b["labels"])}

    return step, state, batches()


def test_runtime_trains_and_fills_offline(tiny):
    cfg, params = tiny
    step, state, batches = _make_train(cfg, params)
    engine = InferenceEngine(cfg, params, max_slots=2, max_seq=48)
    for _ in range(2):
        engine.add_request(Request(prompt=np.arange(8), max_new_tokens=1000))
    profile = dp_profile("tiny", compute_s=0.05, comm_s=0.03)
    rt = SpecInFRuntime(
        train_step=step, train_state=state, batch_iter=batches,
        profile=profile, engine=engine, cfg=SpecInFConfig(),
        decode_microstep_s=0.004,
    )
    metrics = rt.run(num_iterations=8)
    assert metrics.train_iterations == 8
    assert metrics.offline_microsteps > 0, "bubbles must admit offline work"
    assert metrics.offline_tokens_generated > 0
    # training made progress (loss finite and generally decreasing)
    assert np.isfinite(metrics.train_losses).all()
    assert metrics.train_losses[-1] < metrics.train_losses[0] + 0.1
    # Algorithm 1 visited all three phases
    assert set(metrics.phase_counts) >= {"conservative", "stable"}


def test_runtime_serves_online_within_bubbles(tiny):
    cfg, params = tiny
    step, state, batches = _make_train(cfg, params)
    engine = InferenceEngine(cfg, params, max_slots=2, max_seq=32)
    reqs = [
        Request(prompt=np.arange(4), max_new_tokens=3, arrival_time=0.02 * i,
                online=True)
        for i in range(6)
    ]
    profile = dp_profile("tiny", compute_s=0.04, comm_s=0.05)
    rt = SpecInFRuntime(
        train_step=step, train_state=state, batch_iter=batches,
        profile=profile, engine=engine, online_requests=reqs,
        cfg=SpecInFConfig(busy_hold_ms=5.0), decode_microstep_s=0.002,
    )
    metrics = rt.run(num_iterations=14)
    assert metrics.online_served >= 3
    assert np.isfinite(metrics.p95_latency_s())
    # TTFT is recorded per served online request (arrival -> first token)
    # and can never exceed the end-to-end latency it is a prefix of
    assert len(metrics.online_ttft_s) >= metrics.online_served
    assert np.isfinite(metrics.p95_ttft_s())
    assert all(t >= 0.0 for t in metrics.online_ttft_s)


def test_preempted_legacy_offline_resumes_on_virtual_clock(tiny):
    """Regression: a request admitted via the legacy shim BEFORE the
    runtime exists is stamped on the wall clock; after an online arrival
    preempts it, re-admission is gated on the virtual clock — the runtime
    must restamp RUNNING slots to the virtual epoch or the offline request
    starves forever."""
    import itertools

    cfg, params = tiny
    engine = InferenceEngine(cfg, params, max_slots=1, max_seq=64)
    off = Request(prompt=np.arange(8), max_new_tokens=12)
    assert engine.add_request(off)  # wall-clock arrival stamp
    rt = SpecInFRuntime(
        train_step=lambda s, b: (s, {"loss": 0.0}), train_state=None,
        batch_iter=itertools.repeat({}),
        profile=dp_profile("tiny", compute_s=0.03, comm_s=0.06),
        engine=engine,
        online_requests=[Request(prompt=np.arange(4), max_new_tokens=2,
                                 arrival_time=0.01, online=True)],
        cfg=SpecInFConfig(busy_hold_ms=5.0), decode_microstep_s=0.002,
    )
    metrics = rt.run(num_iterations=25)
    assert metrics.online_served == 1
    assert metrics.preemptions >= 1, "online must preempt the lone slot"
    cr_off = engine.core.requests[off.request_id]
    assert cr_off.state.finished, "preempted offline request starved"
    assert len(cr_off.output_tokens) == 12
    assert not engine.core.has_unfinished


def test_fused_collocated_step_preserves_training(tiny):
    """Beyond-paper fused program: train result must be bit-identical to the
    unfused train step, and the decode chain must advance the cache."""
    cfg, params = tiny
    tcfg = TrainConfig(learning_rate=1e-2)

    def train_step(state, batch):
        def loss_fn(p):
            loss, _ = T.lm_loss(cfg, p, batch["inputs"], batch["labels"])
            return loss

        loss, g = jax.value_and_grad(loss_fn)(state["params"])
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        new_p, new_opt = adamw_update(
            g, state["opt"], state["params"], lr=0.01, cfg=tcfg
        )
        return {"params": new_p, "opt": new_opt}, {"loss": loss}

    def decode_fn(p, tokens, cache):
        return T.decode_step(cfg, p, tokens, cache)

    fused = make_collocated_step(train_step, decode_fn, k_buckets=(0, 2))

    ds = SyntheticDataset(cfg=cfg, seq_len=32, global_batch=4)
    b = ds.next_batch()
    batch = {"inputs": jnp.asarray(b["inputs"]), "labels": jnp.asarray(b["labels"])}
    state = {"params": params, "opt": adamw_init(params)}

    cache = T.init_cache(cfg, 2, 32)
    tokens = jnp.array([1, 2], jnp.int32)

    ref_state, ref_m = jax.jit(train_step)(
        jax.tree.map(jnp.copy, state), batch
    )
    new_state, m, toks0, cache0 = fused[0](
        jax.tree.map(jnp.copy, state), batch, params, tokens,
        jax.tree.map(jnp.copy, cache),  # cache arg is donated by the jit
    )
    new_state2, m2, toks2, cache2 = fused[2](
        jax.tree.map(jnp.copy, state), batch, params, tokens,
        jax.tree.map(jnp.copy, cache),
    )
    # training result identical regardless of collocated decode volume
    np.testing.assert_allclose(float(ref_m["loss"]), float(m2["loss"]), rtol=1e-6)
    for a, b2 in zip(
        jax.tree.leaves(new_state["params"]), jax.tree.leaves(new_state2["params"])
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2), atol=1e-6)
    # k=0 leaves tokens untouched; k=2 advanced the cache index by 2
    assert int(cache0["index"]) == 0
    assert int(cache2["index"]) == 2
    assert toks2.shape == (2,)


def test_pick_bucket_respects_token_grant():
    assert pick_bucket(0.0, 1.0) == 0
    assert pick_bucket(3.0, 1.0) == 2
    assert pick_bucket(8.0, 1.0) == 8
    assert pick_bucket(7.9, 1.0) == 4
    assert pick_bucket(100.0, 12.0) == 8


def test_engine_continuous_batching(tiny):
    cfg, params = tiny
    engine = InferenceEngine(cfg, params, max_slots=2, max_seq=32)
    r1 = Request(prompt=np.arange(4), max_new_tokens=2)
    r2 = Request(prompt=np.arange(6), max_new_tokens=5)
    assert engine.add_request(r1) and engine.add_request(r2)
    assert engine.num_active == 2
    done = []
    for _ in range(8):
        done += engine.decode_microstep()
        if engine.num_active == 0:
            break
    done_ids = {r.request_id for r in done}
    assert r1.request_id in done_ids and r2.request_id in done_ids
    assert len(r1.generated) >= 2 and len(r2.generated) >= 5
    # freed slots accept new work (slot reuse)
    r3 = Request(prompt=np.arange(3), max_new_tokens=1)
    assert engine.add_request(r3)
    assert engine.num_active == 1
