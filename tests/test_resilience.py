"""Failure containment and graceful degradation (DESIGN.md §9): seeded
fault-injector determinism, NaN quarantine with byte-identical recovery,
allocator-fault containment, pool-exhaustion recovery (property test),
queue deadlines, degenerate grants, revocable grants with exact
partial-quantum accounting, the overload ladder's hysteresis, and the
runtime's bounded early-resume yield."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SpecDecodeConfig, SpecInFConfig, draft_config
from repro.models import transformer as T
from repro.resilience import (
    FaultInjector,
    FaultSpec,
    LadderConfig,
    LadderStage,
    OverloadLadder,
)
from repro.serving.core import (
    Grant,
    Priority,
    RequestState,
    RevocationSignal,
    SamplingParams,
)
from repro.serving.engine import InferenceEngine
from repro.serving.kv_pool import PageAllocError, PagePool

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
DCFG = draft_config(CFG)
DPARAMS = T.init_params(DCFG, jax.random.PRNGKey(1))


def _engine(paged=True, spec=False, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 128)
    kw.setdefault("kv_page_size", None if paged else 0)
    if spec:
        kw.update(draft_cfg=DCFG, draft_params=DPARAMS,
                  spec=SpecDecodeConfig(mode="greedy"))
    return InferenceEngine(CFG, PARAMS, **kw)


def _drain(core, limit=300):
    n = 0
    while core.has_unfinished:
        core.step()
        n += 1
        assert n < limit, "core.step() made no progress"


# ---------------------------------------------------------------------------
# FaultInjector unit behavior
# ---------------------------------------------------------------------------


def test_fault_spec_rejects_unknown_point():
    with pytest.raises(ValueError):
        FaultSpec("engine/made_up_point")


def test_injector_deterministic_and_point_independent():
    specs = (
        FaultSpec("engine/nan_logits", probability=0.3),
        FaultSpec("pool/alloc_fail", probability=0.3),
    )
    a = FaultInjector(seed=11, specs=specs)
    b = FaultInjector(seed=11, specs=specs)
    # interleave consultations differently: per-point streams must not shift
    pat_a = [a.should_fire("engine/nan_logits") for _ in range(20)]
    [a.should_fire("pool/alloc_fail") for _ in range(5)]
    [b.should_fire("pool/alloc_fail") for _ in range(5)]
    pat_b = [b.should_fire("engine/nan_logits") for _ in range(20)]
    assert pat_a == pat_b
    assert FaultInjector(seed=12, specs=specs) is not None  # other seeds fine
    c = FaultInjector(seed=12, specs=specs)
    assert [c.should_fire("engine/nan_logits") for _ in range(20)] != pat_a


def test_injector_after_and_max_fires_do_not_shift_stream():
    spec0 = (FaultSpec("engine/nan_logits", probability=0.5),)
    spec1 = (FaultSpec("engine/nan_logits", probability=0.5, after=3,
                       max_fires=2),)
    base = FaultInjector(seed=5, specs=spec0)
    capped = FaultInjector(seed=5, specs=spec1)
    raw = [base.should_fire("engine/nan_logits") for _ in range(30)]
    got = [capped.should_fire("engine/nan_logits") for _ in range(30)]
    assert capped.total_fires <= 2
    # capped fires are a subset of the raw stream's hits, never new ones
    assert all(not g or r for g, r in zip(got, raw))
    assert not any(got[:3])  # warmup consultations never fire
    # unarmed points are inert
    assert not base.should_fire("core/step_overrun")


# ---------------------------------------------------------------------------
# NaN quarantine and allocator-fault containment
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_nan_quarantine_recovers_byte_identical(paged):
    """A poisoned-KV fused dispatch must quarantine only the poisoned slot
    and requeue it; the retried stream is byte-identical to fault-free."""

    def run(inj):
        core = _engine(paged=paged, fault_injector=inj).core
        core.fault_backoff_s = 0.0  # wall clock here; gate tested separately
        reqs = [core.submit(np.arange(6 + i), SamplingParams(max_new_tokens=10))
                for i in range(2)]
        _drain(core)
        return [list(r.output_tokens) for r in reqs], core

    base, _ = run(None)
    inj = FaultInjector(seed=3, specs=(
        FaultSpec("engine/nan_logits", probability=1.0, after=1, max_fires=1),
    ))
    faulty, core = run(inj)
    assert inj.total_fires == 1
    m = core.obs.metrics
    assert m.counter("fault/nan_quarantines").value == 1
    assert m.counter("fault/requeues").value == 1
    assert faulty == base
    assert all(len(t) == 10 for t in faulty)


def test_retry_backoff_gates_readmission():
    """The requeued request is ineligible until its backoff elapses —
    exponential in the fault count — and eligible right after."""
    from repro.serving.core import SchedulerPolicy

    core = _engine().core
    r = core.submit(np.arange(4), SamplingParams(max_new_tokens=2),
                    arrival_time=0.0)
    r.faults = 2
    r.retry_at = 0.0 + core.fault_backoff_s * 2 ** (r.faults - 1)
    pol = SchedulerPolicy()
    assert not pol.eligible(r, Grant(now=r.retry_at - 1e-6))
    assert pol.eligible(r, Grant(now=r.retry_at))


def test_retry_budget_exhaustion_finishes_error():
    inj = FaultInjector(seed=3, specs=(
        FaultSpec("engine/nan_logits", probability=1.0),
    ))
    core = _engine(paged=True, fault_injector=inj).core
    core.fault_backoff_s = 0.0  # retry immediately; every retry is poisoned
    r = core.submit(np.arange(6), SamplingParams(max_new_tokens=10))
    _drain(core)
    assert r.state is RequestState.FINISHED_ERROR
    assert r.finish_reason == "error"
    assert r.faults == core.max_fault_retries + 1
    m = core.obs.metrics
    assert m.counter("fault/retry_exhausted").value == 1
    assert m.counter("core/finish_reason/error").value == 1
    assert core.engine.num_active == 0  # the poisoned slot was released


def test_alloc_fault_contained_and_byte_identical():
    def run(inj):
        core = _engine(paged=True, kv_page_size=8,
                       fault_injector=inj).core
        reqs = [core.submit(np.arange(9), SamplingParams(max_new_tokens=12)),
                core.submit(np.arange(17), SamplingParams(max_new_tokens=12))]
        _drain(core)
        return [list(r.output_tokens) for r in reqs], core

    base, _ = run(None)
    inj = FaultInjector(seed=9, specs=(
        FaultSpec("pool/alloc_fail", probability=1.0, after=2, max_fires=2),
    ))
    faulty, core = run(inj)
    assert inj.total_fires >= 1
    assert faulty == base
    assert all(len(t) == 12 for t in faulty)


@pytest.fixture(scope="module")
def exhaustion_reference():
    """Fault-free bytes per prompt length, from a pool that never blocks."""
    big = _engine(paged=True, kv_page_size=8, kv_pool_pages=256).core
    want = {}
    for n in range(4, 8):
        r = big.submit(np.arange(n), SamplingParams(max_new_tokens=10))
        _drain(big)
        want[n] = list(r.output_tokens)
    return want


def _exhaustion_roundtrip(prompt_lens, want):
    """Property: genuine pool exhaustion never raises — admission blocks on
    capacity and resumes as slots retire, and every request completes with
    the unconstrained pool's exact bytes."""
    # tiny pool: worst-case need of one request is ~3 pages, so several
    # admissions must block on capacity and recover
    core = _engine(paged=True, kv_page_size=8, kv_pool_pages=9).core
    reqs = [core.submit(np.arange(n), SamplingParams(max_new_tokens=10))
            for n in prompt_lens]
    _drain(core, limit=500)
    for n, r in zip(prompt_lens, reqs):
        assert r.state is RequestState.FINISHED_LENGTH
        assert list(r.output_tokens) == want[n]
    assert core.engine.pool.reserved == 0


@pytest.mark.parametrize("lens", [
    [4], [7, 6, 5, 4], [5, 5, 5, 5], [6, 4, 7],
], ids=["one", "desc", "same", "mixed"])
def test_pool_exhaustion_blocks_admission_and_recovers(
    lens, exhaustion_reference
):
    _exhaustion_roundtrip(lens, exhaustion_reference)


def test_pool_exhaustion_property(exhaustion_reference):
    """Hypothesis widening of the seeded sweep (skipped when the package
    is absent — the parametrized cases above always run)."""
    hypothesis = pytest.importorskip("hypothesis")
    given, settings, st = (
        hypothesis.given, hypothesis.settings, hypothesis.strategies,
    )

    @given(st.lists(st.integers(min_value=4, max_value=7),
                    min_size=1, max_size=4))
    @settings(max_examples=6, deadline=None)
    def prop(prompt_lens):
        _exhaustion_roundtrip(prompt_lens, exhaustion_reference)

    prop()


# ---------------------------------------------------------------------------
# Deadlines, degenerate grants
# ---------------------------------------------------------------------------


def test_queue_deadline_expires_without_slot():
    core = _engine().core
    slow = core.submit(np.arange(6), SamplingParams(max_new_tokens=4,
                                                    deadline_s=0.5),
                       arrival_time=0.0)
    out = core.step(Grant(now=1.0))
    assert slow.state is RequestState.FINISHED_EXPIRED
    assert slow.finish_reason == "expired"
    assert slow.request_id not in out.admitted
    assert slow.output_tokens == []
    d = {o.request_id: o for o in out.outputs}
    assert d[slow.request_id].state is RequestState.FINISHED_EXPIRED
    assert core.obs.metrics.counter("core/finish_reason/expired").value == 1
    # expiry never counts toward served latency (it would poison the p95)
    assert core.obs.metrics.histogram("core/offline_latency_s").count == 0
    # deadline-less work is untouched and still serves normally
    keep = core.submit(np.arange(6), SamplingParams(max_new_tokens=4),
                       arrival_time=0.0)
    _drain(core)
    assert keep.state is RequestState.FINISHED_LENGTH
    assert core.obs.metrics.histogram("core/offline_latency_s").count == 1


def test_deadline_never_fires_once_running():
    core = _engine().core
    r = core.submit(np.arange(6), SamplingParams(max_new_tokens=6,
                                                 deadline_s=0.5),
                    arrival_time=0.0)
    core.step(Grant(now=0.0))  # admitted before the deadline
    assert r.state is RequestState.RUNNING
    while not r.state.finished:
        core.step(Grant(now=2.0))  # long past the deadline
    assert r.state is RequestState.FINISHED_LENGTH


def test_degenerate_grant_is_explicit_noop():
    core = _engine().core
    r = core.submit(np.arange(6), SamplingParams(max_new_tokens=4))
    out = core.step(Grant(token_budget=0.0))
    assert out.k == 0 and out.cost_steps == 0.0 and out.prefill_tokens == 0
    assert not out.admitted and r.state is RequestState.WAITING
    m = core.obs.metrics
    assert m.counter("core/starved_quanta").value == 1
    # the quantum still advanced the trace
    ev = [e for e in core.obs.tracer.events if e.get("type") == "quantum"]
    assert len(ev) == 1
    # deadline sweeps still land inside a starved quantum
    doomed = core.submit(np.arange(4), SamplingParams(max_new_tokens=2,
                                                      deadline_s=0.1),
                         arrival_time=0.0)
    core.step(Grant(now=5.0, token_budget=0.0))
    assert doomed.state is RequestState.FINISHED_EXPIRED
    assert m.counter("core/starved_quanta").value == 2


# ---------------------------------------------------------------------------
# Revocable grants
# ---------------------------------------------------------------------------


def _clocked(core):
    """Pin the engine to a controllable virtual clock; returns the grant
    factory: one microstep of cost advances the clock by 1.0."""
    clk = [0.0]
    core.engine.clock = lambda: clk[0]

    def grant(**kw):
        base = clk[0]
        kw.setdefault("now", base)
        kw.setdefault(
            "advance_clock",
            lambda steps, _b=base: clk.__setitem__(0, _b + steps),
        )
        return Grant(**kw)

    return clk, grant


@pytest.mark.parametrize("spec", [False, True], ids=["plain", "spec"])
def test_revocation_yields_within_bound_exact_accounting(spec):
    """An armed signal tripping mid-quantum stops the fused loop within one
    sub-dispatch; the quantum's cost is re-priced to what actually ran, and
    resuming with fresh grants reproduces the fault-free bytes."""

    def run(revoke_at):
        core = _engine(paged=True, max_slots=1, spec=spec).core
        clk, grant = _clocked(core)
        r = core.submit(np.arange(8), SamplingParams(max_new_tokens=24),
                        arrival_time=0.0)
        sig = RevocationSignal()
        sig.arm(revoke_at)
        outs = []
        while not r.state.finished:
            # the signal rides every grant until it trips; afterwards the
            # runtime would stop filling — here we resume with fresh grants
            s = sig if not sig.revoked else None
            outs.append(core.step(grant(revocation=s, revoke_check_steps=1)))
            assert len(outs) < 100
        return list(r.output_tokens), outs, core

    base, outs0, _ = run(revoke_at=float("inf"))
    assert len(base) == 24 and not any(o.revoked for o in outs0)
    # first quantum admits + prefills; revoke 2 microsteps into the second
    cut = outs0[0].cost_steps + 2.0
    toks, outs, core = run(revoke_at=cut)
    revoked = [o for o in outs if o.revoked]
    assert len(revoked) == 1
    ro = revoked[0]
    # exact partial-quantum accounting: each ran microstep priced like the
    # plan's, none of the unran remainder billed
    per = 1.0 if not spec else outs0[1].cost_steps / outs0[1].k
    assert ro.cost_steps == pytest.approx(ro.k * per)
    assert ro.k < outs0[1].k  # genuinely cut short
    # yield bound: at most revoke_check_steps microsteps ran past the
    # signal -> with one slot, <= ceil(2/per)+1 microsteps total
    assert ro.k * per <= 2.0 + per
    assert core.obs.metrics.counter("fault/revocations").value == 1
    # the interrupted stream resumes byte-identical
    assert toks == base


def test_unarmed_signal_is_byte_identical_to_single_dispatch():
    def run(revocable):
        core = _engine(paged=True).core
        clk, grant = _clocked(core)
        r = core.submit(np.arange(8), SamplingParams(max_new_tokens=16),
                        arrival_time=0.0)
        ks, costs = [], []
        while not r.state.finished:
            sig = RevocationSignal() if revocable else None
            out = core.step(grant(revocation=sig, revoke_check_steps=2))
            ks.append(out.k)
            costs.append(out.cost_steps)
        return list(r.output_tokens), ks, costs, clk[0]

    plain = run(False)
    sub = run(True)
    assert sub[0] == plain[0]  # same bytes
    assert sub[1] == plain[1] and sub[2] == plain[2]  # same quantum shapes
    assert sub[3] == pytest.approx(plain[3])  # same virtual end time


def test_injected_mid_quantum_revocation_point():
    inj = FaultInjector(seed=1, specs=(
        FaultSpec("core/revoke_mid_quantum", probability=1.0, after=1,
                  max_fires=1),
    ))
    core = _engine(paged=True, max_slots=1, fault_injector=inj).core
    clk, grant = _clocked(core)
    r = core.submit(np.arange(8), SamplingParams(max_new_tokens=16),
                    arrival_time=0.0)
    sig = RevocationSignal()  # unarmed: only the injector can trip it
    outs = []
    while not r.state.finished:
        outs.append(core.step(grant(revocation=sig, revoke_check_steps=1)))
        if sig.revoked:
            break
    assert sig.revoked and sig.reason == "injected_revocation"
    assert any(o.revoked for o in outs)
    assert core.obs.metrics.counter("fault/revocations").value == 1


def test_injected_step_overrun_inflates_cost():
    def run(inj):
        core = _engine(paged=True, fault_injector=inj).core
        clk, grant = _clocked(core)
        costs = []
        r = core.submit(np.arange(8), SamplingParams(max_new_tokens=8),
                        arrival_time=0.0)
        while not r.state.finished:
            costs.append(core.step(grant()).cost_steps)
        return list(r.output_tokens), costs, clk[0]

    base_toks, base_costs, base_end = run(None)
    inj = FaultInjector(seed=2, specs=(
        FaultSpec("core/step_overrun", probability=1.0, max_fires=1),
    ))
    toks, costs, end = run(inj)
    assert toks == base_toks  # a slow step never corrupts the stream
    assert inj.total_fires == 1
    assert costs[0] > base_costs[0] and costs[1:] == base_costs[1:]
    assert end > base_end  # the overrun consumed real virtual time


# ---------------------------------------------------------------------------
# Overload ladder
# ---------------------------------------------------------------------------


def _ladder_core(n_offline=10):
    core = _engine(paged=True).core
    core.ladder = OverloadLadder(LadderConfig(
        high_queue_depth=4, low_queue_depth=1, up_dwell=2, down_dwell=3,
        offline_keep_depth=2,
    ))
    for i in range(n_offline):
        core.submit(np.arange(5), SamplingParams(max_new_tokens=2),
                    priority=Priority.OFFLINE, arrival_time=0.0)
    return core


def test_ladder_escalates_with_dwell_and_sheds_offline():
    core = _ladder_core()
    lad = core.ladder
    g = Grant(now=0.0)
    lad.update(core, g)
    assert lad.stage is LadderStage.NORMAL  # 1 pressured quantum < up_dwell
    lad.update(core, g)
    assert lad.stage is LadderStage.SPEC_OFF
    lad.update(core, g)
    lad.update(core, g)
    assert lad.stage is LadderStage.K_SHRINK
    lad.update(core, g)
    lad.update(core, g)
    assert lad.stage is LadderStage.SHED_OFFLINE
    # queue trimmed to keep-depth, newest first; oldest work survives
    assert len(core.waiting[Priority.OFFLINE]) == 2
    m = core.obs.metrics
    assert m.counter("fault/shed/offline").value == 8
    assert m.counter("fault/ladder_escalations").value == 3
    assert m.gauge("fault/ladder_stage").value == int(LadderStage.SHED_OFFLINE)


def test_ladder_hysteresis_no_flapping():
    core = _ladder_core(n_offline=0)
    lad = core.ladder
    lad.stage = LadderStage.SPEC_OFF
    # alternating pressured/calm quanta must hold the stage (each flip
    # resets the other dwell) — no flapping around the threshold
    for i in range(6):
        for _ in range(10 if i % 2 else 0):
            core.submit(np.arange(4), SamplingParams(max_new_tokens=1),
                        priority=Priority.OFFLINE, arrival_time=0.0)
        lad.update(core, Grant(now=0.0))
        core.waiting[Priority.OFFLINE].clear()
        assert lad.stage is LadderStage.SPEC_OFF
    # sustained calm de-escalates after down_dwell
    for _ in range(3):
        lad.update(core, Grant(now=0.0))
    assert lad.stage is LadderStage.NORMAL


def test_ladder_sheds_doomed_online_and_downshifts_plan():
    core = _ladder_core(n_offline=0)
    lad = core.ladder
    lad.stage = LadderStage.SHED_ONLINE
    doomed = core.submit(np.arange(4), SamplingParams(max_new_tokens=2,
                                                      deadline_s=1.0),
                         priority=Priority.ONLINE, arrival_time=0.0)
    safe = core.submit(np.arange(4), SamplingParams(max_new_tokens=2,
                                                    deadline_s=100.0),
                       priority=Priority.ONLINE, arrival_time=0.0)
    lad.update(core, Grant(now=1.5))
    assert doomed.state is RequestState.FINISHED_EXPIRED
    assert safe.state is RequestState.WAITING
    assert core.obs.metrics.counter("fault/shed/online").value == 1
    # plan downshift: spec off and k shrunk to the smallest bucket
    from repro.serving.core import StepPlan
    plan = StepPlan(k=8, gamma=4, cost_steps=40.0)
    lad.apply(core, Grant(now=1.5), plan)
    assert plan.gamma is None
    assert plan.k == 1 and plan.cost_steps == pytest.approx(1.0)


def test_ladder_in_step_loop_recovers_service():
    """Integration: with the ladder installed, a burst beyond capacity
    sheds down to the keep-depth but every surviving request completes."""
    core = _ladder_core(n_offline=16)
    n = 0
    while core.has_unfinished:
        core.step(Grant(now=float(n)))
        n += 1
        assert n < 200
    states = [cr.state for q in core.waiting.values() for cr in q]
    assert not states  # nothing stranded
    m = core.obs.metrics
    done = m.counter("core/finished/offline").value
    shed = m.counter("fault/shed/offline").value
    assert done == 16 and shed > 0  # shed requests still FINISH (expired)
    assert m.counter("core/finish_reason/expired").value == shed
    assert m.counter("core/finish_reason/length").value == 16 - shed
    assert m.counter("fault/ladder_escalations").value >= 3


# ---------------------------------------------------------------------------
# Runtime early-resume (training comes back before the predicted bubble end)
# ---------------------------------------------------------------------------


def test_runtime_early_resume_bounded_overrun():
    from repro.core import SpecInFRuntime
    from repro.core.profiles import dp_profile
    from repro.serving.engine import Request

    def make(faults):
        eng = _engine(paged=True)
        for _ in range(2):
            eng.add_request(Request(prompt=np.arange(8), max_new_tokens=1000))
        return SpecInFRuntime(
            train_step=lambda s, b: (s, {}),
            train_state=None,
            batch_iter=iter(lambda: {}, None),
            profile=dp_profile("tiny", compute_s=0.02, comm_s=0.04),
            engine=eng,
            cfg=SpecInFConfig(),
            decode_microstep_s=0.004,
            faults=faults,
        )

    inj = FaultInjector(seed=4, specs=(
        FaultSpec("runtime/early_resume", probability=1.0, max_fires=1),
    ))
    rt = make(inj)
    rt.run(num_iterations=4)
    assert inj.total_fires == 1
    m = rt.engine.obs.metrics
    assert m.counter("fault/early_resume").value == 1
    assert m.counter("fault/revocations").value >= 0  # boundary trips are ok
    h = m.histogram("fault/revocation_overrun_s")
    assert h.count == 1
    # yield bound on the virtual clock: at most one sub-dispatch of
    # ``revoke_check_steps`` (=1) microsteps past the resume instant
    assert max(h.values()) <= rt.decode_microstep_s * 3 + 1e-9
    assert rt.monitor.interrupts == 1
    # training still ran to completion and the run stayed deterministic
    assert rt.metrics.train_iterations == 4
    # per dp_profile iteration: compute_s + exposed comm (overlap 0.3)
    assert rt.metrics.virtual_time_s == pytest.approx(
        4 * (0.02 + 0.04 * 0.7)
    )

    # reproducibility: the same seed fires the same schedule
    inj2 = FaultInjector(seed=4, specs=(
        FaultSpec("runtime/early_resume", probability=1.0, max_fires=1),
    ))
    rt2 = make(inj2)
    rt2.run(num_iterations=4)
    h2 = rt2.engine.obs.metrics.histogram("fault/revocation_overrun_s")
    assert h2.values() == h.values()
