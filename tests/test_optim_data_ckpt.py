"""Optimizer, data-pipeline, and checkpointing substrate tests."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticDataset
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    ef_int8_compress_decompress,
    global_norm,
    make_schedule,
)
from repro import configs


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def test_adamw_matches_manual_reference():
    cfg = TrainConfig(weight_decay=0.0, beta1=0.9, beta2=0.999, eps=1e-8)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    opt = adamw_init(p)
    new_p, opt = adamw_update(g, opt, p, lr=0.01, cfg=cfg)
    mu = 0.1 * np.array([0.1, 0.2, -0.3])
    nu = 0.001 * np.array([0.1, 0.2, -0.3]) ** 2
    mu_hat, nu_hat = mu / 0.1, nu / 0.001
    expect = np.array([1.0, -2.0, 3.0]) - 0.01 * mu_hat / (np.sqrt(nu_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(opt["step"]) == 1


def test_adamw_weight_decay_shrinks_params():
    cfg = TrainConfig(weight_decay=0.5)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.zeros((4,))}
    opt = adamw_init(p)
    new_p, _ = adamw_update(g, opt, p, lr=0.1, cfg=cfg)
    assert float(new_p["w"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(3 * 16 + 4 * 9))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below the limit: untouched
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_schedule_warmup_and_decay():
    cfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine")
    sched = make_schedule(cfg)
    assert float(sched(0)) == pytest.approx(0.0)
    assert float(sched(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(sched(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(sched(55)) < float(sched(10))


@given(st.lists(st.floats(min_value=-10, max_value=10), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_ef_compression_error_feedback_identity(values):
    """EF invariant: deq + new_err == grad + old_err exactly (no signal lost,
    only delayed)."""
    g = jnp.asarray(values, jnp.float32)
    err = jnp.zeros_like(g)
    deq, new_err = ef_int8_compress_decompress(g, err)
    np.testing.assert_allclose(
        np.asarray(deq + new_err), np.asarray(g + err), rtol=1e-5, atol=1e-6
    )
    # quantization error bounded by one int8 step of the scale
    scale = max(float(jnp.max(jnp.abs(g))), 1e-12) / 127.0
    assert float(jnp.max(jnp.abs(new_err))) <= scale * 0.5 + 1e-6


def test_ef_compression_converges_on_constant_gradient():
    """Accumulated EF-SGD updates approach the true gradient sum."""
    g = jnp.asarray([0.001, -0.003, 0.5], jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(100):
        deq, err = ef_int8_compress_decompress(g, err)
        total = total + deq
    np.testing.assert_allclose(np.asarray(total), np.asarray(g * 100),
                               rtol=0.02, atol=0.01)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def _ds(**kw):
    cfg = configs.smoke_config("olmo-1b")
    defaults = dict(cfg=cfg, seq_len=16, global_batch=8)
    defaults.update(kw)
    return SyntheticDataset(**defaults)


def test_data_deterministic_across_instances():
    a, b = _ds(), _ds()
    ba, bb = a.next_batch(), b.next_batch()
    np.testing.assert_array_equal(ba["inputs"], bb["inputs"])
    np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_data_labels_are_shifted_inputs():
    b = _ds().next_batch()
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_data_host_sharding_partitions_global_batch():
    h0 = _ds(host_index=0, host_count=2)
    h1 = _ds(host_index=1, host_count=2)
    assert h0.local_batch == 4 and h1.local_batch == 4
    b0, b1 = h0.next_batch(), h1.next_batch()
    assert b0["inputs"].shape[0] == 4
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_data_stream_advances():
    ds = _ds()
    b1, b2 = ds.next_batch(), ds.next_batch()
    assert not np.array_equal(b1["inputs"], b2["inputs"])


def test_data_has_learnable_structure():
    """Sticky bigram: successor prediction beats chance by a wide margin."""
    ds = _ds(seq_len=256, global_batch=16)
    b = ds.next_batch()
    inp, lab = b["inputs"], b["labels"]
    hit = (ds._succ[inp] == lab).mean()
    assert hit > 0.3, hit


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------


def _state(val=1.0):
    return {
        "params": {"w": jnp.full((4, 4), val), "b": jnp.zeros((4,))},
        "opt": {"step": jnp.int32(3)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(7, _state(2.5))
    restored, step = ck.restore(_state(0.0))
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 2.5)
    assert int(restored["opt"]["step"]) == 3


def test_checkpoint_async_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(float(s)), blocking=(s == 4))
    ck.wait()
    assert ck.all_steps() == [3, 4]
    restored, step = ck.restore(_state())
    assert step == 4
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 4.0)


def test_checkpoint_ignores_incomplete(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1.0))
    os.makedirs(tmp_path / "step_00000009")  # no manifest -> incomplete
    assert ck.latest_step() == 1


def test_checkpoint_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state())
