"""Pallas kernel validation vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the assignment: every kernel is asserted allclose
against ``kernels/ref.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan_chunk


def _attn_inputs(b, h, sq, sk, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd), dtype)
    k = jax.random.normal(ks[1], (b, h, sk, hd), dtype)
    v = jax.random.normal(ks[2], (b, h, sk, hd), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,h,sq,sk,hd,block_q,block_k",
    [
        (1, 1, 128, 128, 64, 64, 64),
        (2, 4, 200, 200, 64, 64, 64),     # ragged: padding path
        (1, 2, 256, 256, 128, 128, 128),
        (1, 1, 64, 320, 64, 32, 64),      # cross-attention lengths
        (2, 2, 96, 96, 32, 32, 32),
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(b, h, sq, sk, hd, block_q, block_k, causal):
    if causal and sq != sk:
        pytest.skip("causal oracle assumes aligned suffix")
    q, k, v = _attn_inputs(b, h, sq, sk, hd, jnp.float32)
    out = flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=True
    )
    expected = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expected), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q, k, v = _attn_inputs(1, 2, 128, 128, 64, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    assert out.dtype == dtype
    expected = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_matches_xla_flash_long():
    """The lax.scan blocked path (used for 32k prefill) matches the oracle."""
    from repro.models.layers import attention_xla_flash

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 512, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 32), jnp.float32)  # GQA kv=2
    v = jax.random.normal(ks[2], (1, 512, 2, 32), jnp.float32)
    out = attention_xla_flash(q, k, v, causal=True, block_k=128)
    from repro.models.layers import _repeat_kv

    expected = ref.attention_ref(q, _repeat_kv(k, 4), _repeat_kv(v, 4), causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "b,q,di,ds,block_d",
    [
        (1, 8, 64, 8, 64),
        (2, 16, 128, 8, 64),
        (2, 32, 128, 16, 128),
        (1, 64, 256, 16, 64),
    ],
)
def test_ssm_scan_matches_oracle(b, q, di, ds, block_d):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    xi = jax.random.normal(ks[0], (b, q, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, q, di)))
    B_ = jax.random.normal(ks[2], (b, q, ds), jnp.float32)
    C_ = jax.random.normal(ks[3], (b, q, ds), jnp.float32)
    A = -jnp.abs(jax.random.normal(ks[4], (di, ds)))
    h0 = jax.random.normal(ks[5], (b, di, ds), jnp.float32) * 0.1
    y, h = ssm_scan_chunk(xi, dt, B_, C_, A, h0, block_d=block_d, interpret=True)
    y_ref, h_ref = ref.ssm_scan_chunk_ref(xi, dt, B_, C_, A, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5, atol=1e-5)


def test_ssm_scan_nonzero_initial_state_chains():
    """Chunked chaining: scanning two chunks == one long oracle scan."""
    b, q, di, ds = 1, 12, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xi = jax.random.normal(ks[0], (b, 2 * q, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, 2 * q, di)))
    B_ = jax.random.normal(ks[2], (b, 2 * q, ds), jnp.float32)
    C_ = jax.random.normal(ks[3], (b, 2 * q, ds), jnp.float32)
    A = -jnp.abs(jax.random.normal(ks[4], (di, ds)))
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    y1, h1 = ssm_scan_chunk(xi[:, :q], dt[:, :q], B_[:, :q], C_[:, :q], A, h0,
                            block_d=64, interpret=True)
    y2, h2 = ssm_scan_chunk(xi[:, q:], dt[:, q:], B_[:, q:], C_[:, q:], A, h1,
                            block_d=64, interpret=True)
    y_ref, h_ref = ref.ssm_scan_chunk_ref(xi, dt, B_, C_, A, h0)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_ref),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


def test_ssd_chunked_matches_naive_recurrence():
    """Mamba2 SSD matmul form == step recurrence applied sequentially."""
    from repro.models.ssm import ssd_chunked

    b, s, nh, hp, ds = 1, 48, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    B_ = jax.random.normal(ks[2], (b, s, ds), jnp.float32)
    C_ = jax.random.normal(ks[3], (b, s, ds), jnp.float32)
    A = -jnp.abs(jax.random.normal(ks[4], (nh,)))
    h0 = jnp.zeros((b, nh, hp, ds), jnp.float32)
    y, h_fin = ssd_chunked(x, dt, B_, C_, A, h0, chunk=16)

    h = h0
    ys = []
    for t in range(s):
        a = jnp.exp(dt[:, t] * A)  # [b, nh]
        h = a[..., None, None] * h + (dt[:, t, :, None] * x[:, t])[..., None] \
            * B_[:, t][:, None, None, :]
        ys.append(jnp.einsum("bnxs,bs->bnx", h, C_[:, t]))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h),
                               rtol=1e-4, atol=1e-4)
