"""EngineCore request lifecycle (DESIGN.md §6): state machine, priority
preemption, preempt->resume byte-identity (dense + paged, spec on/off),
abort resource release, stop tokens, streaming, and the deprecated-shim
equivalence sweep."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SpecDecodeConfig, draft_config
from repro.models import transformer as T
from repro.serving.core import (
    EngineCore,
    Grant,
    Priority,
    PriorityPolicy,
    RequestState,
    SamplingParams,
)
from repro.serving.engine import InferenceEngine, Request

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
DCFG = draft_config(CFG)
DPARAMS = T.init_params(DCFG, jax.random.PRNGKey(1))


def _engine(paged=True, spec=False, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 128)
    kw.setdefault("kv_page_size", None if paged else 0)
    if spec:
        kw.update(draft_cfg=DCFG, draft_params=DPARAMS,
                  spec=SpecDecodeConfig(mode="greedy"))
    return InferenceEngine(CFG, PARAMS, **kw)


def _drain(core, limit=200):
    n = 0
    while core.has_unfinished:
        core.step()
        n += 1
        assert n < limit, "core.step() made no progress"


# ---------------------------------------------------------------------------
# Lifecycle basics
# ---------------------------------------------------------------------------


def test_lifecycle_waiting_running_finished():
    core = _engine().core
    r = core.submit(np.arange(6), SamplingParams(max_new_tokens=3))
    assert r.state is RequestState.WAITING and core.num_waiting == 1
    out = core.step()
    assert r.request_id in out.admitted
    # prefill produced the first token in the same quantum
    deltas = {o.request_id: o for o in out.outputs}
    assert len(deltas[r.request_id].new_tokens) >= 1
    assert deltas[r.request_id].ttft_s is not None  # stamped exactly once
    _drain(core)
    assert r.state is RequestState.FINISHED_LENGTH
    assert r.finish_reason == "length"
    assert len(r.output_tokens) == 3
    assert r.first_token_time is not None and r.finish_time is not None


def test_submit_rejects_structurally_impossible():
    core = _engine(max_seq=32).core
    with pytest.raises(ValueError):
        core.submit(np.arange(64), SamplingParams(max_new_tokens=1))


def test_ttft_reported_exactly_once():
    core = _engine().core
    r = core.submit(np.arange(4), SamplingParams(max_new_tokens=6))
    stamps = []
    n = 0
    while core.has_unfinished:
        out = core.step()
        stamps += [o.ttft_s for o in out.outputs
                   if o.request_id == r.request_id and o.ttft_s is not None]
        n += 1
        assert n < 50
    assert len(stamps) == 1 and stamps[0] >= 0.0


def test_stream_yields_full_sequence():
    core = _engine().core
    r = core.submit(np.arange(5), SamplingParams(max_new_tokens=4))
    toks = list(core.stream(r))
    assert toks == r.output_tokens and len(toks) == 4
    assert r.state.finished


def test_stop_token_finishes_early_and_frees_slot():
    core = _engine().core
    probe = core.submit(np.arange(5), SamplingParams(max_new_tokens=8))
    _drain(core)
    assert len(probe.output_tokens) == 8
    stop = probe.output_tokens[3]
    first = probe.output_tokens.index(stop)  # may repeat earlier
    r = core.submit(
        np.arange(5), SamplingParams(max_new_tokens=8, stop_token_ids=(stop,))
    )
    _drain(core)
    assert r.state is RequestState.FINISHED_STOPPED
    assert r.finish_reason == "stop"
    # trimmed at (and including) the first stop-token occurrence
    assert r.output_tokens == probe.output_tokens[: first + 1]
    assert core.engine.num_active == 0  # slot released despite early stop


# ---------------------------------------------------------------------------
# Preemption / resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("spec", [False, True], ids=["plain", "spec"])
def test_preempt_resume_byte_identical(paged, spec):
    """A preempted-then-resumed greedy stream must be byte-identical to an
    uninterrupted run: resume re-prefills prompt+generated (paged engines
    recover the prompt pages from the radix cache) and greedy decode is
    deterministic."""

    def run(preempt_at):
        core = _engine(paged=paged, spec=spec).core
        r = core.submit(np.arange(20), SamplingParams(max_new_tokens=24))
        n = 0
        while not r.state.finished:
            core.step()
            n += 1
            if n == preempt_at and not r.state.finished:
                assert core.preempt(r) is r
                assert r.state is RequestState.PREEMPTED
            assert n < 100
        return list(r.output_tokens), r

    base, _ = run(preempt_at=10**9)
    resumed, req = run(preempt_at=1)
    assert req.preemptions == 1
    assert resumed == base and len(base) == 24


def test_preempt_releases_pages_and_resume_hits_prefix():
    eng = _engine(paged=True)
    core = eng.core
    r = core.submit(np.arange(32), SamplingParams(max_new_tokens=16))
    core.step()
    slot = core.slot_of(r)
    held = len(eng._slot_pages[slot])
    assert held >= 2
    in_use = eng.pool.pages_in_use
    skipped0 = eng.prefill_skipped_tokens
    core.preempt(r)
    # only the radix-cached prompt pages survive the eviction
    assert eng.pool.pages_in_use < in_use
    assert eng.pool.reserved == 0
    assert eng.prefix_cache.evictable_pages() > 0
    _drain(core)
    # resume recomputed via the prefix hit: prefill compute was skipped
    assert eng.prefill_skipped_tokens > skipped0
    assert r.state is RequestState.FINISHED_LENGTH


def test_online_preempts_offline_and_offline_resumes():
    """The paper's protection story: an ONLINE arrival claims capacity from
    a RUNNING OFFLINE slot instead of queueing behind it, and the offline
    stream is unchanged by the round-trip."""
    eng = _engine(max_slots=1)
    core = eng.core
    off = core.submit(np.arange(8), SamplingParams(max_new_tokens=20),
                      priority=Priority.OFFLINE)
    core.step()
    assert off.state is RequestState.RUNNING
    on = core.submit(np.arange(5), SamplingParams(max_new_tokens=4),
                     priority=Priority.ONLINE)
    out = core.step()
    assert off.request_id in out.preempted
    assert on.request_id in out.admitted
    _drain(core)
    assert on.finish_time <= off.finish_time
    assert off.preemptions == 1 and off.state is RequestState.FINISHED_LENGTH

    ref = _engine(max_slots=1).core
    ref_off = ref.submit(np.arange(8), SamplingParams(max_new_tokens=20))
    _drain(ref)
    assert off.output_tokens == ref_off.output_tokens


def test_no_preemption_policy_queues_online():
    eng = _engine(max_slots=1)
    core = EngineCore(eng, policy=PriorityPolicy(preemption=False))
    off = core.submit(np.arange(8), SamplingParams(max_new_tokens=6),
                      priority=Priority.OFFLINE)
    core.step()
    on = core.submit(np.arange(5), SamplingParams(max_new_tokens=2),
                     priority=Priority.ONLINE)
    out = core.step()
    assert not out.preempted and on.state is RequestState.WAITING
    _drain(core)
    assert off.preemptions == 0
    assert on.state.finished and off.state.finished


# ---------------------------------------------------------------------------
# Abort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [False, True], ids=["plain", "spec"])
def test_abort_mid_decode_releases_pages_and_draft_state(spec):
    eng = _engine(paged=True, spec=spec)
    core = eng.core
    a = core.submit(np.arange(24), SamplingParams(max_new_tokens=30))
    b = core.submit(np.arange(24, 48), SamplingParams(max_new_tokens=30))
    core.step()
    slot = core.slot_of(a)
    assert a.state is RequestState.RUNNING and slot is not None
    in_use = eng.pool.pages_in_use
    reserved = eng.pool.reserved
    core.abort(a)
    assert a.state is RequestState.FINISHED_ABORTED
    assert a.finish_reason == "abort"
    assert eng.pool.pages_in_use < in_use, "abort must release pages"
    assert eng.pool.reserved < reserved, "abort must release reservations"
    assert eng.slots[slot] is None
    assert int(eng.cache["index"][slot]) == 0
    if spec:
        assert int(eng.draft_cache["index"][slot]) == 0, (
            "mid-decode abort left draft-cache state behind"
        )
    # the freed slot admits new work, and survivors run to completion
    c = core.submit(np.arange(5), SamplingParams(max_new_tokens=2))
    _drain(core)
    assert b.state.finished and c.state.finished
    assert len(a.output_tokens) < 30  # aborted mid-decode


def test_abort_waiting_request_never_runs():
    core = _engine(max_slots=1).core
    a = core.submit(np.arange(4), SamplingParams(max_new_tokens=4))
    b = core.submit(np.arange(4), SamplingParams(max_new_tokens=4))
    core.abort(b)
    assert b.state is RequestState.FINISHED_ABORTED and core.num_waiting == 1
    _drain(core)
    assert a.state.finished and b.output_tokens == []


# ---------------------------------------------------------------------------
# Shim-vs-core equivalence sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("spec", [False, True], ids=["plain", "spec"])
def test_shim_vs_core_equivalence(paged, spec):
    """The deprecated add_request/decode_loop surface and the
    submit()/step() lifecycle must produce identical token streams for the
    same workload — the shim really is a thin delegate."""
    prompts = [np.arange(4), np.arange(7, 19), np.arange(30, 36)]
    budgets = [3, 9, 6]

    eng_a = _engine(paged=paged, spec=spec, max_slots=3)
    legacy = [Request(prompt=p, max_new_tokens=m)
              for p, m in zip(prompts, budgets)]
    for r in legacy:
        assert eng_a.add_request(r)
    for _ in range(20):
        if spec:
            eng_a.spec_decode_loop(2, 2)
        else:
            eng_a.decode_loop(4)
        if eng_a.num_active == 0:
            break
    assert eng_a.num_active == 0

    eng_b = _engine(paged=paged, spec=spec, max_slots=3)
    core = eng_b.core
    reqs = [core.submit(p, SamplingParams(max_new_tokens=m))
            for p, m in zip(prompts, budgets)]
    _drain(core)

    for lr, cr in zip(legacy, reqs):
        assert [int(t) for t in lr.generated] == cr.output_tokens
    # the shim registers its requests in the same lifecycle
    for lr in legacy:
        assert eng_a.core.requests[lr.request_id].state.finished


def test_legacy_microstep_path_updates_core_state():
    eng = _engine(paged=False)
    r = Request(prompt=np.arange(4), max_new_tokens=2)
    assert eng.add_request(r)
    for _ in range(4):
        eng.decode_microstep()
        if eng.num_active == 0:
            break
    cr = eng.core.requests[r.request_id]
    assert cr.state is RequestState.FINISHED_LENGTH
    assert cr.output_tokens == [int(t) for t in r.generated]


# ---------------------------------------------------------------------------
# Grants
# ---------------------------------------------------------------------------


def test_grant_gates_online_admission():
    core = _engine().core
    r = core.submit(np.arange(4), SamplingParams(max_new_tokens=2),
                    priority=Priority.ONLINE)
    out = core.step(Grant(online_ok=False))
    assert not out.admitted and r.state is RequestState.WAITING
    out = core.step(Grant(online_ok=True))
    assert r.request_id in out.admitted


def test_grant_advance_clock_stamps_quantum_end():
    vnow = [0.0]
    eng = _engine(clock=lambda: vnow[0])
    core = eng.core
    r = core.submit(np.arange(4), SamplingParams(max_new_tokens=3),
                    arrival_time=0.0)
    n = 0
    while core.has_unfinished:
        core.step(Grant(
            now=vnow[0],
            advance_clock=lambda steps: vnow.__setitem__(
                0, vnow[0] + steps * 0.002),
        ))
        n += 1
        assert n < 20
    assert r.finish_time == pytest.approx(vnow[0])
    assert r.first_token_time is not None
    assert r.first_token_time <= r.finish_time
