"""Speculative decoding subsystem validation (DESIGN.md §4).

Four layers, matching the subsystem's structure:
  * the chunk-verify Pallas kernel (interpret mode on CPU) against a
    chunk-causal length-masked dense reference, across GQA ratios, ragged
    lengths, empty slots, and the T=1 degeneration to flash-decode;
  * ``decode_chunk`` — one fused target pass over gamma+1 positions — against
    the sequential ``decode_step`` chain (KV and recurrent families);
  * the engine's fused ``spec_decode_loop``: greedy mode must emit the
    byte-identical stream as plain greedy ``decode_loop`` with rollback
    exercised, under the one-transfer-per-loop discipline;
  * the adaptive gamma controller and the draft/target config pairing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SpecDecodeConfig, draft_config
from repro.core.scheduler import Phase
from repro.kernels import ops
from repro.kernels.verify_attention import verify_attention
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request
from repro.spec.controller import GAMMA_BUCKETS, AdaptiveGammaController


# ---------------------------------------------------------------------------
# Chunk-verify kernel
# ---------------------------------------------------------------------------


def _inputs(b, t, h, kvh, s, hd, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, hd), dtype)
    return q, k, v


def _ref(q, k, v, lengths):
    """Chunk-causal length-masked dense verify attention."""
    b, t, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    reps = h // kvh
    kk = jnp.repeat(k, reps, axis=2)
    vv = jnp.repeat(v, reps, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * hd**-0.5
    kpos = jnp.arange(s)
    bound = (lengths - t)[:, None] + jnp.arange(t)[None, :]
    mask = kpos[None, None, :] <= bound[:, :, None]
    scores = jnp.where(mask[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(lengths[:, None, None, None] > 0, p, 0.0)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32)).astype(
        q.dtype
    )


@pytest.mark.parametrize(
    "b,t,h,kvh,s,hd,block_k",
    [
        (4, 5, 4, 2, 64, 16, 16),   # GQA 2:1, several kv tiles
        (2, 3, 4, 4, 128, 32, 128),  # MHA, single tile
        (3, 2, 8, 2, 96, 16, 32),   # GQA 4:1, ragged tile count
        (2, 4, 4, 1, 80, 16, 32),   # MQA, non-multiple-of-block length
        (1, 5, 2, 2, 48, 64, 64),   # block_k > s (clamped)
    ],
)
def test_verify_kernel_matches_reference(b, t, h, kvh, s, hd, block_k):
    q, k, v = _inputs(b, t, h, kvh, s, hd)
    # ragged lengths incl. boundary cases: empty, chunk-only, mid, full
    lengths = jnp.asarray(([0, t, t + s // 3, s] * b)[:b], jnp.int32)
    out = verify_attention(q, k, v, lengths, block_k=block_k, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_ref(q, k, v, lengths)),
        rtol=2e-5, atol=2e-5,
    )


def test_verify_kernel_empty_slot_is_zero():
    q, k, v = _inputs(2, 3, 4, 2, 32, 16)
    lengths = jnp.array([0, 9], jnp.int32)
    out = verify_attention(q, k, v, lengths, block_k=16, interpret=True)
    assert np.all(np.asarray(out[0]) == 0.0)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_kernel_dtypes(dtype):
    q, k, v = _inputs(2, 4, 4, 2, 64, 32, dtype=dtype)
    lengths = jnp.array([7, 64], jnp.int32)
    out = verify_attention(q, k, v, lengths, block_k=32, interpret=True)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(_ref(q, k, v, lengths), np.float32),
        rtol=tol, atol=tol,
    )


def test_verify_kernel_empty_window_rows_are_zero():
    """0 < lengths < T: chunk rows whose causal window is empty must return
    zeros (not a softmax-of-all-masked mean of V); rows with a window match
    the reference."""
    t = 4
    q, k, v = _inputs(1, t, 4, 2, 32, 16, seed=4)
    lengths = jnp.array([2], jnp.int32)  # rows t=0,1 have no visible keys
    out = verify_attention(q, k, v, lengths, block_k=16, interpret=True)
    assert np.all(np.asarray(out[:, :2]) == 0.0)
    np.testing.assert_allclose(
        np.asarray(out[:, 2:]), np.asarray(_ref(q, k, v, lengths)[:, 2:]),
        rtol=2e-5, atol=2e-5,
    )
    assert np.all(np.isfinite(np.asarray(out)))


def test_verify_kernel_chunk1_degenerates_to_flash_decode():
    """A T=1 chunk is exactly single-token decode attention."""
    from repro.kernels.decode_attention import decode_attention

    q, k, v = _inputs(3, 1, 4, 2, 64, 16, seed=2)
    lengths = jnp.array([1, 11, 64], jnp.int32)
    out_v = verify_attention(q, k, v, lengths, block_k=32, interpret=True)
    out_d = decode_attention(q[:, 0], k, v, lengths, block_k=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out_v[:, 0]), np.asarray(out_d), rtol=2e-5, atol=2e-5
    )


def test_ops_dispatch_pallas_equals_xla():
    q, k, v = _inputs(3, 4, 4, 2, 64, 16, seed=3)
    lengths = jnp.array([0, 13, 64], jnp.int32)
    out_x = ops.verify_attention(q, k, v, lengths, impl="xla")
    out_p = ops.verify_attention(q, k, v, lengths, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_x), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# decode_chunk: one fused pass == sequential decode steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b"])
def test_decode_chunk_matches_sequential_steps(arch):
    cfg = configs.smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    _, cache = T.prefill(cfg, params, prompt, 32, compute_dtype=jnp.float32)
    cache["index"] = jnp.full((2,), 6, jnp.int32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0, cfg.vocab_size)
    chunk_logits, chunk_cache, states = T.decode_chunk(
        cfg, params, toks, jax.tree.map(lambda x: x, cache),
        compute_dtype=jnp.float32,
    )
    seq_logits = []
    for j in range(4):
        l, cache = T.decode_step(
            cfg, params, toks[:, j], cache, compute_dtype=jnp.float32
        )
        seq_logits.append(l)
    np.testing.assert_allclose(
        np.asarray(chunk_logits), np.asarray(jnp.stack(seq_logits, 1)),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(chunk_cache["index"]), np.asarray(cache["index"])
    )
    if cfg.family in ("ssm", "hybrid"):
        # per-step state capture: last captured state == sequential final
        assert states is not None
        last = jax.tree.map(lambda s: s[-1], states)
        ref = T.chunk_recurrent_states(cfg, cache["layers"])
        for a, b in zip(jax.tree.leaves(last), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
        assert states is None  # KV rollback is an index rewind


# ---------------------------------------------------------------------------
# Engine: fused speculative loop == plain greedy loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["qwen3-1.7b", "falcon-mamba-7b", "zamba2-2.7b"]
)
def test_spec_engine_equals_plain_greedy(arch):
    """Greedy speculative decoding is an exact accelerator: same stream as
    plain greedy, KV *and* SSM/conv rollback exercised (random draft ->
    near-zero acceptance), one device->host transfer per fused loop."""
    cfg = configs.smoke_config(arch)
    dcfg = draft_config(cfg)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    dparams = T.init_params(dcfg, jax.random.PRNGKey(7))
    prompts = [np.arange(4), np.arange(9), np.arange(2)]
    max_new = [7, 12, 5]  # ragged budgets: slots finish mid-loop

    plain = InferenceEngine(
        cfg, params, max_slots=3, max_seq=64, compute_dtype=jnp.float32
    )
    spec = InferenceEngine(
        cfg, params, max_slots=3, max_seq=64, compute_dtype=jnp.float32,
        draft_cfg=dcfg, draft_params=dparams,
    )
    rp = [Request(prompt=p, max_new_tokens=m) for p, m in zip(prompts, max_new)]
    rs = [Request(prompt=p, max_new_tokens=m) for p, m in zip(prompts, max_new)]
    for r in rp:
        assert plain.add_request(r)
    for r in rs:
        assert spec.add_request(r)
    while plain.num_active:
        plain.decode_loop(4)
    loops = 0
    while spec.num_active:
        d2h0 = spec.d2h_transfers
        spec.spec_decode_loop(2, 2)
        assert spec.d2h_transfers - d2h0 == 1, "one transfer per fused loop"
        loops += 1
        assert loops < 50
    for a, b in zip(rp, rs):
        assert b.generated == a.generated, (
            f"speculative stream diverges for prompt len {len(a.prompt)}"
        )
    assert spec.spec_drafted > spec.spec_accepted, "rollback never exercised"


def test_spec_loop_noop_without_active_slots():
    cfg = configs.smoke_config("qwen3-1.7b")
    dcfg = draft_config(cfg)
    engine = InferenceEngine(
        cfg, T.init_params(cfg, jax.random.PRNGKey(0)), max_slots=2,
        max_seq=32, draft_cfg=dcfg,
        draft_params=T.init_params(dcfg, jax.random.PRNGKey(1)),
    )
    assert engine.spec_decode_loop(4, 2) == []
    assert engine.d2h_transfers == 0


def test_simulated_mode_respects_budgets():
    """Simulated acceptance (benchmark mode) runs the real loop mechanics:
    budgets land exactly, acceptance tracks the Bernoulli parameter."""
    cfg = configs.smoke_config("qwen3-1.7b")
    dcfg = draft_config(cfg)
    engine = InferenceEngine(
        cfg, T.init_params(cfg, jax.random.PRNGKey(0)), max_slots=3,
        max_seq=256, draft_cfg=dcfg,
        draft_params=T.init_params(dcfg, jax.random.PRNGKey(1)),
        spec=SpecDecodeConfig(mode="simulated", sim_accept_p=0.9),
    )
    reqs = [
        Request(prompt=np.arange(3 + i), max_new_tokens=20 + 3 * i)
        for i in range(3)
    ]
    for r in reqs:
        assert engine.add_request(r)
    while engine.num_active:
        engine.spec_decode_loop(4, 4)
    for r in reqs:
        assert len(r.generated) == r.max_new_tokens
    assert 0.5 < engine.spec_acceptance_rate <= 1.0


def test_sample_mode_deterministic_under_seed():
    cfg = configs.smoke_config("qwen3-1.7b")
    dcfg = draft_config(cfg)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    dparams = T.init_params(dcfg, jax.random.PRNGKey(3))
    streams = []
    for _ in range(2):
        engine = InferenceEngine(
            cfg, params, max_slots=1, max_seq=64, compute_dtype=jnp.float32,
            draft_cfg=dcfg, draft_params=dparams,
            spec=SpecDecodeConfig(mode="sample"), spec_seed=11,
        )
        req = Request(prompt=np.arange(5), max_new_tokens=12)
        assert engine.add_request(req)
        while engine.num_active:
            engine.spec_decode_loop(2, 2)
        assert all(0 <= t < cfg.vocab_size for t in req.generated)
        assert len(req.generated) == 12
        streams.append(list(req.generated))
    assert streams[0] == streams[1]


def test_spec_slot_recycling():
    """A slot freed by the speculative loop accepts a fresh request and both
    caches (target + draft) are re-prefilled for it."""
    cfg = configs.smoke_config("qwen3-1.7b")
    dcfg = draft_config(cfg)
    engine = InferenceEngine(
        cfg, T.init_params(cfg, jax.random.PRNGKey(0)), max_slots=1,
        max_seq=64, draft_cfg=dcfg,
        draft_params=T.init_params(dcfg, jax.random.PRNGKey(1)),
    )
    first = Request(prompt=np.arange(4), max_new_tokens=3)
    assert engine.add_request(first)
    while engine.num_active:
        engine.spec_decode_loop(2, 2)
    assert len(first.generated) == 3
    again = Request(prompt=np.arange(6), max_new_tokens=4)
    assert engine.add_request(again)
    while engine.num_active:
        engine.spec_decode_loop(2, 2)
    assert len(again.generated) == 4


# ---------------------------------------------------------------------------
# Adaptive gamma controller
# ---------------------------------------------------------------------------


def test_gamma_controller_phase_gating():
    ctrl = AdaptiveGammaController(init_acceptance=0.95)
    assert ctrl.gamma_for(Phase.CONSERVATIVE) == GAMMA_BUCKETS[0]
    assert ctrl.gamma_for(Phase.INCREMENTAL) <= ctrl.gamma_for(Phase.STABLE)
    assert ctrl.gamma_for(Phase.STABLE) == GAMMA_BUCKETS[-1]


def test_gamma_controller_tracks_acceptance():
    ctrl = AdaptiveGammaController(init_acceptance=0.9)
    high = ctrl.gamma_for(Phase.STABLE)
    for _ in range(10):
        ctrl.observe(accepted=0, proposed=8)  # draft is useless
    low = ctrl.gamma_for(Phase.STABLE)
    assert ctrl.acceptance < 0.05
    assert low <= high and low == GAMMA_BUCKETS[0]
    for _ in range(10):
        ctrl.observe(accepted=8, proposed=8)
    assert ctrl.gamma_for(Phase.STABLE) == GAMMA_BUCKETS[-1]
    assert ctrl.expected_tokens_per_round(4) > ctrl.expected_tokens_per_round(1)


def test_gamma_controller_ignores_empty_observations():
    ctrl = AdaptiveGammaController(init_acceptance=0.7)
    ctrl.observe(accepted=0, proposed=0)
    assert ctrl.acceptance == 0.7


# ---------------------------------------------------------------------------
# Draft/target pairing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["qwen3-1.7b", "olmo-1b", "falcon-mamba-7b", "zamba2-2.7b",
             "moonshot-v1-16b-a3b"]
)
def test_draft_config_structurally_valid(arch):
    cfg = configs.smoke_config(arch)
    dcfg = draft_config(cfg)
    assert dcfg.vocab_size == cfg.vocab_size
    assert dcfg.family == cfg.family
    assert dcfg.num_layers <= max(cfg.num_layers, cfg.shared_attn_every or 1)
    if dcfg.num_heads:
        assert dcfg.num_heads % dcfg.num_kv_heads == 0
        assert dcfg.resolved_head_dim == cfg.resolved_head_dim
    if dcfg.shared_attn_every:
        assert dcfg.num_layers % dcfg.shared_attn_every == 0
    if dcfg.ssm_version == 2:
        assert dcfg.d_inner % dcfg.ssm_head_dim == 0
    # the draft must actually be cheaper
    assert dcfg.param_count() < cfg.param_count()
    # and instantiable
    T.init_params(dcfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Runtime integration: grants spent in verified tokens
# ---------------------------------------------------------------------------


def test_runtime_spends_grants_in_verified_tokens():
    import itertools

    from repro.configs.base import SpecInFConfig
    from repro.core import SpecInFRuntime
    from repro.core.profiles import dp_profile

    cfg = configs.smoke_config("olmo-1b")
    dcfg = draft_config(cfg)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = InferenceEngine(
        cfg, params, max_slots=2, max_seq=256, draft_cfg=dcfg,
        draft_params=T.init_params(dcfg, jax.random.PRNGKey(1)),
        spec=SpecDecodeConfig(mode="simulated", sim_accept_p=0.9),
    )
    for _ in range(2):
        engine.add_request(Request(prompt=np.arange(8), max_new_tokens=1000))
    rt = SpecInFRuntime(
        train_step=lambda s, b: (s, {"loss": 0.0}),
        train_state=None,
        batch_iter=itertools.repeat({}),
        profile=dp_profile("tiny", compute_s=0.05, comm_s=0.03),
        engine=engine,
        cfg=SpecInFConfig(),
        decode_microstep_s=0.004,
    )
    metrics = rt.run(num_iterations=8)
    assert metrics.spec_rounds > 0, "bubbles must admit speculative rounds"
    assert metrics.offline_tokens_generated > 0
    # speculative rounds multiply tokens per quantum: the verified yield
    # must exceed one token per round (acceptance 0.9, gamma >= 1)
    assert (
        metrics.offline_tokens_generated
        > metrics.spec_rounds * engine.max_slots * 0.5
    )
    assert engine.spec_acceptance_rate > 0.5
    assert rt.gamma_ctrl.acceptance > 0.5
