"""Flash-decode kernel validation + sync-free engine equivalence.

The Pallas decode-attention kernel (interpret mode on CPU) is asserted
against the length-masked XLA reference across GQA ratios, ragged per-slot
lengths, and empty (length=0) slots; the engine's fused ``decode_loop(k)``
must produce exactly the tokens of k sequential ``decode_microstep`` calls.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine, Request


def _inputs(b, h, kvh, s, hd, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, hd), dtype)
    return q, k, v


def _ref(q, k, v, lengths):
    """Length-masked dense decode attention (the seed path)."""
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    reps = h // kvh
    kk = jnp.repeat(k, reps, axis=2)
    vv = jnp.repeat(v, reps, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, kk).astype(jnp.float32) * hd**-0.5
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(lengths[:, None, None] > 0, p, 0.0)
    return jnp.einsum("bhs,bshd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize(
    "b,h,kvh,s,hd,block_k",
    [
        (4, 4, 2, 64, 16, 16),     # GQA 2:1, several kv tiles
        (2, 4, 4, 128, 32, 128),   # MHA, single tile
        (3, 8, 2, 96, 16, 32),     # GQA 4:1, ragged tile count
        (2, 4, 1, 80, 16, 32),     # MQA, non-multiple-of-block length
        (1, 2, 2, 48, 64, 64),     # block_k > s (clamped)
    ],
)
def test_decode_kernel_matches_reference(b, h, kvh, s, hd, block_k):
    q, k, v = _inputs(b, h, kvh, s, hd)
    # ragged lengths incl. boundary cases: empty, single, mid, full
    lengths = jnp.asarray(([0, 1, s // 3, s] * b)[:b], jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=block_k, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_ref(q, k, v, lengths)), rtol=2e-5, atol=2e-5
    )


def test_decode_kernel_empty_slot_is_zero():
    q, k, v = _inputs(2, 4, 2, 32, 16)
    lengths = jnp.array([0, 7], jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=16, interpret=True)
    assert np.all(np.asarray(out[0]) == 0.0)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_kernel_dtypes(dtype):
    q, k, v = _inputs(2, 4, 2, 64, 32, dtype=dtype)
    lengths = jnp.array([5, 64], jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=32, interpret=True)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(_ref(q, k, v, lengths), np.float32),
        rtol=tol, atol=tol,
    )


def test_ops_dispatch_pallas_equals_xla():
    q, k, v = _inputs(3, 4, 2, 64, 16, seed=3)
    lengths = jnp.array([0, 11, 64], jnp.int32)
    out_x = ops.decode_attention(q, k, v, lengths, impl="xla")
    out_p = ops.decode_attention(q, k, v, lengths, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_x), rtol=2e-5, atol=2e-5
    )


def test_attention_decode_layer_uses_fast_path():
    """layers.attention_decode with the pallas core == xla core (same cache
    updates, same outputs) across per-slot ragged indices."""
    from repro.models import layers as L

    cfg = configs.smoke_config("qwen3-1.7b")  # GQA arch
    p = L.init_attention(cfg, jax.random.PRNGKey(0), cfg.d_model, jnp.float32)
    b, s_max = 3, 32
    hd, kvh = cfg.resolved_head_dim, cfg.num_kv_heads
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model))
    kc = jax.random.normal(jax.random.PRNGKey(2), (b, s_max, kvh, hd))
    vc = jax.random.normal(jax.random.PRNGKey(3), (b, s_max, kvh, hd))
    idx = jnp.array([0, 5, 31], jnp.int32)
    y_x, (k_x, v_x) = L.attention_decode(cfg, p, x, (kc, vc), idx, impl="xla")
    y_p, (k_p, v_p) = L.attention_decode(
        cfg, p, x, (kc, vc), idx, impl="pallas"
    )
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(k_p), np.asarray(k_x))
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_x))


# ---------------------------------------------------------------------------
# Engine: fused loop == sequential microsteps
# ---------------------------------------------------------------------------


def _engine_with_requests(cfg, params, prompts, max_new, **kw):
    engine = InferenceEngine(cfg, params, max_slots=3, max_seq=32, **kw)
    reqs = [
        Request(prompt=np.asarray(p), max_new_tokens=m)
        for p, m in zip(prompts, max_new)
    ]
    for r in reqs:
        assert engine.add_request(r)
    return engine, reqs


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b"])
def test_decode_loop_equals_sequential_microsteps(arch):
    cfg = configs.smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(4), np.arange(9), np.arange(2)]
    max_new = [3, 8, 5]  # ragged budgets: slots finish mid-loop

    e1, r1 = _engine_with_requests(cfg, params, prompts, max_new)
    e2, r2 = _engine_with_requests(cfg, params, prompts, max_new)

    k = 6
    fin_seq = []
    for _ in range(k):
        fin_seq += e1.decode_microstep()
    fin_fused = e2.decode_loop(k)

    for a, b in zip(r1, r2):
        assert b.generated == a.generated[: len(b.generated)], (
            f"fused tokens diverge for prompt len {len(a.prompt)}"
        )
        # the fused loop freezes a slot exactly at its budget; the legacy
        # path overruns by one token before noticing, so fused may be one
        # shorter but never beyond the budget
        assert len(b.generated) == min(len(a.generated), b.max_new_tokens)
    fin_seq_ids = {id(r) for r in fin_seq}
    assert {r.request_id for r in fin_fused} >= {
        r2[i].request_id
        for i, a in enumerate(r1)
        if id(a) in fin_seq_ids
        and len(r2[i].generated) >= r2[i].max_new_tokens
    }
    # exactly one device->host transfer for the whole fused loop
    d2h_before = e2.d2h_transfers
    e2.decode_loop(2)
    assert e2.d2h_transfers - d2h_before <= 1


def test_decode_loop_freezes_finished_slots():
    cfg = configs.smoke_config("olmo-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine, (short, long) = _engine_with_requests(
        cfg, params, [np.arange(4), np.arange(4)], [2, 10]
    )
    finished = engine.decode_loop(8)
    finished_ids = {id(r) for r in finished}
    assert id(short) in finished_ids
    assert len(short.generated) == 2  # froze at its budget mid-loop
    assert id(long) not in finished_ids and len(long.generated) == 9
    # freed slot accepts a new request (prefill_into_slot refills the cache)
    again = Request(prompt=np.arange(5), max_new_tokens=2)
    assert engine.add_request(again)
    engine.decode_loop(2)
    assert len(again.generated) == 2


def test_prefill_bucketing_bounds_compiles():
    cfg = configs.smoke_config("olmo-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, max_slots=1, max_seq=64)
    for n in (3, 5, 7, 8, 9, 15, 17, 30):
        engine.slots = [None]
        engine.add_request(Request(prompt=np.arange(n), max_new_tokens=1))
    # 8 distinct lengths -> buckets {8, 16, 32}
    assert engine.prefill_compile_count <= 3


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-2.7b"])
def test_bucketed_prefill_exact_for_ssm_state(arch):
    """The dt-masked padded prefill must leave the recurrent SSM/conv state
    exactly where the real tokens left it: prefill-logits identical AND the
    subsequent decode trajectory identical to an unpadded prefill."""
    cfg = configs.smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab_size
    )
    logits_r, cache_r = T.prefill(cfg, params, tokens, 32)
    padded = jnp.zeros((1, 16), jnp.int32).at[:, :6].set(tokens)
    logits_p, cache_p = T.prefill(cfg, params, padded, 32, length=jnp.int32(6))
    np.testing.assert_array_equal(np.asarray(logits_r), np.asarray(logits_p))
    # observable-state check: four decode steps stay bit-identical
    tok_r = tok_p = jnp.argmax(logits_r, -1).astype(jnp.int32)
    for _ in range(4):
        l_r, cache_r = T.decode_step(cfg, params, tok_r, cache_r)
        l_p, cache_p = T.decode_step(cfg, params, tok_p, cache_p)
        np.testing.assert_array_equal(np.asarray(l_r), np.asarray(l_p))
        tok_r = jnp.argmax(l_r, -1).astype(jnp.int32)
        tok_p = jnp.argmax(l_p, -1).astype(jnp.int32)


def test_decode_microstep_single_batched_transfer():
    """The legacy microstep's finish-check indices ride in the same batched
    device->host transfer as the token batch: exactly 1 sync per step,
    independent of the number of active slots."""
    cfg = configs.smoke_config("olmo-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine, _ = _engine_with_requests(
        cfg, params, [np.arange(3), np.arange(5), np.arange(2)], [9, 9, 9]
    )
    before = engine.d2h_transfers
    engine.decode_microstep()
    assert engine.d2h_transfers - before == 1
    assert engine.num_active == 3


def test_arrival_time_stamped_from_engine_clock():
    """Default (epoch-zero) arrivals are stamped from the engine clock at
    admission; explicit arrival times are preserved — latency metrics never
    mix an epoch-zero arrival with a monotonic/virtual now."""
    cfg = configs.smoke_config("olmo-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    now = [123.0]
    engine = InferenceEngine(
        cfg, params, max_slots=2, max_seq=32, clock=lambda: now[0]
    )
    default_req = Request(prompt=np.arange(3), max_new_tokens=2)
    explicit_req = Request(prompt=np.arange(3), max_new_tokens=2,
                           arrival_time=120.5)
    assert engine.add_request(default_req)
    assert engine.add_request(explicit_req)
    assert default_req.arrival_time == 123.0
    assert explicit_req.arrival_time == 120.5
    now[0] = 125.0
    while engine.num_active:
        engine.decode_loop(2)
    assert default_req.finish_time - default_req.arrival_time == 2.0
    assert explicit_req.finish_time - explicit_req.arrival_time == 4.5
    # an ONLINE epoch-zero arrival is a real instant on a virtual clock:
    # it must survive admission so queueing delay stays in the latency
    online_req = Request(prompt=np.arange(3), max_new_tokens=1,
                         arrival_time=0.0, online=True)
    assert engine.add_request(online_req)
    assert online_req.arrival_time == 0.0


def test_add_request_rejects_overlong_prompt():
    cfg = configs.smoke_config("olmo-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, max_slots=1, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        engine.add_request(Request(prompt=np.arange(17), max_new_tokens=1))


def test_bucketed_prefill_token_matches_unpadded():
    """The first generated token must be identical whether the prompt is
    prefilled exactly or padded to its bucket."""
    cfg = configs.smoke_config("qwen3-1.7b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(5)
    logits, _ = T.prefill(
        cfg, params, jnp.asarray(prompt, jnp.int32)[None, :], 64
    )
    expect = int(jnp.argmax(logits[0]))
    engine = InferenceEngine(cfg, params, max_slots=1, max_seq=64)
    req = Request(prompt=prompt, max_new_tokens=4)
    engine.add_request(req)
    assert req.generated[0] == expect
