"""Crash-safe serving (DESIGN.md §11): write-ahead journal durability,
deterministic replay recovery, group-commit loss bounds, restamped
deadline ages, fault-counter decay, torn-checkpoint fallback, and the
warm-state snapshot round trip.

The crash model throughout is ``RequestJournal.crash()``: the process
dies, everything after the last fsync is lost (Python's userspace buffer
AND the OS page cache are both volatile), and a fresh engine replays the
surviving journal prefix."""
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.models import transformer as T
from repro.obs import Observability
from repro.obs.schema import validate_events
from repro.resilience import (
    EngineSnapshot,
    FaultInjector,
    FaultSpec,
    ProcessKilled,
    RequestJournal,
    read_journal,
)
from repro.serving.core import Grant, Priority, RequestState, SamplingParams
from repro.serving.engine import InferenceEngine

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
STEP_S = 0.002


def _engine(vnow, paged=True, start=0.0, **kw):
    vnow[0] = start
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 128)
    kw.setdefault("kv_page_size", None if paged else 0)
    kw.setdefault("obs", Observability(tracing=True))
    return InferenceEngine(CFG, PARAMS, clock=lambda: vnow[0], **kw)


def _step(core, vnow, token_budget=16):
    base = vnow[0]
    out = core.step(Grant(
        now=base, token_budget=token_budget,
        advance_clock=lambda steps, b=base: vnow.__setitem__(
            0, b + steps * STEP_S
        ),
    ))
    if out.cost_steps == 0 and not out.admitted:
        vnow[0] += STEP_S
    return out


def _drain(core, vnow, limit=400, token_budget=16):
    n = 0
    while core.has_unfinished:
        _step(core, vnow, token_budget=token_budget)
        n += 1
        assert n < limit, "core.step() made no progress"


def _submit(core, n_offline=2, n_online=3):
    rng = np.random.default_rng(0)
    reqs = [
        core.submit(
            rng.integers(0, CFG.vocab_size, 8),
            SamplingParams(max_new_tokens=12),
            priority=Priority.OFFLINE, arrival_time=0.0,
        )
        for _ in range(n_offline)
    ]
    for t in np.cumsum(rng.exponential(0.01, n_online)):
        reqs.append(core.submit(
            rng.integers(0, CFG.vocab_size, 8),
            SamplingParams(max_new_tokens=4, deadline_s=5.0),
            priority=Priority.ONLINE, arrival_time=float(t),
        ))
    return reqs


def _journal_streams(path):
    """(tokens, finish-records) per request id from the durable prefix."""
    records, _ = read_journal(path)
    toks, fins = {}, {}
    for rec in records:
        if rec["k"] == "delta":
            cur = toks.setdefault(rec["rid"], [])
            if rec["tot"] == len(cur) + len(rec["tok"]):
                cur.extend(rec["tok"])
        elif rec["k"] == "fin":
            fins.setdefault(rec["rid"], []).append(rec)
    return toks, fins


# ---------------------------------------------------------------------------
# Crash -> replay -> drain: exactly-once, byte-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense"])
def test_crash_recover_byte_identical(tmp_path, paged):
    """Kill mid-run, replay the journal into a FRESH engine, drain: every
    request finishes exactly once with the same bytes as an uninterrupted
    run — verified from the journal, the only cross-incarnation record."""
    vnow = [0.0]
    ref_core = _engine(vnow, paged=paged).core
    ref = _submit(ref_core)
    _drain(ref_core, vnow)

    path = str(tmp_path / "j.jsonl")
    vnow = [0.0]
    core = _engine(vnow, paged=paged).core
    journal = RequestJournal(path, fsync_interval=4)
    journal.attach(core)
    rid0 = _submit(core)[0].request_id
    for _ in range(5):
        _step(core, vnow)
    assert core.has_unfinished  # the crash interrupts real work
    journal.crash()

    vnow2 = [0.0]
    core2 = _engine(vnow2, paged=paged).core
    journal2 = RequestJournal(path, fsync_interval=4)
    report = journal2.recover_into(core2)
    journal2.attach(core2)
    assert report.restored + report.skipped_finished == len(ref)
    _drain(core2, vnow2)
    journal2.close()

    toks, fins = _journal_streams(path)
    for i, r in enumerate(ref):
        rid = rid0 + i
        assert fins.get(rid) is not None and len(fins[rid]) == 1, (
            f"request {rid} must reach a terminal state exactly once"
        )
        assert fins[rid][0]["rsn"] == r.finish_reason
        if r.finish_reason in ("stop", "length"):
            assert toks.get(rid, []) == list(r.output_tokens), (
                f"request {rid} recovered stream diverged"
            )


def test_kill_during_prefilling(tmp_path):
    """A long prompt mid-chunked-prefill at the crash re-enters as
    PREEMPTED and re-prefills to a byte-identical stream."""
    prompt = np.arange(96) % CFG.vocab_size
    sp = SamplingParams(max_new_tokens=6)

    vnow = [0.0]
    ref_core = _engine(vnow).core
    ref = ref_core.submit(prompt, sp, arrival_time=0.0)
    _drain(ref_core, vnow, token_budget=16)

    path = str(tmp_path / "j.jsonl")
    vnow = [0.0]
    core = _engine(vnow).core
    journal = RequestJournal(path, fsync_interval=1)
    journal.attach(core)
    r = core.submit(prompt, sp, arrival_time=0.0)
    _step(core, vnow, token_budget=16)  # 96-token prompt >> 16-token grant
    assert r.state is RequestState.PREFILLING
    journal.crash()

    vnow2 = [0.0]
    core2 = _engine(vnow2).core
    journal2 = RequestJournal(path, fsync_interval=1)
    report = journal2.recover_into(core2)
    journal2.attach(core2)
    assert report.resumed_inflight == 1
    cr = core2.requests[r.request_id]
    assert cr.state is RequestState.PREEMPTED
    _drain(core2, vnow2, token_budget=16)
    assert cr.finish_reason == ref.finish_reason
    assert list(cr.output_tokens) == list(ref.output_tokens)


def test_retry_at_survives_restore(tmp_path):
    """A quarantined request's backoff gate and fault count carry across
    the crash (shifted onto the restored clock) — a restart must not
    reset a request's retry budget or let it jump its backoff."""
    path = str(tmp_path / "j.jsonl")
    inj = FaultInjector(seed=3, specs=(
        FaultSpec("engine/nan_logits", probability=1.0, max_fires=1),
    ))
    vnow = [0.0]
    core = _engine(vnow, fault_injector=inj).core
    core.fault_backoff_s = 50.0  # backoff far beyond the drain horizon
    journal = RequestJournal(path, fsync_interval=1)
    journal.attach(core)
    r = core.submit(np.arange(6), SamplingParams(max_new_tokens=8),
                    arrival_time=0.0)
    for _ in range(6):
        _step(core, vnow)
    assert inj.total_fires == 1
    assert r.faults == 1 and r.retry_at > vnow[0]
    pre_crash_gap = r.retry_at - vnow[0]
    journal.crash()

    vnow2 = [100.0]
    core2 = _engine(vnow2, start=100.0).core
    journal2 = RequestJournal(path, fsync_interval=1)
    report = journal2.recover_into(core2)
    assert report.resumed_inflight == 1
    cr = core2.requests[r.request_id]
    assert cr.faults == 1
    # shifted, not reset: the remaining backoff is preserved on the new
    # clock (the journal stamped retry_at after the last delta, so the
    # surviving gap can only be >= what the dead process last observed)
    assert cr.retry_at - 100.0 >= pre_crash_gap - 1e-9
    assert cr.retry_at > 100.0


def test_group_commit_loss_window(tmp_path):
    """A crash loses AT MOST the configured group-commit interval of
    records — asserted, not assumed."""
    path = str(tmp_path / "j.jsonl")
    interval = 8
    journal = RequestJournal(path, fsync_interval=interval)
    durable_before = len(read_journal(path)[0])  # meta is fsync'd eagerly
    for i in range(interval - 3):
        journal._append({"k": "tr", "rid": i, "t": 0.0, "st": "waiting",
                         "f": 0, "ra": 0.0})
    pending = journal.pending_records
    assert 0 < pending < interval
    journal.crash()
    records, torn = read_journal(path)
    assert torn == 0
    lost = durable_before + (interval - 3) - len(records)
    assert lost == pending
    assert lost <= interval


def test_fsync_interval_bounds_pending(tmp_path):
    """The group-commit policy fsyncs automatically every N appends, so
    the loss window can never exceed N."""
    journal = RequestJournal(str(tmp_path / "j.jsonl"), fsync_interval=4)
    for i in range(23):
        journal._append({"k": "tr", "rid": i, "t": 0.0, "st": "waiting",
                         "f": 0, "ra": 0.0})
        assert journal.pending_records < 4
    journal.close()


def test_double_restore_idempotent(tmp_path):
    """Replaying the same journal into a core that already holds the
    requests restores nothing new (skipped_present), and replay never
    duplicates a durably-finished request."""
    path = str(tmp_path / "j.jsonl")
    vnow = [0.0]
    core = _engine(vnow).core
    journal = RequestJournal(path, fsync_interval=1)
    journal.attach(core)
    _submit(core)
    for _ in range(4):
        _step(core, vnow)
    journal.crash()

    vnow2 = [0.0]
    core2 = _engine(vnow2).core
    journal2 = RequestJournal(path, fsync_interval=1)
    first = journal2.recover_into(core2)
    assert first.restored > 0
    again = journal2.recover_into(core2)
    assert again.restored == 0
    assert again.skipped_present == first.restored
    assert again.skipped_finished == first.skipped_finished
    # queues were not double-populated
    depth = sum(len(q) for q in core2.waiting.values())
    assert depth == first.restored


def test_deadline_ages_not_reset(tmp_path):
    """Restamping preserves each request's CONSUMED deadline age: after a
    restart far in the future no queue mass-expires (ages carry over,
    budgets don't vanish) and ages don't silently reset either."""
    path = str(tmp_path / "j.jsonl")
    vnow = [0.0]
    core = _engine(vnow).core
    journal = RequestJournal(path, fsync_interval=1)
    journal.attach(core)
    reqs = _submit(core)  # online requests carry deadline_s=5.0
    for _ in range(3):
        _step(core, vnow)
    aged = vnow[0]
    journal.crash()

    # the new process comes up with a clock far past every old deadline
    vnow2 = [1000.0]
    core2 = _engine(vnow2, start=1000.0).core
    journal2 = RequestJournal(path, fsync_interval=1)
    report = journal2.recover_into(core2)
    journal2.attach(core2)
    assert report.restored > 0
    for rid, cr in core2.requests.items():
        old = next(r for r in reqs if r.request_id == rid)
        age_before = aged - old.arrival_time
        age_after = vnow2[0] - cr.arrival_time
        assert age_after == pytest.approx(age_before, abs=1e-9)
    _drain(core2, vnow2)
    m = core2.obs.metrics
    assert m.counter("core/finish_reason/expired").value == 0, (
        "restored requests mass-expired — deadline budgets were not "
        "restamped onto the restored clock"
    )


def test_recovery_trace_schema_and_attribution(tmp_path):
    """The recovery span and arrival_restamp instants validate against
    the pinned schema, and SLO attribution still telescopes after replay
    (restamped arrivals may be negative — that is schema-legal)."""
    path = str(tmp_path / "j.jsonl")
    vnow = [0.0]
    core = _engine(vnow).core
    journal = RequestJournal(path, fsync_interval=1)
    journal.attach(core)
    _submit(core)
    for _ in range(4):
        _step(core, vnow)
    journal.crash()

    vnow2 = [0.0]
    core2 = _engine(vnow2).core
    journal2 = RequestJournal(path, fsync_interval=1)
    report = journal2.recover_into(core2)
    journal2.attach(core2)
    assert report.restored > 0
    _drain(core2, vnow2)
    tr = core2.obs.tracer
    events = [ev for ev in tr.events]
    spans = [ev for ev in events
             if ev.get("type") == "span" and ev.get("name") == "recovery"]
    restamps = [ev for ev in events
                if ev.get("type") == "instant"
                and ev.get("name") == "arrival_restamp"]
    assert len(spans) == 1
    assert spans[0]["args"]["requests"] == report.restored
    assert len(restamps) == report.restored
    assert validate_events(events) == []
    att = tr.attribution()
    for ra in att.values():
        if ra.finish_time is not None:
            assert abs(
                ra.total - (ra.finish_time - ra.arrival_time)
            ) < 1e-6


def test_runtime_rearms_bubble_filling_from_journal(tmp_path):
    """A restarted ``SpecInFRuntime`` given the dead incarnation's journal
    replays it before fresh submissions and serves the survivors inside
    training bubbles."""
    import itertools

    from repro.configs.base import SpecInFConfig
    from repro.core import SpecInFRuntime
    from repro.core.profiles import dp_profile

    path = str(tmp_path / "j.jsonl")
    vnow = [0.0]
    core = _engine(vnow).core
    journal = RequestJournal(path, fsync_interval=1)
    journal.attach(core)
    _submit(core)
    for _ in range(3):
        _step(core, vnow)
    journal.crash()

    vnow2 = [0.0]
    engine2 = _engine(vnow2)
    journal2 = RequestJournal(path, fsync_interval=1)
    rt = SpecInFRuntime(
        train_step=lambda state, batch: (state, {"loss": 0.0}),
        train_state={}, batch_iter=itertools.repeat({}),
        profile=dp_profile("tiny", compute_s=0.03, comm_s=0.04),
        engine=engine2, cfg=SpecInFConfig(), decode_microstep_s=0.002,
        journal=journal2,
    )
    assert rt.recovery is not None and rt.recovery.restored > 0
    assert rt.core.journal is journal2  # this incarnation journals in turn
    rt.run(num_iterations=10)
    finished = sum(
        1 for cr in rt.core.requests.values() if cr.state.finished
    )
    m = engine2.obs.metrics
    assert m.counter("recovery/restores").value == 1
    assert finished > 0, (
        "bubble filling never finished a journal-restored request"
    )


def test_process_kill_fault_point():
    """The injected process death raises OUT of step() (nothing absorbs
    it) and is armed like any other seeded fault point."""
    vnow = [0.0]
    inj = FaultInjector(seed=1, specs=(
        FaultSpec("process/kill", probability=1.0, max_fires=1),
    ))
    core = _engine(vnow, fault_injector=inj).core
    core.submit(np.arange(6), SamplingParams(max_new_tokens=4),
                arrival_time=0.0)
    with pytest.raises(ProcessKilled):
        for _ in range(10):
            _step(core, vnow)
    assert inj.total_fires == 1


# ---------------------------------------------------------------------------
# Satellite: fault-counter decay (serving fairness)
# ---------------------------------------------------------------------------


def test_fault_decay_earns_retry_budget_back():
    """A long-lived request whose retry budget is already spent must earn
    it back after ``fault_decay_quanta`` consecutive clean quanta, so ONE
    more transient fault late in its life quarantines-and-retries instead
    of escalating to FINISHED_ERROR.  The control run (decay disabled)
    shows the old lifetime-counter unfairness: the same single late fault
    kills the request."""
    def run(decay_quanta):
        vnow = [0.0]
        # one late fault, long after the request has decoded cleanly
        # (token_budget=2 -> one fused dispatch == one consultation per
        # quantum, so ``after`` spaces the fault in clean-quantum units)
        inj = FaultInjector(seed=7, specs=(
            FaultSpec("engine/nan_logits", probability=1.0, after=12,
                      max_fires=1),
        ))
        core = _engine(vnow, fault_injector=inj, max_slots=1).core
        core.fault_backoff_s = 0.0
        core.fault_decay_quanta = decay_quanta
        r = core.submit(np.arange(6), SamplingParams(max_new_tokens=48),
                        arrival_time=0.0)
        r.faults = core.max_fault_retries  # budget spent early in life
        _drain(core, vnow, limit=800, token_budget=2)
        return r, core, inj

    r, core, inj = run(8)
    assert inj.total_fires == 1
    assert r.state is not RequestState.FINISHED_ERROR, (
        "a late transient fault escalated to FINISHED_ERROR despite the "
        "clean-quanta decay"
    )
    assert core.obs.metrics.counter("fault/decays").value >= 1
    r0, core0, inj0 = run(0)  # decay disabled: lifetime counter is unfair
    assert inj0.total_fires == 1
    assert r0.state is RequestState.FINISHED_ERROR
    assert core0.obs.metrics.counter("fault/decays").value == 0


# ---------------------------------------------------------------------------
# Satellite: torn-checkpoint fallback
# ---------------------------------------------------------------------------


def _state(val=1.0):
    return {"w": np.full((4, 4), val, np.float32)}


def test_checkpoint_restore_skips_torn_saves(tmp_path):
    """A crash mid-save leaves a torn step directory; restore must fall
    back to the newest VALID checkpoint instead of failing (or worse,
    loading garbage)."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1.0))

    # torn variant A: step dir without a manifest (killed before rename
    # machinery finished) — already invisible to all_steps
    os.makedirs(tmp_path / "step_00000002")
    # torn variant B: manifest present but complete:false
    d3 = tmp_path / "step_00000003"
    os.makedirs(d3)
    (d3 / "manifest.json").write_text('{"step": 3, "complete": false}')
    # torn variant C: valid manifest, corrupt arrays file
    d4 = tmp_path / "step_00000004"
    os.makedirs(d4)
    np.savez(d4 / "arrays.npz", **{"0": np.zeros(1)})
    raw = (d4 / "arrays.npz").read_bytes()
    (d4 / "arrays.npz").write_bytes(raw[: len(raw) // 2])  # truncate
    (d4 / "manifest.json").write_text(
        '{"step": 4, "complete": true, "leaves": 1}'
    )

    restored, step = ck.restore(_state(0.0))
    assert step == 1
    np.testing.assert_allclose(restored["w"], 1.0)
    # explicit-step restore falls back below the torn step too
    restored, step = ck.restore(_state(0.0), step=4)
    assert step == 1


def test_checkpoint_save_fsyncs_files_and_dirs(tmp_path, monkeypatch):
    """The save path must fsync payload, manifest, and the directories —
    rename-into-place alone is not durable."""
    calls = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: (calls.append(fd),
                                                 real_fsync(fd))[1])
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(2.0))
    # arrays.npz + manifest + tmp dir + parent dir
    assert len(calls) >= 4
    restored, step = ck.restore(_state(0.0))
    assert step == 1
    np.testing.assert_allclose(restored["w"], 2.0)


def test_checkpoint_restore_all_torn_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    d1 = tmp_path / "step_00000001"
    os.makedirs(d1)
    (d1 / "manifest.json").write_text(
        '{"step": 1, "complete": true, "leaves": 1}'
    )  # manifest OK, arrays.npz missing entirely
    with pytest.raises(FileNotFoundError):
        ck.restore(_state(0.0))


# ---------------------------------------------------------------------------
# Warm-state snapshot
# ---------------------------------------------------------------------------


def test_snapshot_round_trip_warms_prefix_cache(tmp_path):
    """Snapshot the radix cache, restore into a COLD engine: resubmitted
    prompts hit the warmed prefix pages (prefill skipped) and decode
    byte-identically."""
    prompt = np.arange(32) % CFG.vocab_size
    sp = SamplingParams(max_new_tokens=4)

    vnow = [0.0]
    engine = _engine(vnow, kv_page_size=8)
    core = engine.core
    ref = core.submit(prompt, sp, arrival_time=0.0)
    _drain(core, vnow)
    ck = Checkpointer(str(tmp_path / "snap"))
    snap = EngineSnapshot(engine, ck)
    assert snap.save() is True

    vnow2 = [0.0]
    engine2 = _engine(vnow2, kv_page_size=8)
    snap2 = EngineSnapshot(engine2, Checkpointer(str(tmp_path / "snap")))
    loaded = snap2.restore()
    assert loaded > 0
    core2 = engine2.core
    m0 = engine2.obs.metrics.counter("engine/prefill_skipped_tokens").value
    r2 = core2.submit(prompt, sp, arrival_time=0.0)
    _drain(core2, vnow2)
    skipped = (
        engine2.obs.metrics.counter("engine/prefill_skipped_tokens").value
        - m0
    )
    # every reusable page came from the warmed cache: 3 of 4 pages — the
    # final position is always recomputed to produce the first logits
    assert skipped == 24
    assert list(r2.output_tokens) == list(ref.output_tokens)


def test_snapshot_discarded_when_it_outran_the_journal(tmp_path):
    """A snapshot whose journal watermark exceeds the surviving journal
    length (its tail died in the crash) must be discarded — warm state
    stays a strict subset of journaled truth."""
    path = str(tmp_path / "j.jsonl")
    vnow = [0.0]
    engine = _engine(vnow, kv_page_size=8)
    core = engine.core
    journal = RequestJournal(path, fsync_interval=1)
    journal.attach(core)
    core.submit(np.arange(32) % CFG.vocab_size,
                SamplingParams(max_new_tokens=4), arrival_time=0.0)
    _drain(core, vnow)
    ck = Checkpointer(str(tmp_path / "snap"))
    assert EngineSnapshot(engine, ck, journal=journal).save() is True
    journal.close()
    # the crash erases journal bytes the snapshot's watermark counted on
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)

    vnow2 = [0.0]
    engine2 = _engine(vnow2, kv_page_size=8)
    journal2 = RequestJournal(path, fsync_interval=1)
    snap2 = EngineSnapshot(
        engine2, Checkpointer(str(tmp_path / "snap")), journal=journal2
    )
    assert snap2.restore() == 0
    m = engine2.obs.metrics
    assert m.counter("recovery/snapshot_discarded").value == 1
