"""Chunked prefill unified with decode (DESIGN.md §7).

Four layers of validation:

* kernel: the ragged prefill-attention kernel (dense + paged, XLA fallback
  AND Pallas interpret mode, the ``test_paged_kv`` CI pattern) equals an
  independent masked-softmax reference on random ragged chunks;
* engine: chunked-prefill greedy streams are byte-identical to monolithic
  prefill across dense/paged × spec on/off, with chunk boundaries landing
  mid-page and at page edges, and the program zoo pinned to one fixed-width
  compile per model;
* core: token-budgeted steps stream prompts through WAITING -> PREFILLING
  -> RUNNING without ever exceeding the granted mixed-batch budget, and
  produce the same stream as a permissive run;
* lifecycle: preempt during PREFILLING resumes byte-identically.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import draft_config
from repro.kernels import ops
from repro.models import transformer as T
from repro.serving.core import (
    Grant,
    PriorityPolicy,
    RequestState,
    SamplingParams,
)
from repro.serving.engine import InferenceEngine, Request

CFG = configs.smoke_config("qwen3-1.7b")
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
DCFG = draft_config(CFG)
DPARAMS = T.init_params(DCFG, jax.random.PRNGKey(5))


# ---------------------------------------------------------------------------
# Kernel: ragged chunk attention == independent reference
# ---------------------------------------------------------------------------


def _reference(q, k, v, starts, lens):
    """Masked-softmax reference: row t attends kpos <= starts + t, rows
    past chunk_lens are zeros."""
    b, c, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    kk = jnp.broadcast_to(
        k[:, :, :, None], (b, s, kvh, g, hd)
    ).reshape(b, s, h, hd)
    vv = jnp.broadcast_to(
        v[:, :, :, None], (b, s, kvh, g, hd)
    ).reshape(b, s, h, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32)
    scores = scores * hd**-0.5
    kpos = jnp.arange(s)
    bound = starts[:, None] + jnp.arange(c)[None, :]
    valid = jnp.arange(c)[None, :] < lens[:, None]
    mask = (kpos[None, None, :] <= bound[:, :, None]) & valid[:, :, None]
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    return jnp.where(valid[:, :, None, None], out, 0.0)


def _paged_from_dense(k, v, page, rng):
    b, s, kvh, hd = k.shape
    npages = s // page
    pool_n = 1 + b * npages
    perm = rng.permutation(np.arange(1, pool_n))
    bt = perm.reshape(b, npages)
    k_pool = np.zeros((pool_n, page, kvh, hd), np.float32)
    v_pool = np.zeros((pool_n, page, kvh, hd), np.float32)
    for i in range(b):
        for j in range(npages):
            k_pool[bt[i, j]] = np.asarray(k[i, j * page:(j + 1) * page])
            v_pool[bt[i, j]] = np.asarray(v[i, j * page:(j + 1) * page])
    bt = np.concatenate([bt, np.zeros((b, 1), np.int64)], axis=1)
    return (jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(bt, jnp.int32))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_prefill_chunk_matches_reference(impl):
    """Property (seeded sweep): ragged chunked-prefill attention equals the
    reference on random starts / chunk lengths, including empty chunks,
    single-token chunks, and chunks wider than the remaining prefix."""
    geoms = [(4, 2, 16), (4, 4, 16), (2, 1, 32)]
    for seed in range(10):
        rng = np.random.RandomState(2000 + seed)
        h, kvh, hd = geoms[seed % len(geoms)]
        b = rng.randint(1, 4)
        c = int(rng.choice([8, 16, 24, 40]))
        s = int(rng.choice([64, 96, 128]))
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (b, c, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kvh, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kvh, hd), jnp.float32)
        picks = [0, 1, c, c - 1]
        lens = jnp.asarray(
            [picks[rng.randint(0, 4)] if rng.rand() < 0.5
             else rng.randint(0, c + 1) for _ in range(b)], jnp.int32)
        starts = jnp.asarray(
            [rng.randint(0, s - c + 1) for _ in range(b)], jnp.int32)
        ref = _reference(q, k, v, starts, lens)
        out = ops.prefill_chunk_attention(q, k, v, starts, lens, impl=impl)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5,
            err_msg=f"seed={seed} b={b} c={c} s={s} "
                    f"starts={np.asarray(starts)} lens={np.asarray(lens)}",
        )


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_prefill_chunk_matches_dense(impl):
    """Property (seeded sweep): the block-table prefill kernel equals the
    dense one under randomly-permuted physical page placement."""
    geoms = [(4, 2, 16), (8, 2, 32), (2, 1, 16)]
    for seed in range(8):
        rng = np.random.RandomState(3000 + seed)
        h, kvh, hd = geoms[seed % len(geoms)]
        b = rng.randint(1, 4)
        c = int(rng.choice([8, 16, 24]))
        page = int(rng.choice([8, 16]))
        s = page * rng.randint(3, 7)
        if s < c:
            s = page * (-(-c // page) + 1)
        ks = jax.random.split(jax.random.PRNGKey(100 + seed), 3)
        q = jax.random.normal(ks[0], (b, c, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kvh, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kvh, hd), jnp.float32)
        lens = jnp.asarray([rng.randint(0, c + 1) for _ in range(b)],
                           jnp.int32)
        starts = jnp.asarray([rng.randint(0, s - c + 1) for _ in range(b)],
                             jnp.int32)
        k_pool, v_pool, bt = _paged_from_dense(k, v, page, rng)
        ref = ops.prefill_chunk_attention(q, k, v, starts, lens, impl="xla")
        out = ops.paged_prefill_chunk_attention(
            q, k_pool, v_pool, bt, starts, lens, impl=impl
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5,
            err_msg=f"seed={seed} b={b} c={c} page={page} s={s} "
                    f"starts={np.asarray(starts)} lens={np.asarray(lens)}",
        )


# ---------------------------------------------------------------------------
# Engine: chunked streams == monolithic streams
# ---------------------------------------------------------------------------


def _drain(engine, k=4, guard=200):
    while engine.num_active and guard:
        engine.decode_loop(k)
        guard -= 1
    assert engine.num_active == 0


#: ragged prompts; 33 crosses two pages, 47 ends mid-page, one request
#: hits the sequence horizon
CASES = [(5, 12), (17, 7), (33, 20), (47, 9)]


def _run_engine(paged, chunk, spec=False, cases=CASES):
    kw: dict = {"kv_page_size": None if paged else 0, "prefill_chunk": chunk}
    if spec:
        kw.update(draft_cfg=DCFG, draft_params=DPARAMS,
                  compute_dtype=jnp.float32)
    eng = InferenceEngine(CFG, PARAMS, max_slots=4, max_seq=64, **kw)
    reqs = [Request(prompt=np.arange(1, n + 1), max_new_tokens=m)
            for n, m in cases]
    for r in reqs:
        assert eng.add_request(r)
    if spec:
        guard = 100
        while eng.num_active and guard:
            eng.spec_decode_loop(2, 2)
            guard -= 1
        assert eng.num_active == 0
    else:
        _drain(eng)
    return [r.generated for r in reqs], eng


@pytest.mark.parametrize("spec", [False, True], ids=["plain", "spec"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("chunk", [8, 16, 24])
def test_chunked_stream_byte_identical_to_monolithic(paged, spec, chunk):
    """The acceptance property: greedy streams are byte-identical whether
    the prompt prefilled monolithically or streamed in chunks — across
    dense/paged layouts, spec on/off, and chunk widths that land on page
    edges (16), mid-page (24 with page 16), and below a page (8)."""
    mono, _ = _run_engine(paged, 0, spec)
    chunked, eng = _run_engine(paged, chunk, spec)
    assert chunked == mono
    counts = eng.prefill_compile_counts()
    assert counts["target/chunk"] == 1
    assert "target/bucket" not in counts  # the bucket zoo is gone
    if spec:
        assert counts["draft/chunk"] == 1
        assert "draft/bucket" not in counts  # one wave, no per-req dispatch


def test_chunked_prefix_hit_skips_and_matches():
    """Prefix sharing composes with chunking: the radix-covered prefix is
    skipped (zero prefill FLOPs, counter-verified) and the stream equals
    both the cold chunked run and a monolithic engine's."""
    eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=64,
                          prefill_chunk=8)
    prompt = np.arange(1, 40)
    cold = Request(prompt=prompt, max_new_tokens=10)
    assert eng.add_request(cold)
    _drain(eng)
    assert eng.prefill_skipped_tokens == 0
    assert eng.prefix_cache.pages_cached == 2
    warm = Request(prompt=prompt, max_new_tokens=10)
    assert eng.add_request(warm)
    assert eng.prefill_skipped_tokens == 32
    _drain(eng)
    assert warm.generated == cold.generated
    mono, _ = _run_engine(True, 0, cases=[(39, 10)])
    assert cold.generated == mono[0]


def test_chunked_pool_accounting_clean_after_drain():
    """Pages, reservations, and radix refcounts settle exactly as the
    monolithic path's: nothing leaks across chunk waves or completions."""
    _, eng = _run_engine(True, 8)
    assert eng.pool.pages_in_use == eng.prefix_cache.pages_cached
    assert eng.pool.reserved == 0
    _, eng = _run_engine(True, 16, spec=True)
    assert eng.pool.pages_in_use == eng.prefix_cache.pages_cached
    assert eng.pool.reserved == 0


# ---------------------------------------------------------------------------
# Core: token-budgeted streaming through PREFILLING
# ---------------------------------------------------------------------------


def _core_engine(**kw):
    kw.setdefault("prefill_chunk", 8)
    eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=128, **kw)
    return eng, eng.core


def test_budgeted_steps_stream_prefilling_state():
    """A token budget below the prompt length forces PREFILLING to span
    steps; the final stream equals the permissive run and no step's mixed
    batch (prefill chunk tokens + generated tokens) exceeds the budget."""
    def run(budget):
        eng, core = _core_engine()
        r = core.submit(np.arange(1, 50), SamplingParams(max_new_tokens=6))
        states, max_step_tokens, steps = [], 0, 0
        while core.has_unfinished:
            g0 = eng.generated_tokens_total
            out = core.step(Grant(token_budget=budget))
            states.append(r.state)
            max_step_tokens = max(
                max_step_tokens,
                out.prefill_tokens + (eng.generated_tokens_total - g0),
            )
            steps += 1
            assert steps < 100, "budgeted stream stalled"
        return r.output_tokens, states, max_step_tokens

    toks_inf, states_inf, _ = run(math.inf)
    toks_b, states_b, max_tokens = run(16)
    assert toks_b == toks_inf
    assert max_tokens <= 16  # the grant is a hard mixed-batch ceiling
    assert RequestState.PREFILLING in states_b
    assert RequestState.PREFILLING not in states_inf  # one-quantum prefill


def test_prefill_cost_charges_the_virtual_clock():
    """With a profiled per-token cost, streaming a prompt advances the
    virtual clock in proportion to the tokens streamed — the bubble-
    deadline accounting SpecInFPolicy's grants rely on."""
    vnow = [0.0]
    eng, core = _core_engine(clock=lambda: vnow[0])
    core.policy = PriorityPolicy(prefill_token_cost_steps=0.125)
    r = core.submit(np.arange(1, 33), SamplingParams(max_new_tokens=1))
    out = core.step(Grant(
        token_budget=math.inf,
        advance_clock=lambda steps: vnow.__setitem__(0, vnow[0] + steps),
    ))
    # 32 prefill tokens * 0.125 steps/token = 4 steps of prefill cost,
    # plus the decode quantum the policy planned
    assert out.prefill_tokens == 32
    assert out.cost_steps >= 4.0
    assert vnow[0] == out.cost_steps
    assert r.state.finished or r.state is RequestState.RUNNING


def test_preempt_during_prefilling_resumes_byte_identical():
    """Eviction mid-PREFILLING drops the pending chunk streams; resume
    re-enters PREFILLING from the radix-covered prefix and the final
    stream is byte-identical to an uninterrupted run."""
    def run(preempt_at):
        eng, core = _core_engine()
        r = core.submit(np.arange(1, 50), SamplingParams(max_new_tokens=6))
        steps = 0
        preempted = 0
        while core.has_unfinished:
            core.step(Grant(token_budget=16))
            steps += 1
            if steps == preempt_at and r.state is RequestState.PREFILLING:
                assert core.preempt(r) is r
                assert r.state is RequestState.PREEMPTED
                preempted += 1
            assert steps < 120
        return r.output_tokens, preempted, r.preemptions

    base, _, _ = run(10**9)
    resumed, hit, count = run(2)
    assert hit == 1 and count == 1
    assert resumed == base


def test_abort_during_prefilling_releases_slot():
    eng, core = _core_engine()
    r = core.submit(np.arange(1, 50), SamplingParams(max_new_tokens=6))
    core.step(Grant(token_budget=8))
    assert r.state is RequestState.PREFILLING
    core.abort(r)
    assert r.state is RequestState.FINISHED_ABORTED
    assert eng.num_active == 0
    assert eng.pool.reserved == 0
    assert eng.num_prefilling == 0


def test_mixed_step_decodes_running_while_prefilling():
    """The unified step: a RUNNING slot keeps decoding in the same quanta
    that stream another slot's prompt chunks — and the decode stream is
    unaffected by the concurrent prefill traffic."""
    eng, core = _core_engine()
    short = core.submit(np.arange(1, 6), SamplingParams(max_new_tokens=12))
    core.step(Grant(token_budget=math.inf))  # short is RUNNING
    assert short.state is RequestState.RUNNING
    core.submit(np.arange(1, 49), SamplingParams(max_new_tokens=4))  # long
    saw_overlap = False
    steps = 0
    while core.has_unfinished:
        out = core.step(Grant(token_budget=12))
        if out.prefill_tokens and out.k:
            saw_overlap = True
        steps += 1
        assert steps < 200
    assert saw_overlap, "no step mixed prefill chunks with decode"
    # reference: the same short request alone, no concurrent prefill
    ref_eng = InferenceEngine(CFG, PARAMS, max_slots=2, max_seq=128,
                              prefill_chunk=8)
    ref = Request(prompt=np.arange(1, 6), max_new_tokens=12)
    assert ref_eng.add_request(ref)
    _drain(ref_eng)
    assert short.output_tokens == ref.generated


def test_spec_draft_index_survives_mixed_spec_steps():
    """Regression: the fused speculative loop pins frozen slots' draft
    index to their target index; mid-prefill the two streams differ, so a
    spec quantum running beside a PREFILLING slot must not corrupt its
    draft progress (the stream would silently diverge)."""
    eng = InferenceEngine(
        CFG, PARAMS, max_slots=2, max_seq=128, prefill_chunk=8,
        draft_cfg=DCFG, draft_params=DPARAMS, compute_dtype=jnp.float32,
    )
    core = eng.core
    short = core.submit(np.arange(1, 6), SamplingParams(max_new_tokens=10))
    core.step(Grant(token_budget=math.inf))
    long = core.submit(np.arange(1, 49), SamplingParams(max_new_tokens=4))
    steps = 0
    while core.has_unfinished:
        core.step(Grant(token_budget=10))
        steps += 1
        assert steps < 200
    # reference: same requests, monolithic spec engine, sequential
    ref_eng = InferenceEngine(
        CFG, PARAMS, max_slots=2, max_seq=128, prefill_chunk=0,
        draft_cfg=DCFG, draft_params=DPARAMS, compute_dtype=jnp.float32,
    )
    r1 = Request(prompt=np.arange(1, 6), max_new_tokens=10)
    r2 = Request(prompt=np.arange(1, 49), max_new_tokens=4)
    assert ref_eng.add_request(r1) and ref_eng.add_request(r2)
    guard = 100
    while ref_eng.num_active and guard:
        ref_eng.spec_decode_loop(2, 2)
        guard -= 1
    assert short.output_tokens == r1.generated
    assert long.output_tokens == r2.generated
