"""Property tests for the collocation planner (paper §3.2 Principles I/II)."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs.base import SpecInFConfig
from repro.core import InstanceProfile, TrainingProfile, plan_collocation

GiB = 1024**3


def _training(mem=8 * GiB, bubble=0.3):
    return TrainingProfile(
        name="t", peak_memory_bytes=mem, iteration_time_s=1.0,
        max_bubble_s=bubble,
    )


def test_accepts_until_budget_exhausted():
    cfg = SpecInFConfig(hbm_limit_bytes=16 * GiB, max_instances=8)
    cands = [InstanceProfile(f"i{k}", 3 * GiB) for k in range(4)]
    plan = plan_collocation(_training(8 * GiB), cands, cfg)
    assert plan.num_instances == 2  # 8 + 3 + 3 <= 16, third would be 17
    assert plan.total_memory_bytes <= cfg.hbm_limit_bytes
    assert len(plan.rejected) == 2


def test_principle2_gates_online_only():
    cfg = SpecInFConfig(hbm_limit_bytes=16 * GiB)
    slow_online = InstanceProfile("slow", GiB, min_exec_time_s=0.5, online=True)
    slow_offline = InstanceProfile("batch", GiB, min_exec_time_s=0.5, online=False)
    plan = plan_collocation(_training(bubble=0.3), [slow_online, slow_offline], cfg)
    names = [i.name for i in plan.accepted]
    assert "batch" in names  # offline exempt from Principle-II
    assert "slow" not in names
    assert any("Principle-II" in r for _, r in plan.rejected)


def test_oversized_training_raises():
    cfg = SpecInFConfig(hbm_limit_bytes=16 * GiB)
    with pytest.raises(ValueError):
        plan_collocation(_training(mem=17 * GiB), [], cfg)


@given(
    train_mem=st.integers(min_value=1, max_value=15),
    cand_mems=st.lists(st.integers(min_value=1, max_value=8), max_size=12),
    max_instances=st.integers(min_value=1, max_value=8),
    bubble_ms=st.integers(min_value=1, max_value=500),
    exec_ms=st.lists(st.integers(min_value=1, max_value=600), max_size=12),
)
@settings(max_examples=200, deadline=None)
def test_plan_invariants(train_mem, cand_mems, max_instances, bubble_ms, exec_ms):
    """For any candidate set:
    * Principle-I: total accepted memory never exceeds the HBM limit
    * accepted count never exceeds max_instances
    * every online accepted instance satisfies Principle-II
    * accepted + rejected == candidates (nothing lost)
    """
    cfg = SpecInFConfig(hbm_limit_bytes=16 * GiB, max_instances=max_instances)
    training = _training(mem=train_mem * GiB, bubble=bubble_ms / 1e3)
    cands = []
    for i, mem in enumerate(cand_mems):
        ex = exec_ms[i % len(exec_ms)] / 1e3 if exec_ms else 0.001
        cands.append(
            InstanceProfile(f"c{i}", mem * GiB, min_exec_time_s=ex,
                            online=(i % 2 == 0))
        )
    plan = plan_collocation(training, cands, cfg)
    assert plan.total_memory_bytes <= cfg.hbm_limit_bytes
    assert plan.num_instances <= max_instances
    for inst in plan.accepted:
        if inst.online:
            assert inst.min_exec_time_s < training.max_bubble_s
    assert len(plan.accepted) + len(plan.rejected) == len(cands)


def test_planner_with_real_profiles():
    """End-to-end: analytic profiles of assigned archs against v5e HBM."""
    from repro import configs
    from repro.core.hardware import V5E
    from repro.core.profiles import analytic_inference_profile, analytic_iteration

    train_cfg = configs.get_config("qwen2-7b")
    prof = analytic_iteration(
        train_cfg, seq_len=4096, per_device_batch=16, num_devices=16,
        mode="dp", hw=V5E,
    )
    infer_cfg = configs.get_config("qwen3-1.7b")
    inst = analytic_inference_profile(
        infer_cfg, batch=8, seq_or_context=2048, hw=V5E, online=True,
    )
    # qwen2-7b fp32 training state is far over one v5e chip; model the
    # per-chip slice (TP16 + fsdp + zero1 from the dry-run memory stats)
    training = prof.as_training_profile(peak_memory_bytes=6 * GiB)
    plan = plan_collocation(training, [inst] * 4, SpecInFConfig())
    assert plan.num_instances >= 1
    assert plan.total_memory_bytes <= SpecInFConfig().hbm_limit_bytes
