"""Integration tests for the production train step builder on a 1x1 dev
mesh: loss descent, microbatch equivalence, fault-tolerant resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticDataset
from repro.launch.mesh import make_dev_mesh
from repro.runtime.step import make_train_step


@pytest.fixture(scope="module")
def mesh():
    return make_dev_mesh(data=1, model=1)


def _batch(ds):
    b = ds.next_batch()
    return {"inputs": jnp.asarray(b["inputs"]), "labels": jnp.asarray(b["labels"])}


def test_loss_decreases_over_steps(mesh):
    cfg = configs.smoke_config("qwen3-1.7b")
    tcfg = TrainConfig(
        learning_rate=3e-3, warmup_steps=5, total_steps=60, microbatches=1,
        fsdp=False, zero1=False, remat_policy="dots",
    )
    art = make_train_step(cfg, tcfg, mesh)
    step = art.jitted(donate=False)
    state = art.init_state(jax.random.PRNGKey(0))
    ds = SyntheticDataset(cfg=cfg, seq_len=32, global_batch=8)
    losses = []
    for _ in range(30):
        state, m = step(state, _batch(ds))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatching_matches_single_batch(mesh):
    """Gradient accumulation must be numerically equivalent (same data)."""
    cfg = configs.smoke_config("olmo-1b")
    ds = SyntheticDataset(cfg=cfg, seq_len=32, global_batch=8)
    batch = _batch(ds)
    outs = {}
    for n_micro in (1, 4):
        tcfg = TrainConfig(
            learning_rate=1e-2, microbatches=n_micro, fsdp=False, zero1=False,
            compute_dtype="float32",
        )
        art = make_train_step(cfg, tcfg, mesh)
        state = art.init_state(jax.random.PRNGKey(1))
        new_state, m = art.jitted(donate=False)(state, batch)
        outs[n_micro] = (new_state, m)
    p1 = jax.tree.leaves(outs[1][0]["params"])
    p4 = jax.tree.leaves(outs[4][0]["params"])
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    assert float(outs[1][1]["loss"]) == pytest.approx(
        float(outs[4][1]["loss"]), rel=1e-3
    )


def test_moe_and_ssm_train_steps(mesh):
    for arch in ("moonshot-v1-16b-a3b", "falcon-mamba-7b", "zamba2-2.7b"):
        cfg = configs.smoke_config(arch)
        tcfg = TrainConfig(microbatches=2, fsdp=False, zero1=False)
        art = make_train_step(cfg, tcfg, mesh)
        state = art.init_state(jax.random.PRNGKey(2))
        ds = SyntheticDataset(cfg=cfg, seq_len=32, global_batch=4)
        state, m = art.jitted(donate=False)(state, _batch(ds))
        assert np.isfinite(float(m["loss"])), arch


def test_checkpoint_resume_reproduces_trajectory(mesh, tmp_path):
    """Fault-tolerance: kill after step k, restore, and the continued
    trajectory must equal the uninterrupted one (data stream included)."""
    cfg = configs.smoke_config("olmo-1b")
    tcfg = TrainConfig(learning_rate=1e-3, microbatches=1, fsdp=False,
                       zero1=False, compute_dtype="float32")
    art = make_train_step(cfg, tcfg, mesh)
    step = art.jitted(donate=False)

    def run(n, state, ds):
        ms = []
        for _ in range(n):
            state, m = step(state, _batch(ds))
            ms.append(float(m["loss"]))
        return state, ms

    # uninterrupted 6 steps
    ds = SyntheticDataset(cfg=cfg, seq_len=16, global_batch=4)
    ref_state, ref_losses = run(6, art.init_state(jax.random.PRNGKey(3)), ds)

    # interrupted at 3, checkpoint, "crash", restore, continue
    ds2 = SyntheticDataset(cfg=cfg, seq_len=16, global_batch=4)
    state, _ = run(3, art.init_state(jax.random.PRNGKey(3)), ds2)
    ck = Checkpointer(str(tmp_path))
    ck.save(3, state)
    del state
    template = jax.eval_shape(lambda: art.init_state(jax.random.PRNGKey(3)))
    template = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), template
    )
    restored, step_no = ck.restore(template)
    assert step_no == 3
    ds3 = SyntheticDataset(cfg=cfg, seq_len=16, global_batch=4, _step=3)
    _, resumed_losses = run(3, restored, ds3)
    np.testing.assert_allclose(resumed_losses, ref_losses[3:], rtol=1e-5)


def test_grad_compression_state_threads_through(mesh):
    cfg = configs.smoke_config("olmo-1b")
    tcfg = TrainConfig(microbatches=1, fsdp=False, zero1=False,
                       grad_compression="int8_ef")
    art = make_train_step(cfg, tcfg, mesh)
    state = art.init_state(jax.random.PRNGKey(0))
    state["err"] = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
    )
    ds = SyntheticDataset(cfg=cfg, seq_len=16, global_batch=4)
    new_state, m = art.jitted(donate=False)(state, _batch(ds))
    assert "err" in new_state
    err_norm = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(new_state["err"]))
    assert err_norm > 0  # quantization residual captured
    assert np.isfinite(float(m["loss"]))
