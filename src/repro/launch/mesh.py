"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked on first jax init — the
dry-run sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
*before* any jax import for exactly that reason).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over however many devices the process actually has
    (CPU smoke tests / examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
