"""Serving CLI — the EngineCore request-lifecycle surface under Poisson load.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \\
      --requests 32 --mean-interval-ms 20

Crash-safe serving (DESIGN.md §11) — journal every request lifecycle to an
append-only write-ahead log, and replay a previous (killed) run's journal
before submitting fresh work:

  PYTHONPATH=src python -m repro.launch.serve --smoke --requests 16 \\
      --journal /tmp/serve.journal.jsonl
  # ... kill it mid-run, then finish the survivors byte-identically:
  PYTHONPATH=src python -m repro.launch.serve --smoke --requests 0 \\
      --journal /tmp/serve.journal.jsonl --restore

All requests are submitted up front (``EngineCore.submit``, ONLINE
priority, explicit arrival times) and the loop just calls
``core.step()``: each quantum drains every admissible arrived request
(the old loop busy-polled ``pending[0]`` and admitted at most one per
pass), picks a responsive k bucket while arrivals are outstanding, and
streams per-request deltas/TTFT/finish reasons back in ``StepOutputs``.

The end-of-run summary reads the metrics registry (DESIGN.md §8): latency
and TTFT percentiles come from the core-recorded histograms, finish
reasons and peak queue depth / pool occupancy from the counters and
per-quantum gauges.  ``--trace PREFIX`` additionally writes the structured
step trace as ``PREFIX.jsonl`` plus a ``PREFIX.chrome.json`` Chrome trace
(open in https://ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serving.core import Priority, SamplingParams
from repro.serving.engine import InferenceEngine


def summarize(engine: InferenceEngine) -> list:
    """Render the registry's end-of-run summary lines."""
    m = engine.obs.metrics
    lines = []
    reasons = {
        r: m.counter(f"core/finish_reason/{r}").value
        for r in ("stop", "length", "abort", "expired", "error")
    }
    lines.append(
        "[serve] finish reasons: "
        + " ".join(f"{k}={v}" for k, v in reasons.items())
        + f"; preemptions={m.counter('core/preemptions').value}"
    )
    shed = (m.counter("fault/shed/online").value
            + m.counter("fault/shed/offline").value)
    if reasons["expired"] or shed:
        lines.append(
            f"[serve] degradation: expired={reasons['expired']} "
            f"(shed {shed}); starved_quanta="
            f"{m.counter('core/starved_quanta').value}"
        )
    peaks = []
    for name in (
        "core/queue_depth/online", "core/queue_depth/offline",
        "engine/slots_active", "engine/pool/pages_in_use",
    ):
        gauge = m.gauge(name)
        if gauge.samples:
            peaks.append(f"{name.split('/', 1)[1]} peak={gauge.max:g}")
    if peaks:
        lines.append("[serve] gauges: " + "; ".join(peaks))
    for name in ("core/online_latency_s", "core/online_ttft_s"):
        h = m.histogram(name)
        if h.count:
            label = name.rsplit("/", 1)[1].replace("_s", "")
            lines.append(
                f"[serve] {label}: n={h.count} "
                f"p50={h.percentile(50)*1e3:.1f}ms "
                f"p95={h.percentile(95)*1e3:.1f}ms "
                f"max={h.max*1e3:.1f}ms"
            )
    # one row per proposer that actually ran (DESIGN.md §10)
    for prop in ("draft", "ngram", "suffix"):
        rounds = m.counter(f"spec/proposer/rounds/{prop}").value
        if rounds:
            lines.append(
                f"[serve] proposer {prop}: rounds={rounds} "
                f"proposed={m.counter(f'spec/proposer/proposed/{prop}').value} "
                f"accepted={m.counter(f'spec/proposer/accepted/{prop}').value} "
                f"acceptance={m.gauge(f'spec/proposer/acceptance/{prop}').value:.3f}"
            )
    switches = m.counter("spec/proposer/router_switches").value
    fallbacks = m.counter("spec/proposer/no_match_fallbacks").value
    if switches or fallbacks:
        lines.append(
            f"[serve] proposer routing: switches={switches} "
            f"no_match_fallbacks={fallbacks}"
        )
    # crash durability (DESIGN.md §11): journal I/O + replay recovery
    appends = m.counter("journal/appends").value
    if appends:
        lines.append(
            f"[serve] journal: appends={appends} "
            f"fsyncs={m.counter('journal/fsyncs').value} "
            f"bytes={m.counter('journal/bytes').value}"
        )
    restores = m.counter("recovery/restores").value
    if restores:
        lines.append(
            f"[serve] recovery: restores={restores} "
            f"requeued={m.counter('recovery/requeued_waiting').value} "
            f"resumed={m.counter('recovery/resumed_inflight').value} "
            f"replayed_tokens={m.counter('recovery/replayed_tokens').value} "
            f"skipped_finished="
            f"{m.counter('recovery/skipped_finished').value}"
        )
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS), default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--mean-interval-ms", type=float, default=20.0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument(
        "--deadline-ms", type=float, default=None,
        help="queue TTL per request; WAITING past it finishes 'expired'",
    )
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--trace", metavar="PREFIX", default=None,
        help="write the step trace to PREFIX.jsonl + PREFIX.chrome.json",
    )
    ap.add_argument(
        "--journal", metavar="PATH", default=None,
        help="write-ahead request journal (append-only JSONL, DESIGN.md "
        "§11): submits, transitions, token deltas, and finishes are "
        "logged so a killed run can be recovered with --restore",
    )
    ap.add_argument(
        "--journal-fsync-interval", type=int, default=8,
        help="group-commit interval: fsync the journal every N records "
        "(a crash loses at most the last N appends)",
    )
    ap.add_argument(
        "--restore", action="store_true",
        help="replay the --journal file into the engine before submitting "
        "fresh work: a previous run's unfinished requests re-enter the "
        "queue (mid-flight ones as PREEMPTED) and finish byte-identically",
    )
    ap.add_argument(
        "--proposer", choices=("auto", "draft", "ngram", "none"),
        default="none",
        help="speculation source: 'ngram' is host-only (no draft model); "
        "'draft'/'auto' additionally build a draft pairing; 'auto' routes "
        "between them per quantum (DESIGN.md §10)",
    )
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    spec_kw = {}
    if args.proposer != "none":
        from repro.configs.base import SpecDecodeConfig, draft_config

        spec = SpecDecodeConfig(proposer=args.proposer)
        spec_kw["spec"] = spec
        if args.proposer in ("auto", "draft"):
            dcfg = draft_config(cfg, spec)
            spec_kw["draft_cfg"] = dcfg
            spec_kw["draft_params"] = T.init_params(
                dcfg, jax.random.PRNGKey(args.seed + 1)
            )
    t0 = time.monotonic()
    # single clock source: engine timestamps share the arrival timebase
    engine = InferenceEngine(cfg, params, max_slots=args.slots,
                             max_seq=args.max_seq,
                             clock=lambda: time.monotonic() - t0,
                             **spec_kw)
    engine.obs.tracer.enabled = args.trace is not None
    core = engine.core

    journal = None
    if args.journal is not None:
        from repro.resilience import RequestJournal

        journal = RequestJournal(
            args.journal, fsync_interval=args.journal_fsync_interval
        )
        if args.restore:
            report = journal.recover_into(core)
            print(
                f"[serve] restored {report.restored} requests "
                f"({report.resumed_inflight} mid-flight, "
                f"{report.replayed_tokens} tokens replayed, "
                f"{report.skipped_finished} already finished) from "
                f"{args.journal}"
            )
        journal.attach(core)
    elif args.restore:
        raise SystemExit("--restore requires --journal PATH")

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(
        rng.exponential(args.mean_interval_ms / 1e3, args.requests)
    )
    requests = [
        core.submit(
            rng.integers(0, cfg.vocab_size, args.prompt_len),
            SamplingParams(
                max_new_tokens=args.max_new_tokens,
                deadline_s=(
                    None if args.deadline_ms is None
                    else args.deadline_ms / 1e3
                ),
            ),
            priority=Priority.ONLINE,
            arrival_time=float(arrivals[i]),
        )
        for i in range(args.requests)
    ]
    while core.has_unfinished:
        out = core.step()
        if out.k == 0 and not out.admitted:
            time.sleep(0.001)  # idle until the next arrival
    if journal is not None:
        journal.close()
    total_tokens = sum(len(r.output_tokens) for r in requests)
    dt = time.monotonic() - t0
    print(
        f"[serve] {len(requests)} requests, {total_tokens} tokens in "
        f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s)"
    )
    for line in summarize(engine):
        print(line)
    if args.trace is not None:
        tr = engine.obs.tracer
        tr.write_jsonl(
            args.trace + ".jsonl", metrics=engine.obs.metrics.snapshot()
        )
        tr.write_chrome(args.trace + ".chrome.json")
        print(
            f"[serve] trace: {args.trace}.jsonl "
            f"({len(tr.events)} events, {tr.dropped} dropped); "
            f"{args.trace}.chrome.json (load in https://ui.perfetto.dev)"
        )


if __name__ == "__main__":
    main()
