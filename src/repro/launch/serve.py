"""Serving CLI — the EngineCore request-lifecycle surface under Poisson load.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \\
      --requests 32 --mean-interval-ms 20

All requests are submitted up front (``EngineCore.submit``, ONLINE
priority, explicit arrival times) and the loop just calls
``core.step()``: each quantum drains every admissible arrived request
(the old loop busy-polled ``pending[0]`` and admitted at most one per
pass), picks a responsive k bucket while arrivals are outstanding, and
streams per-request deltas/TTFT/finish reasons back in ``StepOutputs``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serving.core import Priority, SamplingParams
from repro.serving.engine import InferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS), default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--mean-interval-ms", type=float, default=20.0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    t0 = time.monotonic()
    # single clock source: engine timestamps share the arrival timebase
    engine = InferenceEngine(cfg, params, max_slots=args.slots,
                             max_seq=args.max_seq,
                             clock=lambda: time.monotonic() - t0)
    core = engine.core

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(
        rng.exponential(args.mean_interval_ms / 1e3, args.requests)
    )
    requests = [
        core.submit(
            rng.integers(0, cfg.vocab_size, args.prompt_len),
            SamplingParams(max_new_tokens=args.max_new_tokens),
            priority=Priority.ONLINE,
            arrival_time=float(arrivals[i]),
        )
        for i in range(args.requests)
    ]
    while core.has_unfinished:
        out = core.step()
        if out.k == 0 and not out.admitted:
            time.sleep(0.001)  # idle until the next arrival
    lat = [r.finish_time - r.arrival_time for r in requests]
    ttft = [r.first_token_time - r.arrival_time for r in requests]
    total_tokens = sum(len(r.output_tokens) for r in requests)
    dt = time.monotonic() - t0
    print(
        f"[serve] {len(requests)} requests, {total_tokens} tokens in {dt:.2f}s "
        f"({total_tokens/dt:.1f} tok/s); latency p50={np.percentile(lat,50)*1e3:.1f}ms "
        f"p95={np.percentile(lat,95)*1e3:.1f}ms; "
        f"ttft p95={np.percentile(ttft,95)*1e3:.1f}ms"
    )


if __name__ == "__main__":
    main()
