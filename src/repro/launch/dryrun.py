import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-compile every (arch x shape) cell on the
production mesh and record memory / cost / collective analysis.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the dry-run needs 512 placeholder
host devices to build the 2x16x16 production mesh.  Smoke tests and benches
import other modules and still see 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --isolate
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

from repro import configs
from repro.launch import cells as C
from repro.launch import hlo as H
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh

DEFAULT_OUT = "results/dryrun"


def cell_path(out_dir: str, mesh_name: str, arch: str, shape: str) -> str:
    return os.path.join(out_dir, mesh_name, f"{arch}__{shape}.json")


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    out_dir: str = DEFAULT_OUT,
    save_hlo: bool = False,
    train_overrides: dict | None = None,
    options: dict | None = None,
    tag: str = "",
) -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    path = cell_path(out_dir, mesh_name, arch + tag, shape_name)
    os.makedirs(os.path.dirname(path), exist_ok=True)

    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    ok, reason = configs.shape_applicable(cfg, shape)
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
    }
    if not ok:
        record["skipped"] = reason
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
        print(f"[dryrun] SKIP {arch} x {shape_name} ({mesh_name}): {reason}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = 1
    for v in mesh.shape.values():
        n_devices *= v

    t0 = time.time()
    cell = C.build_cell(
        arch, shape_name, mesh, train_overrides=train_overrides,
        options=options,
    )
    lowered = cell.lower()
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = H.memory_stats(compiled)
    cost = H.cost_stats(compiled)
    print(f"[dryrun] {arch} x {shape_name} ({mesh_name})")
    print(f"  memory_analysis: {compiled.memory_analysis()}")
    print(
        "  cost_analysis (XLA, loop bodies once): flops=%.3e bytes=%.3e"
        % (cost["flops"], cost["bytes_accessed"])
    )

    hlo_text = compiled.as_text()
    parsed = hlo_cost.analyze(hlo_text)
    print(
        "  hlo_cost (trip-count rolled up): flops/device=%.3e bytes/device=%.3e"
        % (parsed["flops"], parsed["bytes_accessed"])
    )
    roof = H.roofline_terms(
        parsed=parsed,
        n_devices=n_devices,
        model_flops=C.model_flops(cfg, shape),
    )
    print(
        f"  roofline: compute={roof.compute_s*1e3:.2f}ms"
        f" memory={roof.memory_s*1e3:.2f}ms"
        f" collective={roof.collective_s*1e3:.2f}ms"
        f" -> dominant={roof.dominant}"
        f" useful_flops_ratio={roof.useful_flops_ratio:.3f}"
    )
    for op, v in sorted(parsed["collectives"].items()):
        print(
            f"    {op:20s} n={v['count']:6.0f} result={v['result_bytes']/1e6:10.1f}MB"
            f" wire={v['wire_bytes']/1e6:10.1f}MB groups={v['group_sizes']}"
        )

    record.update(
        n_devices=n_devices,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem,
        cost_xla=cost,
        cost_parsed={k: parsed[k] for k in (
            "flops", "bytes_accessed", "transcendentals",
            "collective_result_bytes", "collective_wire_bytes")},
        roofline=roof.as_dict(),
        hbm_ok=bool(mem["peak_bytes_per_device"] <= 16 * 1024**3),
        train_overrides=train_overrides or {},
        options=options or {},
    )
    if save_hlo:
        hlo_path = path.replace(".json", ".hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(hlo_text)
        record["hlo_path"] = hlo_path
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def _run_isolated(arch, shape, mesh_flag, out_dir, save_hlo) -> int:
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh_flag,
        "--out", out_dir,
    ]
    if save_hlo:
        cmd.append("--save-hlo")
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    res = subprocess.run(cmd, env=env)
    return res.returncode


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS))
    ap.add_argument("--shape", choices=list(configs.SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--isolate", action="store_true",
                    help="run each cell in a subprocess (with --all)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--pad-heads", action="store_true",
                    help="physical TP head padding (perf variant)")
    ap.add_argument("--cache-dtype", default=None,
                    choices=["bfloat16", "float8_e4m3fn"])
    ap.add_argument("--layout", default=None, choices=["tp", "dp256"])
    ap.add_argument("--impl", default=None, choices=["auto", "xla", "xla_flash"])
    ap.add_argument("--moe-dispatch", default=None, choices=["batched", "vmap"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    options = {}
    if args.pad_heads:
        options["pad_heads"] = True
    if args.cache_dtype:
        options["cache_dtype"] = args.cache_dtype
    if args.layout:
        options["layout"] = args.layout
    if args.impl:
        options["impl"] = args.impl
    if args.moe_dispatch:
        options["moe_dispatch"] = args.moe_dispatch

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.all:
        failures = []
        for arch, shape_name, ok, reason in configs.all_cells(include_skipped=True):
            for multi_pod in meshes:
                mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
                path = cell_path(args.out, mesh_name, arch, shape_name)
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] exists, skipping {arch} x {shape_name} ({mesh_name})")
                    continue
                if args.isolate and ok:
                    rc = _run_isolated(
                        arch, shape_name,
                        "multi" if multi_pod else "single",
                        args.out, args.save_hlo,
                    )
                    if rc != 0:
                        failures.append((arch, shape_name, mesh_name, f"rc={rc}"))
                    continue
                try:
                    run_cell(
                        arch, shape_name, multi_pod=multi_pod,
                        out_dir=args.out, save_hlo=args.save_hlo,
                    )
                except Exception as e:  # record failures, keep going
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_name, repr(e)))
        if failures:
            print("\n[dryrun] FAILURES:")
            for f in failures:
                print("  ", f)
            sys.exit(1)
        print("\n[dryrun] all cells passed")
        return

    assert args.arch and args.shape, "--arch/--shape or --all required"
    for multi_pod in meshes:
        run_cell(
            args.arch, args.shape, multi_pod=multi_pod,
            out_dir=args.out, save_hlo=args.save_hlo,
            options=options or None, tag=args.tag,
        )


if __name__ == "__main__":
    main()
