"""Training CLI — end-to-end driver with optional SpecInF collocation.

Examples (CPU dev mesh):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
      --steps 50 --global-batch 8 --seq-len 64
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \\
      --steps 200 --collocate --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced config (CPU-runnable); omit it on real
hardware to train the full assigned architecture.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import configs
from repro.configs.base import SpecInFConfig, TrainConfig
from repro.launch.mesh import make_dev_mesh, make_production_mesh
from repro.runtime.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--collocate", action="store_true",
                    help="fill training bubbles with a collocated inference "
                         "engine (SpecInF)")
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    tcfg = TrainConfig(
        learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps, microbatches=args.microbatches,
        fsdp=not args.smoke, zero1=not args.smoke,
        remat_policy="dots" if args.smoke else "full",
    )
    mesh = (
        make_production_mesh() if args.production_mesh else make_dev_mesh()
    )
    trainer = Trainer(
        cfg, tcfg, mesh, seq_len=args.seq_len, global_batch=args.global_batch,
        checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every,
    )
    if args.ckpt_dir and trainer.restore_latest():
        print(f"[train] resumed from step {trainer.step_no}")

    if args.collocate:
        _train_collocated(args, cfg, trainer)
        return

    t0 = time.time()
    report = trainer.train(args.steps)
    dt = time.time() - t0
    toks = args.steps * args.global_batch * args.seq_len
    print(
        f"[train] {report.steps} steps in {dt:.1f}s "
        f"({toks/dt:.0f} tok/s) loss {report.losses[0]:.3f} -> "
        f"{report.losses[-1]:.3f} restores={report.restores} "
        f"checkpoints={report.checkpoints}"
    )


def _train_collocated(args, cfg, trainer) -> None:
    """SpecInF end-to-end: the trainer's real step runs under the
    speculative-filling runtime with a real inference engine."""
    from repro.core import SpecInFRuntime
    from repro.core.profiles import dp_profile
    from repro.serving.engine import InferenceEngine, Request

    params = trainer.state["params"]
    engine = InferenceEngine(cfg, params, max_slots=4, max_seq=args.seq_len)
    for i in range(4):
        engine.add_request(
            Request(prompt=np.arange(8) % cfg.vocab_size, max_new_tokens=10**9)
        )

    def step(state, batch):
        return trainer.step_fn(state, batch)

    def batches():
        while True:
            yield trainer._batch()

    profile = dp_profile(cfg.name, compute_s=0.05, comm_s=0.025)
    rt = SpecInFRuntime(
        train_step=step, train_state=trainer.state, batch_iter=batches(),
        profile=profile, engine=engine, cfg=SpecInFConfig(),
        decode_microstep_s=0.004,
    )
    t0 = time.time()
    metrics = rt.run(args.steps)
    dt = time.time() - t0
    print(
        f"[train+fill] {metrics.train_iterations} train steps, "
        f"{metrics.offline_tokens_generated} collocated inference tokens "
        f"in {dt:.1f}s; loss {metrics.train_losses[0]:.3f} -> "
        f"{metrics.train_losses[-1]:.3f}; phases={metrics.phase_counts}"
    )


if __name__ == "__main__":
    main()
