"""Call-graph-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` (HloCostAnalysis) counts every computation
ONCE — a ``lax.scan`` over 62 layers reports 1/62nd of the real FLOPs, and
collectives inside the loop are similarly undercounted.  This parser walks
the partitioned module's call graph and multiplies ``while``-body costs by
the loop trip count, giving per-device totals that are correct for
scan-over-layers / scan-over-microbatch programs:

  flops       -- dots (2*M*N*K via contracting dims), elementwise arithmetic,
                 transcendentals, reduces
  bytes       -- operands + result of every *top-level* op (fusion internals
                 are register/VMEM-resident and free, matching the HBM
                 traffic model)
  collectives -- per-op result bytes + ring-model wire bytes, multiplied by
                 enclosing trip counts

Trip counts are recovered from the loop condition's ``compare(iter,
constant(N))`` pattern (all our loops come from lax.scan, which emits it).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w\.\-]+)"),
    "condition": re.compile(r"condition=%?([\w\.\-]+)"),
    "calls": re.compile(r"calls=%?([\w\.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w\.\-]+)"),
}
_CONST_RE = re.compile(r"constant\((\d+)\)")

_ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "maximum",
    "minimum", "compare", "select", "and", "or", "xor", "not",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "clamp", "power",
}
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "cbrt", "tanh", "sine", "cosine", "tan", "atan2", "logistic",
    "erf", "expm1", "log1p",
}
_COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_info(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dtype, shape))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dtype, shape in _shape_info(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _elems_of(text: str) -> int:
    total = 0
    for _, shape in _shape_info(text):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result: str  # result type text
    opcode: str
    rest: str  # everything after the opening paren (operands + attrs)
    is_root: bool = False

    def operand_names(self) -> list[str]:
        # operands live before the closing paren of the op; attrs follow.
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _OPERAND_RE.findall(self.rest[:i])
        return _OPERAND_RE.findall(self.rest)

    def attr_comp(self, key: str) -> Optional[str]:
        m = _ATTR_COMP_RE[key].search(self.rest)
        return m.group(1) if m else None

    def group_size(self) -> int:
        m = _GROUPS_IOTA_RE.search(self.rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(self.rest)
        if m:
            ids = [x for x in m.group(1).split(",") if x.strip()]
            return max(1, len(ids))
        return 1


def parse_computations(hlo_text: str) -> tuple[dict, str]:
    """-> ({comp_name: [Instr, ...]}, entry_name)"""
    comps: dict[str, list[Instr]] = {}
    entry = None
    current: Optional[str] = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip()) if "{" in line and "->" in line else None
        if hdr and not line.lstrip().startswith("%param"):
            current = hdr.group(1)
            comps[current] = []
            if line.strip().startswith("ENTRY"):
                entry = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, result, opcode, rest = m.groups()
            comps[current].append(
                Instr(name, result, opcode, rest,
                      is_root=line.lstrip().startswith("ROOT"))
            )
    if entry is None:  # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    return comps, entry


def _trip_count(cond_instrs: list[Instr]) -> int:
    """lax.scan loop conditions compare the counter against constant(N)."""
    consts = {}
    for ins in cond_instrs:
        if ins.opcode == "constant":
            mm = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if mm:
                consts[ins.name] = int(mm.group(1))
    for ins in cond_instrs:
        if ins.opcode == "compare":
            for op in ins.operand_names():
                if op in consts:
                    return consts[op]
    return max(consts.values(), default=1)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_result_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_ops: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CompCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.coll_result_bytes += other.coll_result_bytes * mult
        self.coll_wire_bytes += other.coll_wire_bytes * mult
        for k, v in other.coll_ops.items():
            rec = self.coll_ops.setdefault(
                k, {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0,
                    "group_sizes": set()}
            )
            rec["count"] += v["count"] * mult
            rec["result_bytes"] += v["result_bytes"] * mult
            rec["wire_bytes"] += v["wire_bytes"] * mult
            rec["group_sizes"] |= set(v["group_sizes"])


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_computations(hlo_text)
        self._defs: dict[str, dict[str, str]] = {
            c: {i.name: i.result for i in instrs}
            for c, instrs in self.comps.items()
        }
        self._flops_cache: dict[tuple[str, bool], CompCost] = {}

    # -- per-instruction flops -------------------------------------------
    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = _elems_of(ins.result)
        ops = ins.operand_names()
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        if m and ops:
            lhs_shape_txt = self._defs[comp].get(ops[0], "")
            shapes = _shape_info(lhs_shape_txt)
            if shapes:
                lhs = shapes[0][1]
                for idx in m.group(1).split(","):
                    if idx and int(idx) < len(lhs):
                        k *= lhs[int(idx)]
        return 2.0 * out_elems * k

    def _instr_flops(self, comp: str, ins: Instr) -> tuple[float, float]:
        """-> (flops, transcendentals)"""
        if ins.opcode == "dot":
            return self._dot_flops(comp, ins), 0.0
        if ins.opcode == "convolution":
            return self._dot_flops(comp, ins), 0.0  # contracting-dim model
        if ins.opcode in _ELEMENTWISE_1FLOP:
            return float(_elems_of(ins.result)), 0.0
        if ins.opcode in _TRANSCENDENTAL:
            n = float(_elems_of(ins.result))
            return n, n
        if ins.opcode == "reduce":
            # cost ~ number of input elements
            ops = ins.operand_names()
            if ops:
                return float(_elems_of(self._defs[comp].get(ops[0], ""))), 0.0
            return float(_elems_of(ins.result)), 0.0
        return 0.0, 0.0

    def _operand_bytes(self, comp: str, ins: Instr) -> int:
        total = 0
        for op in ins.operand_names():
            total += _bytes_of(self._defs[comp].get(op, ""))
        return total

    def _root_opcode(self, comp: str) -> str:
        for ins in self.comps.get(comp, []):
            if ins.is_root:
                return ins.opcode
        instrs = self.comps.get(comp, [])
        return instrs[-1].opcode if instrs else ""

    def _instr_bytes(self, comp: str, ins: Instr) -> float:
        """HBM traffic model for one top-level op.

        Slice-type ops move only the slice, not the buffer they index into
        (dynamic-slice / gather read O(result); dynamic-update-slice writes
        O(update) in place — the enclosing buffer must not be charged per
        loop iteration, which would overcount a scan's weight/stash buffers
        by the trip count)."""
        op = ins.opcode
        if op in ("dynamic-slice", "gather"):
            return 2.0 * _bytes_of(ins.result)
        if op == "dynamic-update-slice":
            ops = ins.operand_names()
            upd = (
                _bytes_of(self._defs[comp].get(ops[1], "")) if len(ops) > 1 else 0
            )
            return 2.0 * upd
        if op in ("scatter", "select-and-scatter"):
            return 3.0 * _bytes_of(ins.result)
        if op == "fusion":
            callee = ins.attr_comp("calls")
            root = self._root_opcode(callee) if callee else ""
            rbytes = _bytes_of(ins.result)
            if root in ("dynamic-update-slice", "dynamic-slice", "scatter"):
                # charge only operands strictly smaller than the aliased
                # big buffer, twice (read + write of the touched region)
                small = 0
                for opn in ins.operand_names():
                    b = _bytes_of(self._defs[comp].get(opn, ""))
                    if b < rbytes:
                        small += b
                return 2.0 * small
            return rbytes + self._operand_bytes(comp, ins)
        return _bytes_of(ins.result) + self._operand_bytes(comp, ins)

    # -- computation rollup ------------------------------------------------
    def comp_cost(self, comp: str, fused: bool = False) -> CompCost:
        key = (comp, fused)
        if key in self._flops_cache:
            return self._flops_cache[key]
        cost = CompCost()
        self._flops_cache[key] = cost  # guard recursion
        for ins in self.comps.get(comp, []):
            fl, tr = self._instr_flops(comp, ins)
            cost.flops += fl
            cost.transcendentals += tr
            if ins.opcode == "while":
                body = ins.attr_comp("body")
                cond = ins.attr_comp("condition")
                trip = _trip_count(self.comps.get(cond, [])) if cond else 1
                if body:
                    cost.add(self.comp_cost(body), mult=trip)
                if cond:
                    cost.add(self.comp_cost(cond), mult=trip)
                continue
            if ins.opcode == "fusion":
                callee = ins.attr_comp("calls")
                if callee:
                    sub = self.comp_cost(callee, fused=True)
                    cost.flops += sub.flops
                    cost.transcendentals += sub.transcendentals
                    # fusion internals don't touch HBM
                if not fused:
                    cost.bytes += self._instr_bytes(comp, ins)
                continue
            if ins.opcode in ("call", "conditional", "map"):
                for k in ("to_apply", "calls"):
                    callee = ins.attr_comp(k)
                    if callee:
                        cost.add(self.comp_cost(callee, fused=fused))
                continue
            base = ins.opcode.removesuffix("-start")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "ragged-all-to-all", "collective-permute"):
                rbytes = _bytes_of(ins.result)
                # async -start results carry (input, output) tuples; price
                # the op once via its largest array
                g = ins.group_size()
                if base == "all-reduce":
                    wire = 2.0 * rbytes * (g - 1) / max(g, 1)
                elif base == "all-gather":
                    wire = rbytes * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    wire = float(rbytes) * (g - 1)
                elif base in ("all-to-all", "ragged-all-to-all"):
                    wire = rbytes * (g - 1) / max(g, 1)
                else:
                    wire = float(rbytes)
                cost.coll_result_bytes += rbytes
                cost.coll_wire_bytes += wire
                rec = cost.coll_ops.setdefault(
                    base, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0,
                           "group_sizes": set()}
                )
                rec["count"] += 1
                rec["result_bytes"] += rbytes
                rec["wire_bytes"] += wire
                rec["group_sizes"].add(g)
            if not fused and ins.opcode not in (
                "parameter", "constant", "get-tuple-element", "tuple",
                "bitcast",
            ):
                cost.bytes += self._instr_bytes(comp, ins)
        return cost

    def total(self) -> CompCost:
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    cost = HloCostModel(hlo_text).total()
    return {
        "flops": cost.flops,
        "bytes_accessed": cost.bytes,
        "transcendentals": cost.transcendentals,
        "collective_result_bytes": cost.coll_result_bytes,
        "collective_wire_bytes": cost.coll_wire_bytes,
        "collectives": {
            k: {
                "count": v["count"],
                "result_bytes": v["result_bytes"],
                "wire_bytes": v["wire_bytes"],
                "group_sizes": sorted(v["group_sizes"]),
            }
            for k, v in cost.coll_ops.items()
        },
    }
