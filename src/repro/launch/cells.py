"""Per-cell runtime settings + builders for the (arch x shape) matrix.

``microbatches`` per train cell is napkin-math'd so the remat'd activation
footprint stays ~<= 3 GiB/chip at global_batch=256 over data=16 (see
DESIGN.md §4); ``zero1``+``fsdp`` keep the fp32 state within v5e HBM for the
33B/132B configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro import configs
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.runtime.step import (
    ServeStepArtifacts,
    TrainStepArtifacts,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

# arch id -> gradient-accumulation microbatches for train_4k
TRAIN_MICROBATCHES = {
    "zamba2-2.7b": 4,
    "moonshot-v1-16b-a3b": 8,
    "dbrx-132b": 16,
    "deepseek-coder-33b": 16,
    "qwen2-7b": 8,
    "qwen3-1.7b": 4,
    "olmo-1b": 4,
    "falcon-mamba-7b": 16,
    "musicgen-large": 8,
    "pixtral-12b": 8,
}


def train_config_for(arch: str, **overrides: Any) -> TrainConfig:
    base = dict(
        microbatches=TRAIN_MICROBATCHES.get(arch, 8),
        remat_policy="full",
        zero1=True,
        fsdp=True,
        param_dtype="float32",
        compute_dtype="bfloat16",
    )
    base.update(overrides)
    return TrainConfig(**base)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    kind: str  # "train" | "prefill" | "decode"
    artifacts: Any  # TrainStepArtifacts | ServeStepArtifacts

    def lower(self):
        """AOT-lower the cell's program against abstract inputs."""
        if self.kind == "train":
            art: TrainStepArtifacts = self.artifacts
            return art.jitted(donate=True).lower(
                art.abstract_state(), art.abstract_batch(self.shape)
            )
        art: ServeStepArtifacts = self.artifacts
        return art.jitted().lower(*art.abstract_inputs())


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    train_overrides: dict | None = None,
    options: dict | None = None,
) -> Cell:
    """``options`` select beyond-baseline variants (§Perf):
    pad_heads      -- physical TP head padding for non-divisible GQA
    cache_dtype    -- KV-cache storage dtype ("bfloat16" | "float8_e4m3fn")
    layout         -- "tp" (default) | "dp256" (model axis joins data: pure
                      DP+ZeRO-3; right call for small archs)
    """
    options = options or {}
    if options.get("moe_dispatch"):
        from repro.models import moe as MOE

        MOE.set_dispatch(options["moe_dispatch"])
    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    ok, reason = configs.shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape_name}) skipped: {reason}")
    if options.get("pad_heads"):
        model = mesh.shape.get("model", 1)
        cfg = cfg.padded_for_tp(model)
    cache_dtype = jnp.dtype(options.get("cache_dtype", "bfloat16"))
    if shape.kind == "train":
        overrides = dict(train_overrides or {})
        if options.get("layout"):
            overrides["layout"] = options["layout"]
            if options["layout"] == "dp256":
                # B_local is 1 per device — grad accumulation is meaningless
                overrides.setdefault("microbatches", 1)
        tcfg = train_config_for(arch, **overrides)
        art = make_train_step(cfg, tcfg, mesh, impl=options.get("impl", "auto"))
        return Cell(arch, shape, cfg, "train", art)
    if shape.kind == "prefill":
        art = make_prefill_step(
            cfg, mesh, shape, compute_dtype=jnp.bfloat16,
            cache_dtype=cache_dtype,
        )
        return Cell(arch, shape, cfg, "prefill", art)
    art = make_serve_step(
        cfg, mesh, shape, compute_dtype=jnp.bfloat16, cache_dtype=cache_dtype,
    )
    return Cell(arch, shape, cfg, "decode", art)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful model FLOPs per step: 6*N_active*D for training, 2*N_active*D
    for inference (D = tokens processed by the step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per slot
