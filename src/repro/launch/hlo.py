"""Post-compile HLO analysis: collective byte counts + roofline terms.

``cost_analysis()`` gives per-device FLOPs/bytes but no collective traffic,
so we parse the optimized (SPMD-partitioned, per-device) HLO text and price
every collective op with a ring model over its replica-group size:

  all-reduce        2 * bytes * (g-1)/g        (reduce-scatter + all-gather)
  all-gather        result * (g-1)/g           (each device sends its shard g-1 times)
  reduce-scatter    result * (g-1)              (input = result*g; wire = input*(g-1)/g)
  all-to-all        bytes * (g-1)/g
  collective-permute  bytes                     (one hop)

Terms (v5e constants fixed by the assignment):
  compute    = device_flops / 197e12
  memory     = device_bytes / 819e9
  collective = device_wire_bytes / 50e9
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)
# an instruction line:  %name = TYPE opcode(...)  /  name = (tuple) opcode(...)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.*)$")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every TYPE[shape] token in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    ops: dict  # opcode -> {"count", "result_bytes", "wire_bytes"}
    total_result_bytes: int
    total_wire_bytes: float
    group_sizes: dict  # opcode -> sorted list of distinct group sizes

    def summary(self) -> str:
        rows = [
            f"  {op:20s} n={v['count']:4d} result={v['result_bytes']/1e6:10.1f}MB"
            f" wire={v['wire_bytes']/1e6:10.1f}MB groups={self.group_sizes[op]}"
            for op, v in sorted(self.ops.items())
        ]
        return "\n".join(rows)


def collective_stats(hlo_text: str) -> CollectiveStats:
    ops: dict = {}
    gsizes: dict = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m is None:
            continue
        rhs = m.group(1)
        opcode = None
        for cand in _COLLECTIVES:
            # match 'opcode(' or async 'opcode-start('
            if re.search(rf"\b{cand}(-start)?\(", rhs):
                opcode = cand
                break
        if opcode is None or f"{opcode}-done" in rhs:
            continue
        # result segment = text before the opcode token
        result_part = rhs.split(opcode)[0]
        rbytes = _shape_bytes(result_part)
        g = _group_size(rhs)
        if opcode == "all-reduce":
            wire = 2.0 * rbytes * (g - 1) / max(g, 1)
        elif opcode == "all-gather":
            wire = rbytes * (g - 1) / max(g, 1)
        elif opcode == "reduce-scatter":
            wire = float(rbytes) * (g - 1)
        elif opcode in ("all-to-all", "ragged-all-to-all"):
            wire = rbytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = float(rbytes)
        rec = ops.setdefault(
            opcode, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
        )
        rec["count"] += 1
        rec["result_bytes"] += rbytes
        rec["wire_bytes"] += wire
        gsizes.setdefault(opcode, set()).add(g)
    return CollectiveStats(
        ops=ops,
        total_result_bytes=sum(v["result_bytes"] for v in ops.values()),
        total_wire_bytes=sum(v["wire_bytes"] for v in ops.values()),
        group_sizes={k: sorted(v) for k, v in gsizes.items()},
    )


@dataclasses.dataclass
class Roofline:
    device_flops: float
    device_bytes: float
    collective_result_bytes: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_flops_ratio: float  # model_flops / (device_flops * n_devices)
    bound_s: float  # max of the three terms = roofline-model step time
    collectives: dict

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def roofline_terms(
    *,
    parsed: dict,
    n_devices: int,
    model_flops: float,
) -> Roofline:
    """``parsed`` is the output of ``hlo_cost.analyze`` (per-device totals
    with loop trip counts applied)."""
    device_flops = parsed["flops"]
    device_bytes = parsed["bytes_accessed"]
    wire = parsed["collective_wire_bytes"]
    compute_s = device_flops / PEAK_FLOPS
    memory_s = device_bytes / HBM_BW
    collective_s = wire / LINK_BW
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    total_flops = device_flops * n_devices
    return Roofline(
        device_flops=device_flops,
        device_bytes=device_bytes,
        collective_result_bytes=parsed["collective_result_bytes"],
        collective_wire_bytes=wire,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_flops_ratio=model_flops / max(total_flops, 1e-30),
        bound_s=max(terms.values()),
        collectives=parsed["collectives"],
    )


def memory_stats(compiled) -> dict:
    """Per-device memory picture from ``compiled.memory_analysis()``."""
    m = compiled.memory_analysis()
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        out[k] = int(getattr(m, k, 0) or 0)
    out["peak_bytes_per_device"] = (
        out["argument_size_in_bytes"]
        + out["temp_size_in_bytes"]
        + out["output_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    return out


def cost_stats(compiled) -> dict:
    c = compiled.cost_analysis() or {}
    return {
        "flops": float(c.get("flops", 0.0)),
        "bytes_accessed": float(c.get("bytes accessed", 0.0)),
        "transcendentals": float(c.get("transcendentals", 0.0)),
    }
