"""Flash-decode Pallas TPU kernel: single-token batched decode attention.

Serving decode is the hottest path in the repo — every engine microstep runs
it once per layer per slot batch — so it gets its own kernel instead of the
masked dense ``attention_xla`` over the full ``S_max`` KV cache.

Layout: q [B, H, hd] (one query token per slot), k/v [B, S_max, kvH, hd]
(the KV cache in its native engine layout — no transpose copy on the hot
path), lengths [B] int32 (valid KV entries per slot; 0 marks an empty slot).

Grid: (B, kvH, num_kv_blocks).  Each program owns one slot's GQA group
(``H // kvH`` query heads) and accumulates the online softmax over KV tiles
in VMEM scratch, exactly like ``flash_attention.py``.  Two length-awareness
levers make the kernel ragged-batch fast:

  * ``lengths`` rides in as a scalar-prefetch operand
    (``PrefetchScalarGridSpec``), so the KV BlockSpec index_map can clamp the
    tile index to the slot's last useful block — tiles past a slot's length
    re-address the same block and the pipeline skips their DMA entirely.
  * the kernel body early-exits (``pl.when(k_start < length)``) for tiles
    past the length, so their FLOPs are skipped too.

``interpret=True`` runs the same kernel body on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(
    lengths_ref,  # scalar prefetch: [B] int32
    q_ref,  # [1, 1, gp, hd]
    k_ref, v_ref,  # [1, bk, 1, hd]
    o_ref,  # [1, 1, gp, hd]
    acc_ref, m_ref, l_ref,  # VMEM scratch: [gp, hd], [gp, 1], [gp, 1] (fp32)
    *,
    block_k: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    length = lengths_ref[b]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [gp, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)  # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [gp, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        # length == 0 slots never accumulate: l stays 0, clamped -> output 0.
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, H, hd]; k/v: [B, S_max, kvH, hd]; lengths: [B] int32 valid-KV
    counts.  Returns [B, H, hd].  Slots with ``lengths == 0`` return zeros."""
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    block_k = min(block_k, s)
    nk = (s + block_k - 1) // block_k
    pad_s = nk * block_k - s
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    qr = q.reshape(b, kvh, group, hd)
    if gp != group:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, gp - group), (0, 0)))
    lengths = jnp.minimum(lengths.astype(jnp.int32), s)

    def q_map(bi, hi, ki, lens):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, lens):
        # Clamp past-length tiles onto the slot's last useful block: the
        # pipeline sees a repeated index and skips the DMA (ragged early-exit).
        last = jnp.maximum(pl.cdiv(lens[bi], block_k) - 1, 0)
        return (bi, jnp.minimum(ki, last), hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, gp, hd), q_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((gp, hd), jnp.float32),
            pltpu.VMEM((gp, 1), jnp.float32),
            pltpu.VMEM((gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _decode_kernel, block_k=block_k, sm_scale=hd**-0.5
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths, qr, k, v)
    return out[:, :, :group].reshape(b, h, hd)
