"""JAX version compatibility shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` upstream;
resolve whichever this JAX exposes so the kernels run on both sides of the
rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
