"""Paged flash-decode Pallas TPU kernel: block-table KV gather.

The dense ``decode_attention`` kernel assumes each slot owns a contiguous
``[S_max]`` row of the KV cache.  Under the paged KV pool
(``serving/kv_pool.py``) a slot's cache is a list of fixed-size *physical
pages* scattered through a shared pool, named by a per-slot **block table**.
This kernel is the dense one with exactly one change: the KV BlockSpec
index_map dereferences the scalar-prefetched block table, so each grid step
DMAs the slot's ``ki``-th *logical* page from wherever it physically lives.

Layout: q [B, H, hd] (one query token per slot), k/v pools
[P, page, kvH, hd] (physical pages, shared across slots — prefix-shared
pages appear in several block tables), block_tables [B, W] int32 (logical
page ``j`` of slot ``b`` lives at physical page ``block_tables[b, j]``;
unused entries hold the sentinel page 0), lengths [B] int32 valid-KV counts.

Grid: (B, kvH, num_logical_pages).  Both ragged-batch levers of the dense
kernel survive the indirection:

  * ``lengths`` and ``block_tables`` ride in as scalar-prefetch operands, so
    the KV index_map clamps the logical page index at the slot's last useful
    page *before* dereferencing — tiles past a slot's length re-address the
    same physical page and the pipeline skips their DMA entirely.
  * the kernel body early-exits (``pl.when(k_start < length)``) for pages
    past the length, skipping their FLOPs.

``lengths == 0`` marks an empty slot (output zeros).  ``interpret=True``
runs the same kernel body on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.decode_attention import _decode_kernel

NEG_INF = -1e30


def _paged_decode_kernel(lengths_ref, tables_ref, *refs, **kw):
    # The body IS the dense flash-decode kernel (single source of truth for
    # the online softmax / masking); the block table only steers the
    # BlockSpec index_map below and is unused inside the body.
    _decode_kernel(lengths_ref, *refs, **kw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, H, hd]; k/v_pool: [P, page, kvH, hd]; block_tables: [B, W]
    int32 physical-page ids per logical page, whose LAST column is the
    overflow sentinel (never live KV: ``lengths <= (W-1) * page`` — see
    ``transformer.init_paged_cache``), so the grid iterates W-1 logical
    pages; lengths: [B] int32 valid-KV counts.  Returns [B, H, hd].  Slots
    with ``lengths == 0`` return zeros."""
    b, h, hd = q.shape
    page, kvh = k_pool.shape[1], k_pool.shape[2]
    nk = block_tables.shape[1] - 1
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    qr = q.reshape(b, kvh, group, hd)
    if gp != group:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, gp - group), (0, 0)))
    # lengths are NOT clamped to the logical capacity: kv_map's min(ki,
    # last) already keeps every table lookup in-grid, and positions past
    # the last logical page are simply never loaded.
    lengths = lengths.astype(jnp.int32)
    block_tables = block_tables.astype(jnp.int32)

    def q_map(bi, hi, ki, lens, tables):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, lens, tables):
        # Clamp the *logical* page index at the slot's last useful page, then
        # dereference the block table: past-length tiles re-address the same
        # physical page and the pipeline skips their DMA (ragged early-exit).
        last = jnp.maximum(pl.cdiv(lens[bi], page) - 1, 0)
        return (tables[bi, jnp.minimum(ki, last)], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, gp, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((gp, hd), jnp.float32),
            pltpu.VMEM((gp, 1), jnp.float32),
            pltpu.VMEM((gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel, block_k=page, sm_scale=hd**-0.5
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths, block_tables, qr, k_pool, v_pool)
    return out[:, :, :group].reshape(b, h, hd)
