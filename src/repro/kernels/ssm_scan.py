"""Mamba1 selective-scan Pallas TPU kernel (one chunk).

TPU adaptation of the CUDA selective-scan (DESIGN.md §2): the recurrent state
``h [d_inner, d_state]`` lives in VMEM scratch for the whole chunk, so HBM
traffic is only the chunk inputs/outputs — the XLA fallback materializes the
[B, Q, d_inner, d_state] state tensor in HBM, which is what makes the SSM
cells memory-bound (§Roofline).

Grid: (B, d_inner / block_d); time is a sequential ``fori_loop`` inside the
kernel (the recurrence is inherently serial in t, parallel in d_inner).
block_d defaults to 512 lanes: h scratch is 512*d_state fp32 (32 KiB at
d_state=16) and the per-step row ops are VPU-aligned (8x128 tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssm_kernel(
    xi_ref, dt_ref,  # [1, Q, bd]
    b_ref, c_ref,  # [1, Q, ds]
    a_ref,  # [bd, ds]
    h0_ref,  # [1, bd, ds]
    y_ref,  # out [1, Q, bd]
    h_out_ref,  # out [1, bd, ds]
    h_scratch,  # VMEM [bd, ds] fp32
    *,
    chunk: int,
):
    h_scratch[...] = h0_ref[0].astype(jnp.float32)
    a_mat = a_ref[...].astype(jnp.float32)  # A (negative) [bd, ds]

    def step(t, _):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # [bd]
        xi_t = xi_ref[0, t, :].astype(jnp.float32)  # [bd]
        b_t = b_ref[0, t, :].astype(jnp.float32)  # [ds]
        c_t = c_ref[0, t, :].astype(jnp.float32)  # [ds]
        decay = jnp.exp(dt_t[:, None] * a_mat)  # [bd, ds]
        h = decay * h_scratch[...] + (dt_t * xi_t)[:, None] * b_t[None, :]
        h_scratch[...] = h
        y_ref[0, t, :] = (h @ c_t).astype(y_ref.dtype)  # [bd]
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)
    h_out_ref[0] = h_scratch[...].astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ssm_scan_chunk(
    xi: jax.Array,
    dt: jax.Array,
    B_: jax.Array,
    C_: jax.Array,
    A: jax.Array,
    h0: jax.Array,
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One chunk of the selective scan.

    xi/dt: [B, Q, di]; B_/C_: [B, Q, ds]; A: [di, ds]; h0: [B, di, ds].
    Returns (y [B, Q, di], h_final [B, di, ds]); fp32 in/out.
    """
    b, q, di = xi.shape
    ds = B_.shape[-1]
    block_d = min(block_d, di)
    assert di % block_d == 0, (di, block_d)
    nd = di // block_d

    kernel = functools.partial(_ssm_kernel, chunk=q)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(b, nd),
        in_specs=[
            pl.BlockSpec((1, q, block_d), lambda bi, d: (bi, 0, d)),
            pl.BlockSpec((1, q, block_d), lambda bi, d: (bi, 0, d)),
            pl.BlockSpec((1, q, ds), lambda bi, d: (bi, 0, 0)),
            pl.BlockSpec((1, q, ds), lambda bi, d: (bi, 0, 0)),
            pl.BlockSpec((block_d, ds), lambda bi, d: (d, 0)),
            pl.BlockSpec((1, block_d, ds), lambda bi, d: (bi, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, block_d), lambda bi, d: (bi, 0, d)),
            pl.BlockSpec((1, block_d, ds), lambda bi, d: (bi, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, q, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(xi, dt, B_, C_, A, h0)
    return y, h_fin
