"""Chunk-verify Pallas TPU kernel: multi-token speculative-verify attention.

Speculative decoding's target-side hot path scores all ``gamma + 1`` chunk
positions (current token + gamma draft tokens) in ONE pass over the KV cache
instead of ``gamma + 1`` sequential decode steps.  This kernel is
``decode_attention`` generalized from one query token per slot to a small
query *chunk* per slot.

Layout: q [B, T, H, hd] (T = gamma+1 chunk queries per slot), k/v
[B, S_max, kvH, hd] (the KV cache in its native engine layout — the chunk's
own K/V has already been written at positions ``lengths - T .. lengths - 1``),
lengths [B] int32 = valid KV entries per slot INCLUDING the chunk.  Chunk
query t sits at sequence position ``lengths - T + t`` and may attend to
``kpos <= lengths - T + t`` — prefix plus the chunk's own causal triangle.

Grid: (B, kvH, num_kv_blocks).  Each program owns one slot's GQA group for
ALL T chunk queries: the query rows fold to a single ``T * gp`` sublane axis
(``gp`` = sublane-padded group size), so the online-softmax scratch and both
MXU contractions keep the exact shape discipline of ``decode_attention``.
The same two ragged-batch levers apply:

  * ``lengths`` rides in as a scalar-prefetch operand, so the KV BlockSpec
    index_map clamps the tile index at each slot's last useful block — tiles
    past the length re-address the same block and the pipeline skips their
    DMA entirely (the decode kernel's DMA-clamp machinery, reused verbatim).
  * the kernel body early-exits (``pl.when(k_start < length)``) for tiles
    past the length, skipping their FLOPs; the intra-chunk causal mask is a
    per-row position bound on top of the shared length mask.

``lengths == 0`` marks an empty slot: every tile is skipped and the output
is zeros.  ``interpret=True`` runs the same kernel body on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _verify_kernel(
    lengths_ref,  # scalar prefetch: [B] int32
    q_ref,  # [1, 1, T * gp, hd]
    k_ref, v_ref,  # [1, bk, 1, hd]
    o_ref,  # [1, 1, T * gp, hd]
    acc_ref, m_ref, l_ref,  # VMEM scratch: [T*gp, hd], [T*gp, 1], [T*gp, 1]
    *,
    block_k: int,
    chunk: int,  # T = gamma + 1
    gp: int,  # sublane-padded GQA group size
    sm_scale: float,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    length = lengths_ref[b]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [T*gp, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)  # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [T*gp, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # Row r holds chunk query t = r // gp at sequence position
        # length - chunk + t: causal bound over prefix + intra-chunk triangle.
        t_row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // gp
        s = jnp.where(kpos <= length - chunk + t_row, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # A fully-masked row (its causal window is empty, e.g. lengths < T)
        # leaves m_new == NEG_INF; exp(s - m_new) would then be 1, turning
        # the output into an unweighted mean of V.  Mask those entries so l
        # stays 0 and the row finalizes to zeros.
        p = jnp.where(s > NEG_INF, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        # length == 0 slots (and rows whose causal window is empty) never
        # accumulate: l stays 0, clamped -> output 0.
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def verify_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, T, H, hd] chunk queries; k/v: [B, S_max, kvH, hd]; lengths: [B]
    int32 valid-KV counts *including* the T chunk positions (chunk query t
    attends to kpos <= lengths - T + t).  Returns [B, T, H, hd].  Slots with
    ``lengths == 0`` — and individual chunk rows whose causal window is
    empty (``lengths < T``) — return zeros."""
    b, t, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    block_k = min(block_k, s)
    nk = (s + block_k - 1) // block_k
    pad_s = nk * block_k - s
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    # Fold (chunk, group) into one sublane axis: row r = t * gp + g.
    qr = q.reshape(b, t, kvh, group, hd)
    if gp != group:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, gp - group), (0, 0)))
    qr = qr.transpose(0, 2, 1, 3, 4).reshape(b, kvh, t * gp, hd)
    lengths = jnp.minimum(lengths.astype(jnp.int32), s)

    def q_map(bi, hi, ki, lens):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, lens):
        # Clamp past-length tiles onto the slot's last useful block: the
        # pipeline sees a repeated index and skips the DMA (ragged early-exit).
        last = jnp.maximum(pl.cdiv(lens[bi], block_k) - 1, 0)
        return (bi, jnp.minimum(ki, last), hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, t * gp, hd), q_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, t * gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((t * gp, hd), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _verify_kernel, block_k=block_k, chunk=t, gp=gp, sm_scale=hd**-0.5
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, t * gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths, qr, k, v)
    out = out.reshape(b, kvh, t, gp, hd)[:, :, :, :group]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, t, h, hd)
