"""Paged chunk-verify Pallas TPU kernel: block-table KV gather.

``verify_attention`` generalized the flash-decode kernel from one query
token to a ``T = gamma + 1`` speculative chunk; this kernel applies the same
block-table indirection as ``paged_decode_attention`` on top, so speculative
verification runs directly against the paged KV pool.  The chunk's own K/V
has already been scattered into the slot's pages at logical positions
``lengths - T .. lengths - 1``.

Layout: q [B, T, H, hd]; k/v pools [P, page, kvH, hd]; block_tables [B, W]
int32; lengths [B] int32 valid-KV counts INCLUDING the chunk.  Chunk query t
sits at sequence position ``lengths - T + t`` and attends to
``kpos <= lengths - T + t`` — prefix plus the chunk's own causal triangle.

Grid: (B, kvH, num_logical_pages); query rows fold to a single ``T * gp``
sublane axis exactly as in ``verify_attention``.  The scalar-prefetched
block table is dereferenced in the KV index_map after clamping the logical
page index at the slot's last useful page, preserving the DMA-skip behavior
for ragged batches.  ``interpret=True`` runs the same body on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.verify_attention import _verify_kernel

NEG_INF = -1e30


def _paged_verify_kernel(lengths_ref, tables_ref, *refs, **kw):
    # The body IS the dense chunk-verify kernel (single source of truth for
    # the online softmax / causal bound / fully-masked-row guard); the block
    # table only steers the BlockSpec index_map below and is unused inside
    # the body.
    _verify_kernel(lengths_ref, *refs, **kw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, T, H, hd] chunk queries; k/v_pool: [P, page, kvH, hd];
    block_tables: [B, W] int32; lengths: [B] int32 valid-KV counts
    *including* the T chunk positions.  Returns [B, T, H, hd].  Slots with
    ``lengths == 0`` — and chunk rows whose causal window is empty — return
    zeros.  The block table's LAST column is the overflow sentinel (never
    live KV: ``lengths <= (W-1) * page``), so the grid iterates W-1 logical
    pages."""
    b, t, h, hd = q.shape
    page, kvh = k_pool.shape[1], k_pool.shape[2]
    nk = block_tables.shape[1] - 1
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    # Fold (chunk, group) into one sublane axis: row r = t * gp + g.
    qr = q.reshape(b, t, kvh, group, hd)
    if gp != group:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, gp - group), (0, 0)))
    qr = qr.transpose(0, 2, 1, 3, 4).reshape(b, kvh, t * gp, hd)
    # lengths are NOT clamped to the logical capacity: suffix prefill passes
    # lengths = shared + T_bucket, which may exceed it when the bucket's pad
    # tail spills past max_seq — clamping would shift the causal bound
    # (length - chunk + t_row) and silently mask real prefix positions.
    # kv_map's min(ki, last) already keeps every table lookup in-grid.
    lengths = lengths.astype(jnp.int32)
    block_tables = block_tables.astype(jnp.int32)

    def q_map(bi, hi, ki, lens, tables):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, lens, tables):
        last = jnp.maximum(pl.cdiv(lens[bi], page) - 1, 0)
        return (tables[bi, jnp.minimum(ki, last)], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, t * gp, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, t * gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((t * gp, hd), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_verify_kernel, block_k=page, chunk=t, gp=gp,
        sm_scale=hd**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, t * gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths, block_tables, qr, k_pool, v_pool)
    out = out.reshape(b, kvh, t, gp, hd)[:, :, :, :group]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, t, h, hd)
