"""Ragged chunked-prefill Pallas TPU kernel: large-query-chunk attention.

Chunked prefill (DESIGN.md §7) splits an admitted prompt into fixed-width
chunks and streams them into a slot across successive engine steps, so no
single step ever pays a whole prompt's latency.  The attention each chunk
needs is the chunk-verify shape scaled up: every chunk query attends the
slot's *previously-written* cache prefix plus the chunk's own causal
triangle.  This kernel is ``verify_attention`` generalized from a
``gamma + 1`` speculative chunk to a prefill-sized query chunk, with one
extra grid axis so large chunks tile instead of loading one giant block.

Layout: q [B, C, H, hd] (C = chunk width), k/v [B, S_max, kvH, hd] — the
chunk's *real* K/V (rows ``t < chunk_lens``) has already been written at
positions ``starts .. starts + chunk_lens - 1``; starts [B] int32 = KV
entries before the chunk (the slot's prefill progress); chunk_lens [B]
int32 = real tokens in this chunk (ragged: the mixed batch runs every
slot's chunk at its own length, 0 = slot not prefilling).  Chunk query t
sits at sequence position ``starts + t`` and attends ``kpos <= starts + t``;
rows ``t >= chunk_lens`` return zeros.

Grid: (B, kvH, num_q_blocks, num_kv_blocks).  Each program owns one
``block_q``-row slice of one slot's GQA group, folded to a single
``block_q * gp`` sublane axis exactly as in the verify kernel.  Both
ragged-batch levers generalize:

  * ``starts`` and ``chunk_lens`` ride in as scalar-prefetch operands; the
    KV BlockSpec index_map clamps the tile index at the q block's *causal*
    bound ``starts + min((qi + 1) * block_q, chunk_lens)`` — tiles past it
    re-address the same block and the pipeline skips their DMA.  A short
    chunk (``chunk_lens`` well below C) therefore skips the KV tiles its
    missing rows would have swept, not just their FLOPs.
  * the body early-exits for q blocks past ``chunk_lens`` and KV tiles past
    the causal bound; the intra-chunk causal mask is the per-row position
    bound ``kpos <= starts + t`` on top of the row-validity mask.

``chunk_lens == 0`` marks a frozen slot: every tile is skipped and the
output is zeros.  ``interpret=True`` runs the same body on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _prefill_kernel(
    starts_ref,  # scalar prefetch: [B] int32
    lens_ref,  # scalar prefetch: [B] int32
    q_ref,  # [1, 1, block_q * gp, hd]
    k_ref, v_ref,  # [1, block_k, 1, hd]
    o_ref,  # [1, 1, block_q * gp, hd]
    acc_ref, m_ref, l_ref,  # VMEM scratch
    *,
    block_q: int,
    block_k: int,
    gp: int,  # sublane-padded GQA group size
    sm_scale: float,
):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    start = starts_ref[b]
    clen = lens_ref[b]
    q0 = qi * block_q  # first chunk row owned by this program

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ki * block_k
    # Exclusive KV bound of this q block: its last real row q0 + block_q - 1
    # (clamped at chunk_lens) attends kpos <= start + row.
    limit = start + jnp.minimum(q0 + block_q, clen)

    @pl.when((q0 < clen) & (k_start < limit))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [block_q * gp, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)  # [block_k, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [block_q * gp, block_k]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # Row r holds chunk query t = q0 + r // gp at sequence position
        # start + t: causal bound over prefix + intra-chunk triangle, and
        # rows past the slot's real chunk length are masked out entirely.
        t_row = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // gp
        s = jnp.where((kpos <= start + t_row) & (t_row < clen), s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # Fully-masked rows (t >= chunk_lens) leave m_new == NEG_INF;
        # exp(s - m_new) would then be 1, turning the output into an
        # unweighted mean of V.  Mask so l stays 0 and they finalize to 0.
        p = jnp.where(s > NEG_INF, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        # chunk_lens == 0 slots and pad rows never accumulate: l stays 0,
        # clamped -> output 0.
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _fold_queries(q: jax.Array, kvh: int, group: int, gp: int, block_q: int):
    """[B, C, H, hd] -> [B, kvH, Cp * gp, hd] with C padded to a block_q
    multiple and the (chunk, group) axes folded to one sublane axis
    (row r = t * gp + g)."""
    b, c, h, hd = q.shape
    cp = -(-c // block_q) * block_q
    qr = q.reshape(b, c, kvh, group, hd)
    if cp != c:
        qr = jnp.pad(qr, ((0, 0), (0, cp - c), (0, 0), (0, 0), (0, 0)))
    if gp != group:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, gp - group), (0, 0)))
    return qr.transpose(0, 2, 1, 3, 4).reshape(b, kvh, cp * gp, hd), cp


def _unfold_outputs(out, b, c, cp, kvh, group, gp, hd):
    out = out.reshape(b, kvh, cp, gp, hd)[:, :, :c, :group]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, kvh * group, hd)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def prefill_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    starts: jax.Array,
    chunk_lens: jax.Array,
    *,
    block_q: int = 32,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, C, H, hd] chunk queries; k/v: [B, S_max, kvH, hd] with the
    chunk's real K/V already written at ``starts .. starts + chunk_lens - 1``;
    starts/chunk_lens: [B] int32.  Chunk query t attends
    ``kpos <= starts + t``.  Returns [B, C, H, hd]; rows ``t >= chunk_lens``
    (frozen slots included: ``chunk_lens == 0``) return zeros."""
    b, c, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    block_q = min(block_q, c)
    block_k = min(block_k, s)
    nk = (s + block_k - 1) // block_k
    pad_s = nk * block_k - s
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    qr, cp = _fold_queries(q, kvh, group, gp, block_q)
    nq = cp // block_q
    starts = starts.astype(jnp.int32)
    # chunk rows never extend past the cache; rows past a clamped length
    # are pad by contract (the engine sizes chunks to fit)
    chunk_lens = jnp.minimum(chunk_lens.astype(jnp.int32), c)

    def q_map(bi, hi, qi, ki, starts, lens):
        return (bi, hi, qi, 0)

    def kv_map(bi, hi, qi, ki, starts, lens):
        # Clamp past-bound tiles onto the q block's last useful KV block:
        # the pipeline sees a repeated index and skips the DMA, so short
        # chunks skip the KV tiles their missing rows would have swept.
        limit = starts[bi] + jnp.minimum((qi + 1) * block_q, lens[bi])
        last = jnp.maximum(pl.cdiv(limit, block_k) - 1, 0)
        return (bi, jnp.minimum(ki, last), hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q * gp, hd), q_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q * gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((block_q * gp, hd), jnp.float32),
            pltpu.VMEM((block_q * gp, 1), jnp.float32),
            pltpu.VMEM((block_q * gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _prefill_kernel, block_q=block_q, block_k=block_k, gp=gp,
        sm_scale=hd**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, cp * gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")
        ),
        interpret=interpret,
    )(starts, chunk_lens, qr, k, v)
    return _unfold_outputs(out, b, c, cp, kvh, group, gp, hd)
