"""Tree-verify Pallas TPU kernel: multi-candidate speculative verification.

``verify_attention`` scores one *linear* draft chain per slot: chunk query t
attends the prefix plus intra-chunk positions ``<= t`` (a causal triangle).
This kernel generalizes that intra-chunk triangle to an ANCESTOR MASK so a
packed candidate *tree* — k branches sharing a root — verifies in ONE pass.
Node j of the tree occupies chunk position j (its K/V is written at cache
position ``lengths - N + j``, exactly where a linear chunk would put it);
``anc[b, j]`` is an int32 bitmask whose bit i is set iff node i is an
ancestor of node j *or j itself* (nodes are numbered so parents precede
children, hence ``N <= 31`` nodes fit one int32).  Query row j then attends

    kpos <  lengths - N          (the committed prefix), or
    kpos >= lengths - N  with bit ``kpos - (lengths - N)`` set in anc[b, j]

A linear chain (``anc[j]`` = bits 0..j) reproduces the triangle bound
``kpos <= lengths - N + j`` bit for bit, so this kernel is a strict
generalization of ``verify_attention`` (the equivalence a property test
pins down).

Layout mirrors ``verify_attention`` exactly: q [B, N, H, hd] (one query per
tree node), k/v [B, S_max, kvH, hd], lengths [B] int32 INCLUDING the N tree
positions, anc [B, N] int32 riding in as a second scalar-prefetch operand
next to lengths.  Grid (B, kvH, num_kv_blocks); query rows fold to a
``N * gp`` sublane axis; the DMA-clamp index_map and the fully-masked-row
guard are reused verbatim.  The per-row bitmask test is an unrolled Python
loop over the N chunk rows reading one SMEM scalar each — no gathers inside
the kernel body.  ``interpret=True`` runs the same body on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30

#: Hard cap on packed-tree size: ancestor sets are int32 bitmasks.
MAX_TREE_NODES = 31


def _tree_verify_kernel(
    lengths_ref,  # scalar prefetch: [B] int32
    anc_ref,  # scalar prefetch: [B, N] int32 ancestor bitmasks
    q_ref,  # [1, 1, N * gp, hd]
    k_ref, v_ref,  # [1, bk, 1, hd]
    o_ref,  # [1, 1, N * gp, hd]
    acc_ref, m_ref, l_ref,  # VMEM scratch: [N*gp, hd], [N*gp, 1], [N*gp, 1]
    *,
    block_k: int,
    chunk: int,  # N = tree nodes
    gp: int,  # sublane-padded GQA group size
    sm_scale: float,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    length = lengths_ref[b]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [N*gp, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)  # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [N*gp, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        t_row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // gp
        # Intra-chunk node index of each key position (negative = prefix,
        # >= chunk = beyond the tree).  Shifts are clamped into [0, 31] so
        # out-of-range lanes stay defined; ``in_chunk`` gates them off.
        jpos = kpos - (length - chunk)
        jc = jnp.clip(jpos, 0, 31)
        in_chunk = (jpos >= 0) & (jpos < chunk)
        # Row r holds tree node t = r // gp.  Visibility of key node j from
        # query node t is bit j of anc[b, t]; each of the N rows reads its
        # one SMEM scalar in an unrolled loop (no in-kernel gathers).
        intra = jnp.zeros(s.shape, jnp.bool_)
        for t in range(chunk):
            bit = ((anc_ref[b, t] >> jc) & 1) == 1
            intra = jnp.where(t_row == t, bit, intra)
        s = jnp.where((kpos < length - chunk) | (in_chunk & intra), s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # Fully-masked rows (empty slots, lengths < N) must finalize to
        # zeros: mask the exp so l stays 0 (same guard as verify_attention).
        p = jnp.where(s > NEG_INF, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def tree_verify_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    anc: jax.Array,
    *,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, N, H, hd] one query per packed-tree node; k/v: [B, S_max, kvH,
    hd] with node j's K/V already written at position ``lengths - N + j``;
    lengths: [B] int32 valid-KV counts *including* the N tree positions;
    anc: [B, N] int32 ancestor bitmasks (bit i of anc[b, j] = node i visible
    from node j; self bit set).  Returns [B, N, H, hd].  Slots with
    ``lengths == 0`` — and rows whose visibility set is empty — return
    zeros."""
    b, t, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    assert t <= MAX_TREE_NODES, f"tree has {t} nodes (> {MAX_TREE_NODES})"
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    block_k = min(block_k, s)
    nk = (s + block_k - 1) // block_k
    pad_s = nk * block_k - s
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    qr = q.reshape(b, t, kvh, group, hd)
    if gp != group:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, gp - group), (0, 0)))
    qr = qr.transpose(0, 2, 1, 3, 4).reshape(b, kvh, t * gp, hd)
    lengths = jnp.minimum(lengths.astype(jnp.int32), s)
    anc = anc.astype(jnp.int32)

    def q_map(bi, hi, ki, lens, ancs):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, lens, ancs):
        last = jnp.maximum(pl.cdiv(lens[bi], block_k) - 1, 0)
        return (bi, jnp.minimum(ki, last), hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, t * gp, hd), q_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
            pl.BlockSpec((1, block_k, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, t * gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((t * gp, hd), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _tree_verify_kernel, block_k=block_k, chunk=t, gp=gp,
        sm_scale=hd**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, t * gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths, anc, qr, k, v)
    out = out.reshape(b, kvh, t, gp, hd)[:, :, :, :group]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, t, h, hd)
