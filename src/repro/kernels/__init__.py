"""Pallas TPU kernels for the perf-critical compute layers.

The paper's contribution is scheduler-level (see ``repro.core``); these
kernels cover the model compute hot spots it schedules around:
  * flash_attention.py  -- blocked online-softmax attention (MXU-tiled)
  * decode_attention.py -- flash-decode: single-token ragged-batch decode
                           attention over the KV cache (serving hot path)
  * verify_attention.py -- chunk-verify: flash-decode generalized to the
                           gamma+1 query chunk of speculative decoding
  * ssm_scan.py         -- Mamba1 selective scan with VMEM-resident state
ops.py dispatches between Pallas and XLA fallbacks; ref.py holds the
pure-jnp oracles used by the test suite.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
