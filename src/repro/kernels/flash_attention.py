"""Flash attention Pallas TPU kernel.

Layout: q [B, H, Sq, hd], k/v [B, H, Sk, hd] (heads pre-expanded for GQA).
Grid: (B*H, num_q_blocks, num_kv_blocks); the kv dimension is ``arbitrary``
(sequential) and accumulates the online softmax in VMEM scratch, writing the
output block on the final kv step — the canonical TPU flash schedule.

Block shapes default to (128, head_dim) q-tiles and (512, head_dim) kv-tiles:
q/k/v tiles plus fp32 accumulators stay well under ~2 MiB VMEM per core while
keeping the MXU matmul dims at multiples of 128 (hardware-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # [1, bq, hd], [1, bk, hd]
    o_ref,  # [1, bq, hd]
    acc_ref, m_ref, l_ref,  # VMEM scratch: [bq, hd], [bq, 1], [bq, 1] (fp32)
    *,
    causal: bool,
    sm_scale: float,
    block_q: int,
    block_k: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [bq, bk]
        corr = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    if causal:
        # kv blocks strictly above the causal diagonal contribute nothing;
        # skip their math entirely (the scheduler still visits the step).
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, H, Sq, hd]; k/v: [B, H, Sk, hd].  Returns [B, H, Sq, hd]."""
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = (sq + block_q - 1) // block_q
    nk = (sk + block_k - 1) // block_k
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    qf = q.reshape(b * h, nq * block_q, hd)
    kf = k.reshape(b * h, nk * block_k, hd)
    vf = v.reshape(b * h, nk * block_k, hd)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        sm_scale=hd**-0.5,
        block_q=block_q,
        block_k=block_k,
        kv_len=sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, nq * block_q, hd)[:, :, :sq, :]
