"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """q: [B, Sq, H, hd]; k/v: [B, Sk, H, hd] (heads already expanded)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= (jnp.arange(sq)[:, None] + (sk - sq))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)


def ssm_scan_chunk_ref(
    xi: jax.Array,
    dt: jax.Array,
    B_: jax.Array,
    C_: jax.Array,
    A: jax.Array,
    h0: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Naive sequential selective scan over one chunk.

    xi/dt: [B, Q, di]; B_/C_: [B, Q, ds]; A: [di, ds]; h0: [B, di, ds].
    h_t = exp(dt_t A) h_{t-1} + (dt_t xi_t) B_t ;  y_t = h_t . C_t
    """
    def step(h, inp):
        xi_t, dt_t, b_t, c_t = inp  # [B, di], [B, di], [B, ds], [B, ds]
        a = jnp.exp(dt_t[..., None] * A)  # [B, di, ds]
        h = a * h + (dt_t * xi_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (xi.swapaxes(0, 1), dt.swapaxes(0, 1), B_.swapaxes(0, 1), C_.swapaxes(0, 1))
    h_fin, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), h_fin
