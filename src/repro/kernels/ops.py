"""Jit'd dispatch wrappers over the Pallas kernels with XLA fallbacks.

``impl`` semantics:
  * "auto"      -- pallas on TPU; on CPU/GPU pick xla (short seq) or
                   xla_flash (long seq, no S^2 buffer)
  * "xla"       -- plain einsum attention
  * "xla_flash" -- lax.scan blocked online softmax
  * "pallas"    -- Pallas kernel (interpret=True automatically off-TPU,
                   so tests validate the real kernel body on CPU)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_FLASH_SEQ_THRESHOLD = 8192


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """q: [B, Sq, H, hd]; k/v: [B, Sk, kvH, hd].  Returns [B, Sq, H, hd]."""
    from repro.models import layers as L

    if impl == "auto":
        if _on_tpu():
            impl = "pallas"
        else:
            impl = "xla_flash" if q.shape[1] >= _FLASH_SEQ_THRESHOLD else "xla"

    if impl == "xla":
        return L.attention_xla(q, k, v, causal=causal)
    if impl == "xla_flash":
        return L.attention_xla_flash(q, k, v, causal=causal)
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention

        qh = q.shape[2]
        kk = L._repeat_kv(k, qh)
        vv = L._repeat_kv(v, qh)
        out = flash_attention(
            q.transpose(0, 2, 1, 3),
            kk.transpose(0, 2, 1, 3),
            vv.transpose(0, 2, 1, 3),
            causal=causal,
            interpret=not _on_tpu(),
        )
        return out.transpose(0, 2, 1, 3)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Single-token decode attention over a ragged KV cache.

    q: [B, H, hd]; k/v_cache: [B, S_max, kvH, hd]; lengths: [B] int32 valid-KV
    counts (0 == empty slot -> zero output).  Returns [B, H, hd].

    ``impl``:
      * "auto"   -- pallas on TPU, xla elsewhere (interpret-mode pallas is
                    correct but slow; CI forces it explicitly)
      * "xla"    -- length-masked dense attention over S_max
      * "pallas" -- flash-decode kernel (interpret=True automatically off-TPU)
    """
    from repro.models import layers as L

    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        s_max = k_cache.shape[1]
        length_mask = jnp.arange(s_max)[None, :] < lengths[:, None]
        out = L.attention_xla(
            q[:, None],
            k_cache.astype(q.dtype),
            v_cache.astype(q.dtype),
            causal=False,
            length_mask=length_mask,
        )[:, 0]
        # empty slots are all-masked -> uniform softmax garbage; zero them to
        # match the kernel's defined output
        return jnp.where(lengths[:, None, None] > 0, out, 0.0)
    if impl == "pallas":
        from repro.kernels.decode_attention import decode_attention as _kernel

        return _kernel(
            q,
            k_cache.astype(q.dtype),
            v_cache.astype(q.dtype),
            lengths,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown decode attention impl {impl!r}")


def verify_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Chunk-verify attention over a ragged KV cache (speculative decoding).

    q: [B, T, H, hd] — the T = gamma+1 chunk queries per slot; k/v_cache:
    [B, S_max, kvH, hd] with the chunk's own K/V already written at positions
    ``lengths - T .. lengths - 1``; lengths: [B] int32 valid-KV counts
    *including* the chunk (0 == empty slot -> zero output).  Chunk query t
    attends to ``kpos <= lengths - T + t`` — the prefix plus the chunk's own
    causal triangle.  Returns [B, T, H, hd].

    ``impl``:
      * "auto"   -- pallas on TPU, xla elsewhere
      * "xla"    -- chunk-causal length-masked dense attention over S_max
      * "pallas" -- chunk-verify kernel (interpret=True automatically off-TPU)
    """
    from repro.models import layers as L

    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        b, t, h, hd = q.shape
        s_max = k_cache.shape[1]
        kk = L._repeat_kv(k_cache.astype(q.dtype), h)
        vv = L._repeat_kv(v_cache.astype(q.dtype), h)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32)
        scores = scores * hd**-0.5
        kpos = jnp.arange(s_max)
        bound = (lengths - t)[:, None] + jnp.arange(t)[None, :]  # [B, T]
        mask = kpos[None, None, :] <= bound[:, :, None]  # [B, T, S_max]
        scores = jnp.where(mask[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
        # all-masked rows (empty slots, and chunk rows whose causal window
        # is empty when lengths < T) are uniform softmax garbage; zero them
        # to match the kernel's defined output
        return jnp.where(bound[:, :, None, None] >= 0, out, 0.0)
    if impl == "pallas":
        from repro.kernels.verify_attention import verify_attention as _kernel

        return _kernel(
            q,
            k_cache.astype(q.dtype),
            v_cache.astype(q.dtype),
            lengths,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown verify attention impl {impl!r}")


def tree_verify_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    anc: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Tree-verify attention over a ragged KV cache (multi-candidate
    speculative decoding).

    q: [B, N, H, hd] — one query per packed-tree node, the node's K/V
    already written at position ``lengths - N + node``; k/v_cache:
    [B, S_max, kvH, hd]; lengths: [B] int32 valid-KV counts *including*
    the N tree positions; anc: [B, N] int32 ancestor bitmasks (bit i of
    anc[b, j] = node i visible from node j; self bit set).  Node j attends
    the committed prefix ``kpos < lengths - N`` plus the intra-chunk
    positions its bitmask admits.  A linear-chain anc reproduces
    ``verify_attention`` exactly.  Returns [B, N, H, hd].

    ``impl``:
      * "auto"   -- pallas on TPU, xla elsewhere
      * "xla"    -- ancestor-masked dense attention over S_max
      * "pallas" -- tree-verify kernel (interpret=True automatically off-TPU)
    """
    from repro.models import layers as L

    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        b, t, h, hd = q.shape
        s_max = k_cache.shape[1]
        kk = L._repeat_kv(k_cache.astype(q.dtype), h)
        vv = L._repeat_kv(v_cache.astype(q.dtype), h)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32)
        scores = scores * hd**-0.5
        kpos = jnp.arange(s_max)[None, :]  # [1, S]
        base = (lengths - t)[:, None]  # [B, 1]
        prefix = kpos < base  # [B, S]
        jpos = kpos - base  # [B, S] intra-chunk node index of each key
        in_chunk = (jpos >= 0) & (jpos < t)
        bits = (anc.astype(jnp.int32)[:, :, None]
                >> jnp.clip(jpos, 0, 31)[:, None, :]) & 1  # [B, N, S]
        mask = prefix[:, None, :] | (in_chunk[:, None, :] & (bits == 1))
        scores = jnp.where(mask[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
        # rows with an empty visibility set (empty slots, lengths < N) are
        # uniform softmax garbage; zero them to match the kernel
        any_vis = mask.any(axis=-1)  # [B, N]
        return jnp.where(any_vis[:, :, None, None], out, 0.0)
    if impl == "pallas":
        from repro.kernels.tree_verify_attention import (
            tree_verify_attention as _kernel,
        )

        return _kernel(
            q,
            k_cache.astype(q.dtype),
            v_cache.astype(q.dtype),
            lengths,
            anc,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown tree verify attention impl {impl!r}")


def prefill_chunk_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    starts: jax.Array,
    chunk_lens: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Ragged chunked-prefill attention over a dense KV cache.

    q: [B, C, H, hd] — one fixed-width prefill chunk per slot; k/v_cache:
    [B, S_max, kvH, hd] with the chunk's *real* K/V already written at
    positions ``starts .. starts + chunk_lens - 1``; starts: [B] int32
    per-slot prefill progress (KV entries before the chunk); chunk_lens:
    [B] int32 real tokens per chunk (ragged; 0 == frozen slot).  Chunk
    query t attends ``kpos <= starts + t`` — the previously-written prefix
    plus the chunk's own causal triangle.  Returns [B, C, H, hd]; rows
    ``t >= chunk_lens`` return zeros.

    ``impl``:
      * "auto"   -- pallas on TPU, xla elsewhere
      * "xla"    -- chunk-causal masked dense attention over S_max
      * "pallas" -- ragged prefill kernel (interpret=True automatically
                    off-TPU)
    """
    from repro.models import layers as L

    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        b, c, h, hd = q.shape
        s_max = k_cache.shape[1]
        kk = L._repeat_kv(k_cache.astype(q.dtype), h)
        vv = L._repeat_kv(v_cache.astype(q.dtype), h)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32)
        scores = scores * hd**-0.5
        kpos = jnp.arange(s_max)
        bound = starts[:, None] + jnp.arange(c)[None, :]  # [B, C]
        valid = jnp.arange(c)[None, :] < chunk_lens[:, None]  # [B, C]
        mask = (kpos[None, None, :] <= bound[:, :, None]) & valid[:, :, None]
        scores = jnp.where(mask[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
        # pad rows (t >= chunk_lens, frozen slots included) are uniform
        # softmax garbage; zero them to match the kernel's defined output
        return jnp.where(valid[:, :, None, None], out, 0.0)
    if impl == "pallas":
        from repro.kernels.prefill_attention import (
            prefill_attention as _kernel,
        )

        return _kernel(
            q,
            k_cache.astype(q.dtype),
            v_cache.astype(q.dtype),
            starts,
            chunk_lens,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown prefill chunk attention impl {impl!r}")


def _gather_pages(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Materialize a paged pool into its per-slot dense layout.

    pool: [P, page, kvH, hd]; block_tables: [B, W] int32 whose LAST column
    is the overflow sentinel (never holds live KV; ``lengths <= (W-1) *
    page`` — see ``transformer.init_paged_cache``), so only W-1 columns are
    gathered and the fallback's attention width matches the dense layout
    exactly.  Returns [B, (W-1) * page, kvH, hd]; positions past a slot's
    length hold sentinel/stale garbage, which the caller masks by length
    exactly as in the dense path."""
    b, w = block_tables.shape
    page, kvh, hd = pool.shape[1:]
    return pool[block_tables[:, :-1]].reshape(b, (w - 1) * page, kvh, hd)


def paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Single-token decode attention over the paged KV pool.

    q: [B, H, hd]; k/v_pool: [P, page, kvH, hd] physical pages shared across
    slots; block_tables: [B, W] int32 per-slot logical->physical page map
    (unused entries hold the sentinel page 0); lengths: [B] int32 valid-KV
    counts (0 == empty slot -> zero output).  Returns [B, H, hd].

    ``impl``:
      * "auto"   -- pallas on TPU, xla elsewhere
      * "xla"    -- gather pages dense, then length-masked attention
      * "pallas" -- block-table flash-decode kernel (interpret off-TPU)
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        return decode_attention(
            q,
            _gather_pages(k_pool, block_tables),
            _gather_pages(v_pool, block_tables),
            lengths,
            impl="xla",
        )
    if impl == "pallas":
        from repro.kernels.paged_decode_attention import (
            paged_decode_attention as _kernel,
        )

        return _kernel(
            q,
            k_pool.astype(q.dtype),
            v_pool.astype(q.dtype),
            block_tables,
            lengths,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown paged decode attention impl {impl!r}")


def paged_verify_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Chunk-verify attention over the paged KV pool (speculative decoding).

    q: [B, T, H, hd] — the T = gamma+1 chunk queries per slot, whose own K/V
    has already been scattered into the slot's pages at logical positions
    ``lengths - T .. lengths - 1``; k/v_pool: [P, page, kvH, hd];
    block_tables: [B, W] int32; lengths: [B] int32 valid-KV counts
    *including* the chunk.  Returns [B, T, H, hd].

    ``impl``: same semantics as ``paged_decode_attention``.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        return verify_attention(
            q,
            _gather_pages(k_pool, block_tables),
            _gather_pages(v_pool, block_tables),
            lengths,
            impl="xla",
        )
    if impl == "pallas":
        from repro.kernels.paged_verify_attention import (
            paged_verify_attention as _kernel,
        )

        return _kernel(
            q,
            k_pool.astype(q.dtype),
            v_pool.astype(q.dtype),
            block_tables,
            lengths,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown paged verify attention impl {impl!r}")


def paged_tree_verify_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    anc: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Tree-verify attention over the paged KV pool (multi-candidate
    speculative decoding).

    q: [B, N, H, hd] — one query per packed-tree node, whose K/V has
    already been scattered into the slot's pages at logical position
    ``lengths - N + node``; k/v_pool: [P, page, kvH, hd]; block_tables:
    [B, W] int32; lengths: [B] int32 *including* the N tree positions;
    anc: [B, N] int32 ancestor bitmasks.  Returns [B, N, H, hd].

    ``impl``: same semantics as ``paged_decode_attention``.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        return tree_verify_attention(
            q,
            _gather_pages(k_pool, block_tables),
            _gather_pages(v_pool, block_tables),
            lengths,
            anc,
            impl="xla",
        )
    if impl == "pallas":
        from repro.kernels.paged_tree_verify_attention import (
            paged_tree_verify_attention as _kernel,
        )

        return _kernel(
            q,
            k_pool.astype(q.dtype),
            v_pool.astype(q.dtype),
            block_tables,
            lengths,
            anc,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown paged tree verify attention impl {impl!r}")


def paged_prefill_chunk_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    starts: jax.Array,
    chunk_lens: jax.Array,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Ragged chunked-prefill attention over the paged KV pool.

    q: [B, C, H, hd] — one fixed-width prefill chunk per slot, whose real
    K/V has already been scattered into the slot's pages at positions
    ``starts .. starts + chunk_lens - 1``; k/v_pool: [P, page, kvH, hd];
    block_tables: [B, W] int32; starts / chunk_lens: [B] int32 as in
    ``prefill_chunk_attention``.  Returns [B, C, H, hd].

    ``impl``: same semantics as ``paged_decode_attention``.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        return prefill_chunk_attention(
            q,
            _gather_pages(k_pool, block_tables),
            _gather_pages(v_pool, block_tables),
            starts,
            chunk_lens,
            impl="xla",
        )
    if impl == "pallas":
        from repro.kernels.paged_prefill_attention import (
            paged_prefill_attention as _kernel,
        )

        return _kernel(
            q,
            k_pool.astype(q.dtype),
            v_pool.astype(q.dtype),
            block_tables,
            starts,
            chunk_lens,
            interpret=not _on_tpu(),
        )
    raise ValueError(f"unknown paged prefill chunk attention impl {impl!r}")


def ssm_scan_chunk(xi, dt, B_, C_, A, h0):
    """Pallas selective-scan chunk (interpret mode off-TPU)."""
    from repro.kernels.ssm_scan import ssm_scan_chunk as _kernel

    y, h = _kernel(
        xi.astype(jnp.float32),
        dt.astype(jnp.float32),
        B_.astype(jnp.float32),
        C_.astype(jnp.float32),
        A.astype(jnp.float32),
        h0.astype(jnp.float32),
        block_d=min(512, xi.shape[-1]),
        interpret=not _on_tpu(),
    )
    return y, h
