"""Paged ragged chunked-prefill Pallas TPU kernel: block-table KV gather.

``prefill_attention`` generalized the chunk-verify kernel to prefill-sized
query chunks; this kernel applies the same block-table indirection as
``paged_decode_attention`` / ``paged_verify_attention`` on top, so chunked
prefill streams straight into the paged KV pool: each chunk query attends
the slot's previously-written *pages* (including radix-shared prefix pages)
plus the chunk's own causal triangle.  The chunk's real K/V has already
been scattered into the slot's pages at positions
``starts .. starts + chunk_lens - 1``.

Layout: q [B, C, H, hd]; k/v pools [P, page, kvH, hd]; block_tables [B, W]
int32; starts / chunk_lens [B] int32 as in the dense kernel.

Grid: (B, kvH, num_q_blocks, num_logical_pages); query rows fold to
``block_q * gp`` sublanes exactly as in ``prefill_attention``.  The
scalar-prefetched block table is dereferenced in the KV index_map after
clamping the logical page at the q block's causal bound
``starts + min((qi + 1) * block_q, chunk_lens)`` — the DMA-skip lever now
scales with prefill *progress*: early chunks of a long prompt sweep only
the few pages written so far.  ``interpret=True`` runs the same body on
CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.prefill_attention import (
    _fold_queries,
    _prefill_kernel,
    _unfold_outputs,
)


def _paged_prefill_kernel(starts_ref, lens_ref, tables_ref, *refs, **kw):
    # The body IS the dense chunked-prefill kernel (single source of truth
    # for the online softmax / causal bound / pad-row guard); the block
    # table only steers the BlockSpec index_map below and is unused inside
    # the body.
    _prefill_kernel(starts_ref, lens_ref, *refs, **kw)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def paged_prefill_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    starts: jax.Array,
    chunk_lens: jax.Array,
    *,
    block_q: int = 32,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, C, H, hd] chunk queries; k/v_pool: [P, page, kvH, hd];
    block_tables: [B, W] int32; starts / chunk_lens: [B] int32 — the chunk's
    real K/V sits in the slot's pages at ``starts .. starts + chunk_lens -
    1`` and query t attends ``kpos <= starts + t``.  Returns [B, C, H, hd];
    rows ``t >= chunk_lens`` return zeros.  The table's LAST column is the
    overflow sentinel (never live KV), so the grid iterates W-1 logical
    pages."""
    b, c, h, hd = q.shape
    page, kvh = k_pool.shape[1], k_pool.shape[2]
    nk = block_tables.shape[1] - 1
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    block_q = min(block_q, c)
    qr, cp = _fold_queries(q, kvh, group, gp, block_q)
    nq = cp // block_q
    starts = starts.astype(jnp.int32)
    chunk_lens = jnp.minimum(chunk_lens.astype(jnp.int32), c)
    block_tables = block_tables.astype(jnp.int32)

    def q_map(bi, hi, qi, ki, starts, lens, tables):
        return (bi, hi, qi, 0)

    def kv_map(bi, hi, qi, ki, starts, lens, tables):
        limit = starts[bi] + jnp.minimum((qi + 1) * block_q, lens[bi])
        last = jnp.maximum(pl.cdiv(limit, page) - 1, 0)
        return (tables[bi, jnp.minimum(ki, last)], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q * gp, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q * gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((block_q * gp, hd), jnp.float32),
            pltpu.VMEM((block_q * gp, 1), jnp.float32),
            pltpu.VMEM((block_q * gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_prefill_kernel, block_q=block_q, block_k=page, gp=gp,
        sm_scale=hd**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, cp * gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")
        ),
        interpret=interpret,
    )(starts, chunk_lens, block_tables, qr, k_pool, v_pool)
    return _unfold_outputs(out, b, c, cp, kvh, group, gp, hd)
