"""Paged tree-verify Pallas TPU kernel: ancestor-mask verification over the
block-table KV pool.

``tree_verify_attention`` generalizes the chunk-verify causal triangle to a
packed candidate tree; this kernel applies ``paged_verify_attention``'s
block-table indirection on top, so multi-branch speculative verification
runs directly against the paged KV pool in ONE pass.  Tree node j's K/V has
already been scattered into the slot's pages at logical position
``lengths - N + j`` (the node-index slot a linear chunk would use).

Layout: q [B, N, H, hd]; k/v pools [P, page, kvH, hd]; block_tables [B, W]
int32 (last column = overflow sentinel, so the grid iterates W-1 logical
pages); lengths [B] int32 INCLUDING the N tree positions; anc [B, N] int32
ancestor bitmasks riding as a THIRD scalar-prefetch operand after lengths
and the block table.  The body IS ``_tree_verify_kernel`` — the table only
steers the KV index_map, exactly as in ``paged_verify_attention``.
``interpret=True`` runs the same body on CPU for CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.tree_verify_attention import (
    MAX_TREE_NODES,
    _tree_verify_kernel,
)

NEG_INF = -1e30


def _paged_tree_verify_kernel(lengths_ref, tables_ref, anc_ref, *refs, **kw):
    # Single source of truth: the dense tree kernel body (online softmax,
    # ancestor-bitmask visibility, fully-masked-row guard).  The block table
    # only steers the BlockSpec index_map below.
    _tree_verify_kernel(lengths_ref, anc_ref, *refs, **kw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_tree_verify_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    anc: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, N, H, hd] one query per packed-tree node; k/v_pool: [P, page,
    kvH, hd]; block_tables: [B, W] int32; lengths: [B] int32 valid-KV counts
    *including* the N tree positions; anc: [B, N] int32 ancestor bitmasks.
    Returns [B, N, H, hd]."""
    b, t, h, hd = q.shape
    page, kvh = k_pool.shape[1], k_pool.shape[2]
    nk = block_tables.shape[1] - 1
    assert t <= MAX_TREE_NODES, f"tree has {t} nodes (> {MAX_TREE_NODES})"
    assert h % kvh == 0, f"q heads {h} not a multiple of kv heads {kvh}"
    group = h // kvh
    gp = max(8, group)  # sublane-pad the tiny GQA-group axis
    qr = q.reshape(b, t, kvh, group, hd)
    if gp != group:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, gp - group), (0, 0)))
    qr = qr.transpose(0, 2, 1, 3, 4).reshape(b, kvh, t * gp, hd)
    # lengths NOT clamped — same rationale as paged_verify_attention: the
    # visibility base (lengths - N) must not shift; kv_map's min(ki, last)
    # keeps every table lookup in-grid.
    lengths = lengths.astype(jnp.int32)
    block_tables = block_tables.astype(jnp.int32)
    anc = anc.astype(jnp.int32)

    def q_map(bi, hi, ki, lens, tables, ancs):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, lens, tables, ancs):
        last = jnp.maximum(pl.cdiv(lens[bi], page) - 1, 0)
        return (tables[bi, jnp.minimum(ki, last)], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, t * gp, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, t * gp, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((t * gp, hd), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
            pltpu.VMEM((t * gp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_tree_verify_kernel, block_k=page, chunk=t, gp=gp,
        sm_scale=hd**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, t * gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths, block_tables, anc, qr, k_pool, v_pool)
    out = out.reshape(b, kvh, t, gp, hd)[:, :, :, :group]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, t, h, hd)
