"""Graceful-degradation ladder (DESIGN.md §9).

Overload never fails the engine outright; it walks a ladder of
increasingly aggressive (and increasingly visible) mitigations, driven by
the same registry pressure signals the dashboards read:

====== ============== ====================================================
stage  name           mitigation
====== ============== ====================================================
0      NORMAL         none
1      SPEC_OFF       disable speculative decoding (verify batches are
                      the first thing to go — they multiply tokens/step)
2      K_SHRINK       shrink the decode bucket to the smallest k
                      (quanta stay short; admission latency improves)
3      SHED_OFFLINE   shed queued OFFLINE work beyond a keep-depth
                      (FINISHED_EXPIRED; throughput work is re-submittable)
4      SHED_ONLINE    additionally shed queued ONLINE requests whose
                      deadline can no longer be met (FINISHED_EXPIRED)
====== ============== ====================================================

Each stage includes every mitigation below it.  Transitions are dwelled:
escalation needs ``up_dwell`` consecutive pressured quanta, de-escalation
``down_dwell`` consecutive calm ones, and a quantum that is neither
resets both counters — the hysteresis that keeps the ladder from
flapping when load oscillates around a threshold.

The ladder is consulted by ``EngineCore.step()`` when installed
(``core.ladder = OverloadLadder(...)``): ``update`` before planning
(reads pressure, sheds, records the ``fault/ladder_*`` metrics) and
``apply`` after (downshifts the plan).  It never touches device state.
"""
from __future__ import annotations

import dataclasses
import enum

from repro.serving.core import Priority

__all__ = ["LadderStage", "LadderConfig", "OverloadLadder"]


class LadderStage(enum.IntEnum):
    NORMAL = 0
    SPEC_OFF = 1
    K_SHRINK = 2
    SHED_OFFLINE = 3
    SHED_ONLINE = 4


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """Pressure thresholds and hysteresis dwells.

    Pressure = queue depth >= ``high_queue_depth`` OR pool occupancy
    fraction >= ``high_pool_frac`` OR any deadline expiry since the last
    quantum.  Calm = depth <= ``low_queue_depth`` AND occupancy <=
    ``low_pool_frac`` AND no expiries.  The low thresholds sit below the
    high ones so recovery needs genuinely lighter load, not one quiet
    quantum at the boundary."""

    high_queue_depth: int = 8
    low_queue_depth: int = 2
    high_pool_frac: float = 0.95
    low_pool_frac: float = 0.75
    up_dwell: int = 3
    down_dwell: int = 8
    #: SHED_OFFLINE keeps this many queued OFFLINE requests and sheds the
    #: rest (newest first — the oldest queued work sheds last)
    offline_keep_depth: int = 4
    #: SHED_ONLINE sheds an ONLINE request once its deadline slack drops
    #: to this margin (engine-clock seconds); deadline-less requests are
    #: never shed
    online_slack_s: float = 0.0


class OverloadLadder:
    """Hysteretic overload controller over an ``EngineCore``."""

    def __init__(self, config: LadderConfig = LadderConfig()):
        self.config = config
        self.stage = LadderStage.NORMAL
        self._up = 0
        self._down = 0
        self._expired_seen = 0

    # -- pressure ------------------------------------------------------
    def _pool_frac(self, core) -> float:
        pool = core.engine.pool
        if pool is None:
            return 0.0
        occ = pool.occupancy()
        total = occ.get("pages_in_use", 0) + occ.get("available", 0)
        return occ.get("pages_in_use", 0) / total if total else 0.0

    def update(self, core, grant) -> None:
        """Pre-plan hook: read pressure, move the stage (with dwell),
        shed queued work the current stage calls for, record metrics."""
        cfg = self.config
        m = core.obs.metrics
        depth = core.num_waiting
        frac = self._pool_frac(core)
        expired = m.counter("core/finish_reason/expired").value
        missed = expired - self._expired_seen
        self._expired_seen = expired
        pressured = (
            depth >= cfg.high_queue_depth
            or frac >= cfg.high_pool_frac
            or missed > 0
        )
        calm = (
            depth <= cfg.low_queue_depth
            and frac <= cfg.low_pool_frac
            and missed == 0
        )
        if pressured:
            self._down = 0
            self._up += 1
            if self._up >= cfg.up_dwell and self.stage < LadderStage.SHED_ONLINE:
                self.stage = LadderStage(self.stage + 1)
                self._up = 0
                m.counter("fault/ladder_escalations").inc()
        elif calm:
            self._up = 0
            self._down += 1
            if self._down >= cfg.down_dwell and self.stage > LadderStage.NORMAL:
                self.stage = LadderStage(self.stage - 1)
                self._down = 0
        else:
            # between the thresholds: hold the stage, restart both dwells
            self._up = 0
            self._down = 0
        if self.stage >= LadderStage.SHED_OFFLINE:
            q = core.waiting[Priority.OFFLINE]
            while len(q) > cfg.offline_keep_depth:
                core.shed(q[-1], grant.now, "offline")
        if self.stage >= LadderStage.SHED_ONLINE:
            doomed = [
                cr for cr in core.waiting[Priority.ONLINE]
                if cr.sampling.deadline_s is not None
                and (cr.arrival_time + cr.sampling.deadline_s - grant.now)
                <= cfg.online_slack_s
            ]
            for cr in doomed:
                core.shed(cr, grant.now, "online")
        m.gauge("fault/ladder_stage").set(int(self.stage))
        m.counter("fault/ladder_steps/" + self.stage.name.lower()).inc()

    # -- plan downshift ------------------------------------------------
    def apply(self, core, grant, plan) -> None:
        """Post-plan hook: downshift the quantum shape for the current
        stage.  Only ever REDUCES tokens/steps, so the policy's budget
        clamp stays valid."""
        if self.stage >= LadderStage.SPEC_OFF and plan.gamma is not None:
            plan.gamma = None
            plan.cost_steps = float(plan.k)
        if self.stage >= LadderStage.K_SHRINK and plan.k > 0:
            buckets = getattr(core.policy, "k_buckets", None) or (1,)
            # smallest RUNNABLE bucket: a 0 bucket means "skip the quantum",
            # which would stall streams rather than degrade them
            k_min = min((b for b in buckets if b > 0), default=1)
            if plan.k > k_min:
                plan.cost_steps *= k_min / plan.k
                plan.k = k_min
