"""Optional warm-state engine snapshot (DESIGN.md §11).

``EngineSnapshot`` periodically exports the radix prefix cache — tree
structure plus the KV contents of its pages — through the training
``Checkpointer`` (atomic tmp+rename, fsync'd, manifest-gated,
retention-GC'd), so a restarted engine recovers *prefix hits* instead of
cold re-prefilling every replayed request.

Division of labour with the request journal
(``resilience/journal.py``):

* the **journal** is the sole source of truth for request state — it is
  required for recovery and its replay is exact;
* the **snapshot** is derived KV cache only — best-effort warm state
  that is never required for correctness.  Greedy prefill is
  deterministic, so a missing/stale/partial snapshot merely costs
  re-prefill compute, never output bytes.

Journal-vs-snapshot consistency is resolved by replaying the journal
suffix: restore loads the newest snapshot whose journal watermark (the
durable byte offset at save time) does not exceed the journal's current
durable length, then ``RequestJournal.recover_into`` replays the FULL
journal on top.  A snapshot that outran the surviving journal (its tail
was lost in the crash) is discarded — its pages may encode prompts the
journal no longer knows about, and warm state must stay a strict subset
of journaled truth.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

__all__ = ["EngineSnapshot"]


class EngineSnapshot:
    """Radix-cache snapshot/restore for one ``InferenceEngine``.

    ``checkpointer`` is a ``repro.checkpoint.Checkpointer`` (typically
    rooted next to, but distinct from, the training checkpoints);
    ``journal`` (optional) stamps each snapshot with the journal's
    durable watermark for the consistency rule above."""

    def __init__(self, engine, checkpointer, journal=None):
        self.engine = engine
        self.checkpointer = checkpointer
        self.journal = journal
        self._step = 0

    @property
    def _metrics(self):
        return self.engine.obs.metrics

    # ------------------------------------------------------------------
    def save(
        self, step: Optional[int] = None, blocking: bool = True
    ) -> bool:
        """Export the current radix-cache contents; returns False when
        there is nothing to snapshot (dense engine / empty cache)."""
        exported = self.engine.export_prefix_pages()
        if exported is None:
            return False
        nodes, k, v = exported
        ps = self.engine.kv_page_size
        if step is None:
            self._step += 1
            step = self._step
        else:
            self._step = max(self._step, step)
        watermark = -1
        if self.journal is not None:
            # records past this offset were not yet durable: a crash may
            # erase them, so restore must treat this snapshot as invalid
            # if the surviving journal is shorter
            self.journal.commit()
            watermark = self.journal._synced_offset
        payload = {
            "chunks": np.asarray(
                [chunk for _, chunk, _ in nodes], np.int32
            ).reshape(len(nodes), ps),
            "parents": np.asarray([p for p, _, _ in nodes], np.int32),
            # KV stored as float32: portable across compute dtypes, and
            # npz has no native bfloat16
            "k": np.asarray(k, np.float32),
            "v": np.asarray(v, np.float32),
            "journal_seq": np.asarray([watermark], np.int64),
        }
        self.checkpointer.save(step, payload, blocking=blocking)
        self._metrics.counter("recovery/snapshot_saves").inc()
        return True

    # ------------------------------------------------------------------
    def restore(self, step: Optional[int] = None) -> int:
        """Warm the engine's radix cache from the newest consistent
        snapshot; returns the nodes loaded (0 when none is usable).
        Call BEFORE ``RequestJournal.recover_into`` — replay then runs
        against the warmed cache."""
        template = {
            "chunks": np.zeros((0,), np.int32),
            "parents": np.zeros((0,), np.int32),
            "k": np.zeros((0,), np.float32),
            "v": np.zeros((0,), np.float32),
            "journal_seq": np.zeros((1,), np.int64),
        }
        try:
            tree, found = self.checkpointer.restore(template, step)
        except FileNotFoundError:
            return 0
        watermark = int(tree["journal_seq"][0])
        if self.journal is not None and watermark >= 0:
            durable = (
                os.path.getsize(self.journal.path)
                if os.path.exists(self.journal.path) else 0
            )
            if watermark > durable:
                self._metrics.counter("recovery/snapshot_discarded").inc()
                return 0
        nodes = [
            (int(p), tuple(int(t) for t in chunk), 0)
            for p, chunk in zip(
                tree["parents"].tolist(), tree["chunks"].tolist()
            )
        ]
        loaded = self.engine.import_prefix_pages(
            nodes, tree["k"], tree["v"]
        )
        self._metrics.counter("recovery/snapshot_nodes").inc(loaded)
        self._step = max(self._step, found)
        return loaded
