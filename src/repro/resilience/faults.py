"""Deterministic, seeded fault injection (DESIGN.md §9).

A ``FaultInjector`` is a passive oracle the serving stack consults at
named *fault points*; it never touches engine state itself.  Each point
draws from its own ``numpy`` Generator seeded from
``crc32(point) ^ seed``, so

* a chaos run is reproducible from its seed alone (the virtual clock
  makes the schedule deterministic, the injector makes the faults so);
* points are independent — adding a new fault point, or changing how
  often one is consulted, never perturbs another point's draw sequence.

Fault points wired into the stack:

========================  =================================================
``engine/nan_logits``     poison one active slot's KV before a fused
                          dispatch -> NaN logits for that slot
``pool/alloc_fail``       ``PagePool.alloc`` raises ``PageAllocError``
                          (transient allocator failure, distinct from
                          genuine pool exhaustion)
``core/revoke_mid_quantum``  revoke the grant mid-``EngineCore.step()``
``core/step_overrun``     inflate a quantum's step cost (slow-step fault)
``runtime/early_resume``  training resumes before the predicted bubble
                          end; the runtime arms the grants' revocation
``process/kill``          sever the engine process: ``EngineCore.step()``
                          raises ``ProcessKilled`` between quanta or
                          mid-quantum (after device work, before the
                          journal append) — recovery replays the
                          write-ahead journal (DESIGN.md §11)
========================  =================================================

Use ``FaultSpec`` to arm a point::

    inj = FaultInjector(seed=7, specs=[
        FaultSpec("engine/nan_logits", probability=0.2, max_fires=3),
    ])
    if inj.should_fire("engine/nan_logits"):
        ...

Unarmed points never fire, so a default-constructed injector is inert.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import numpy as np

__all__ = ["FaultInjector", "FaultSpec", "FAULT_POINTS", "ProcessKilled"]

#: the named fault points the serving stack consults (documentation +
#: validation surface; ``FaultSpec`` for an unknown point is an error)
FAULT_POINTS = (
    "engine/nan_logits",
    "pool/alloc_fail",
    "core/revoke_mid_quantum",
    "core/step_overrun",
    "runtime/early_resume",
    "process/kill",
)


class ProcessKilled(RuntimeError):
    """Simulated process death (the ``process/kill`` fault point).

    Raised out of ``EngineCore.step()``; the in-memory engine/core pair is
    unusable afterwards and must be abandoned.  Chaos harnesses catch it,
    truncate the request journal to its fsynced prefix
    (``RequestJournal.crash``), and rebuild a fresh engine via
    ``RequestJournal.recover_into`` (DESIGN.md §11)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Arming description for one fault point.

    ``probability`` is the per-consultation fire chance; ``after`` skips
    the first N consultations (lets a workload warm up before chaos);
    ``max_fires`` caps total fires (None = unbounded)."""

    point: str
    probability: float = 1.0
    after: int = 0
    max_fires: Optional[int] = None

    def __post_init__(self):
        if self.point not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r}; known: {FAULT_POINTS}"
            )


class FaultInjector:
    """Seeded fault oracle.  One instance per chaos run; thread-unsafe by
    design (the serving stack is single-threaded per engine)."""

    def __init__(self, seed: int = 0, specs: tuple = ()):  # noqa: D401
        self.seed = int(seed)
        self.specs = {s.point: s for s in specs}
        self._rngs: dict = {}
        self.consults: dict = {p: 0 for p in self.specs}
        self.fires: dict = {p: 0 for p in self.specs}
        #: optional metrics registry; set by whoever wires the injector in
        #: so every fire lands on the ``fault/injected`` counter
        self.metrics = None

    def _rng(self, point: str) -> np.random.Generator:
        rng = self._rngs.get(point)
        if rng is None:
            # crc32 keys the stream by point name: stable across runs and
            # processes (unlike hash()), independent across points
            rng = np.random.default_rng(
                zlib.crc32(point.encode()) ^ (self.seed & 0xFFFFFFFF)
            )
            self._rngs[point] = rng
        return rng

    def should_fire(self, point: str) -> bool:
        """Consult ``point``: True when the armed spec fires this draw."""
        spec = self.specs.get(point)
        if spec is None:
            return False
        n = self.consults[point]
        self.consults[point] = n + 1
        # the draw happens on EVERY consultation, armed or not past its
        # cap, so max_fires/after never shift later draws in the stream
        hit = self._rng(point).random() < spec.probability
        if n < spec.after:
            return False
        if spec.max_fires is not None and self.fires[point] >= spec.max_fires:
            return False
        if hit:
            self.fires[point] += 1
            if self.metrics is not None:
                self.metrics.counter("fault/injected").inc()
        return hit

    def uniform(self, point: str) -> float:
        """An extra U[0,1) draw from ``point``'s stream (fault shaping:
        e.g. where inside the bubble training resumes)."""
        return float(self._rng(point).random())

    def choice(self, point: str, n: int) -> int:
        """An extra integer draw in [0, n) from ``point``'s stream."""
        return int(self._rng(point).integers(n))

    @property
    def total_fires(self) -> int:
        return sum(self.fires.values())
