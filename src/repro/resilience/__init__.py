"""Failure containment and graceful degradation (DESIGN.md §9).

Three pieces, all host-side and deterministic on the virtual clock:

* ``faults`` — the seeded fault-injection harness (named fault points,
  per-point independent streams) chaos runs are built from;
* ``degradation`` — the hysteretic overload ladder ``EngineCore``
  consults each quantum (spec off -> k shrink -> offline shedding ->
  online deadline shedding);
* ``journal`` / ``snapshot`` — the crash-durability layer (DESIGN.md
  §11): a write-ahead request journal with deterministic replay
  recovery, plus an optional warm-state radix-cache snapshot through
  the training ``Checkpointer``;
* the containment machinery itself lives where the faults land:
  per-slot NaN screens in the fused loops (``serving/engine.py``),
  ``PageAllocError`` handling in ``serving/kv_pool.py``, revocable
  grants in ``serving/core.py``, early-resume handling in
  ``core/filling.py``.
"""
from repro.resilience.degradation import (  # noqa: F401
    LadderConfig,
    LadderStage,
    OverloadLadder,
)
from repro.resilience.faults import (  # noqa: F401
    FAULT_POINTS,
    FaultInjector,
    FaultSpec,
    ProcessKilled,
)
from repro.resilience.journal import (  # noqa: F401
    RecoveryReport,
    RequestJournal,
    read_journal,
)
from repro.resilience.snapshot import EngineSnapshot  # noqa: F401

__all__ = [
    "FAULT_POINTS",
    "EngineSnapshot",
    "FaultInjector",
    "FaultSpec",
    "LadderConfig",
    "LadderStage",
    "OverloadLadder",
    "ProcessKilled",
    "RecoveryReport",
    "RequestJournal",
    "read_journal",
]
