"""Continuous-batching inference engine.

The engine is the *schedulable unit producer* for SpecInF: every public
operation is a short jitted microstep (one prefill, or one decode step over
all active slots), which is exactly the quantum the Kernel Barrier meters
tokens against (DESIGN.md §2, "admission quanta").

Slots: a fixed-capacity decode batch (size ``max_slots``) with per-slot KV
index, so requests of different lengths run concurrently (continuous
batching).  Finished slots are refilled from the queue by the caller
(``core/filling.py`` or the standalone serve loop).

Fast path (DESIGN.md §3):

* ``decode_loop(k)`` fuses k microsteps into one jitted ``lax.scan`` with
  per-slot active/done masking and donated cache buffers — exactly ONE
  device->host transfer per loop, vs ``1 + num_active`` for the legacy
  ``decode_microstep`` (kept for comparison and single-step callers).
* Prefill pads prompts to power-of-two length buckets, so 20 distinct prompt
  lengths compile a handful of programs instead of 20, and
  ``prefill_into_slot`` writes K/V straight into the batch cache on device
  (no host-side cache splice).

Timebase: all request timestamps come from ONE clock chosen at construction
(``clock=``, default ``time.monotonic``).  Collocated runtimes rebind it to
their virtual clock so latencies never mix timebases.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

_req_counter = itertools.count()

#: Fused-loop sizes the engine compiles on demand; callers bucket their k so
#: the set of compiled programs stays bounded (DESIGN.md §2).
DECODE_K_BUCKETS = (1, 2, 4, 8)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [prompt_len] int32
    max_new_tokens: int
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    arrival_time: float = 0.0
    online: bool = False
    # -- filled by the engine --
    generated: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None


class InferenceEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_slots: int = 4,
        max_seq: int = 128,
        compute_dtype=jnp.bfloat16,
        decode_impl: str = "auto",
        prefill_impl: str = "xla",
        clock: Optional[Callable[[], float]] = None,
        min_prefill_bucket: int = 8,
    ):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.compute_dtype = compute_dtype
        self.params = params
        self.clock: Callable[[], float] = clock or time.monotonic
        self.min_prefill_bucket = min_prefill_bucket
        cache = T.init_cache(cfg, max_slots, max_seq, compute_dtype)
        cache["index"] = jnp.zeros((max_slots,), jnp.int32)
        self.cache = cache
        self.slots: list[Optional[Request]] = [None] * max_slots
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.steps_executed = 0
        # perf counters (benchmarks/engine_micro.py reads these)
        self.d2h_transfers = 0  # device->host syncs issued by engine code
        self.generated_tokens_total = 0
        self.prefill_bucket_lengths: set[int] = set()

        self._decode = jax.jit(
            functools.partial(
                T.decode_step, cfg, compute_dtype=compute_dtype,
                attn_impl=decode_impl,
            )
        )
        self._decode_loop = jax.jit(
            functools.partial(
                T.decode_loop, cfg, compute_dtype=compute_dtype,
                attn_impl=decode_impl, max_seq=max_seq,
            ),
            static_argnames=("k",),
            donate_argnames=("tokens", "cache", "remaining"),
        )
        self._prefill_slot = jax.jit(
            functools.partial(
                T.prefill_into_slot, cfg, max_seq=max_seq,
                impl=prefill_impl, compute_dtype=compute_dtype,
            ),
            donate_argnames=("cache",),
        )

    # ------------------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def prefill_compile_count(self) -> int:
        """Distinct prefill programs compiled (one per prompt-length bucket)."""
        return len(self.prefill_bucket_lengths)

    def _bucket_len(self, n: int) -> int:
        """Power-of-two compile bucket for a prompt of length ``n``."""
        b = self.min_prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot.  One engine microstep."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        n = len(req.prompt)
        if n > self.max_seq:
            raise ValueError(
                f"prompt of {n} tokens exceeds engine max_seq={self.max_seq}; "
                "refusing to truncate silently"
            )
        sb = self._bucket_len(n)
        prompt = np.zeros((1, sb), np.int32)
        prompt[0, :n] = np.asarray(req.prompt, np.int32)
        if self.cfg.embed_inputs:
            # stub frontend: embed prompt tokens through the output table
            prompt_in = self.params["embed"][jnp.asarray(prompt)].astype(
                self.compute_dtype
            )
        else:
            prompt_in = jnp.asarray(prompt)
        self.prefill_bucket_lengths.add(sb)
        tok, self.cache = self._prefill_slot(
            self.params, prompt_in, jnp.int32(n), jnp.int32(slot), self.cache
        )
        req.generated.append(int(tok))
        self.d2h_transfers += 1
        self.generated_tokens_total += 1
        if req.first_token_time is None:
            req.first_token_time = self.clock()
        self.tokens = self.tokens.at[slot].set(tok)
        self.slots[slot] = req
        self.steps_executed += 1
        return True

    # ------------------------------------------------------------------
    def decode_loop(self, k: int) -> list[Request]:
        """Run ``k`` fused decode microsteps on-device; returns requests that
        finished.  One device->host transfer total, regardless of ``k``.

        Finished slots freeze mid-loop on device (token, index, and budget
        held in place), so the host never needs to intervene between
        microsteps.  Callers should pick ``k`` from ``DECODE_K_BUCKETS`` to
        bound the number of compiled programs."""
        if self.num_active == 0 or k <= 0:
            return []
        remaining = np.zeros((self.max_slots,), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                remaining[i] = max(r.max_new_tokens - len(r.generated), 0)
        tokens, cache, rem, toks_seq, steps = self._decode_loop(
            self.params, self.tokens, self.cache, jnp.asarray(remaining), k=k
        )
        self.tokens, self.cache = tokens, cache
        toks_np, steps_np, rem_np, idx_np = jax.device_get(
            (toks_seq, steps, rem, cache["index"])
        )
        self.d2h_transfers += 1  # the single fused fetch above
        self.steps_executed += k
        now = self.clock()
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            n = int(steps_np[i])
            req.generated.extend(int(t) for t in toks_np[:n, i])
            self.generated_tokens_total += n
            if rem_np[i] == 0 or idx_np[i] >= self.max_seq - 1:
                req.finish_time = now
                finished.append(req)
                self.slots[i] = None
                self.cache["index"] = self.cache["index"].at[i].set(0)
        return finished

    # ------------------------------------------------------------------
    def decode_microstep(self) -> list[Request]:
        """One decode step over all slots; returns requests that finished.

        Legacy single-step path: syncs to host every step (1 transfer for the
        token batch + 1 per active slot for the finish check).  Kept for
        single-step callers and as the benchmark baseline — the fast path is
        ``decode_loop``."""
        if self.num_active == 0:
            return []
        logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = next_tokens
        self.steps_executed += 1
        finished = []
        host_tokens = np.asarray(next_tokens)
        self.d2h_transfers += 1
        now = self.clock()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(host_tokens[i]))
            self.generated_tokens_total += 1
            index_i = int(self.cache["index"][i])
            self.d2h_transfers += 1  # per-slot finish-check sync
            if len(req.generated) >= req.max_new_tokens or index_i >= (
                self.max_seq - 1
            ):
                req.finish_time = now
                finished.append(req)
                self.slots[i] = None
                self.cache["index"] = self.cache["index"].at[i].set(0)
        return finished

    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        """Weights + cache footprint (Principle-I input)."""
        param_b = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.params))
        cache_b = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self.cache)
        )
        return param_b + cache_b
