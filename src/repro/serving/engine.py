"""Continuous-batching inference engine.

The engine is the *schedulable unit producer* for SpecInF: every public
operation is a short jitted microstep (one prefill, or one decode step over
all active slots), which is exactly the quantum the Kernel Barrier meters
tokens against (DESIGN.md §2, "admission quanta").

Slots: a fixed-capacity decode batch (size ``max_slots``) with per-slot KV
index, so requests of different lengths run concurrently (continuous
batching).  Finished slots are refilled from the queue by the caller
(``core/filling.py`` or the standalone serve loop).

Fast path (DESIGN.md §3):

* ``decode_loop(k)`` fuses k microsteps into one jitted ``lax.scan`` with
  per-slot active/done masking and donated cache buffers — exactly ONE
  device->host transfer per loop, vs the per-step transfer of the legacy
  ``decode_microstep`` (kept for comparison and single-step callers).
* Prefill pads prompts to power-of-two length buckets, so 20 distinct prompt
  lengths compile a handful of programs instead of 20, and
  ``prefill_into_slot`` writes K/V straight into the batch cache on device
  (no host-side cache splice).

Speculative fast path (DESIGN.md §4): constructing the engine with a
``draft_cfg``/``draft_params`` pairing (``configs.base.draft_config``)
enables ``spec_decode_loop(k, gamma)`` — k fused draft-propose /
chunk-verify rounds that emit up to ``gamma + 1`` *verified* tokens per slot
per round under the same one-transfer-per-loop discipline.

Timebase: all request timestamps come from ONE clock chosen at construction
(``clock=``, default ``time.monotonic``).  Collocated runtimes rebind it to
their virtual clock so latencies never mix timebases.  Offline requests
added with the default ``arrival_time == 0.0`` are stamped from the engine
clock at admission, so latency metrics never mix an epoch-zero arrival with
a monotonic/virtual now (online requests keep their explicit arrivals —
including a genuine virtual ``t == 0`` — so queueing delay is preserved).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SpecDecodeConfig
from repro.models import transformer as T

_req_counter = itertools.count()

#: Fused-loop sizes the engine compiles on demand; callers bucket their k so
#: the set of compiled programs stays bounded (DESIGN.md §2).
DECODE_K_BUCKETS = (1, 2, 4, 8)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [prompt_len] int32
    max_new_tokens: int
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    arrival_time: float = 0.0
    online: bool = False
    # -- filled by the engine --
    generated: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None


class InferenceEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_slots: int = 4,
        max_seq: int = 128,
        compute_dtype=jnp.bfloat16,
        decode_impl: str = "auto",
        prefill_impl: str = "xla",
        clock: Optional[Callable[[], float]] = None,
        min_prefill_bucket: int = 8,
        draft_cfg: Optional[ModelConfig] = None,
        draft_params: Any = None,
        spec: Optional[SpecDecodeConfig] = None,
        spec_seed: int = 0,
    ):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.compute_dtype = compute_dtype
        self.params = params
        self.clock: Callable[[], float] = clock or time.monotonic
        self.min_prefill_bucket = min_prefill_bucket
        cache = T.init_cache(cfg, max_slots, max_seq, compute_dtype)
        cache["index"] = jnp.zeros((max_slots,), jnp.int32)
        self.cache = cache
        self.slots: list[Optional[Request]] = [None] * max_slots
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.steps_executed = 0
        # perf counters (benchmarks/engine_micro.py reads these)
        self.d2h_transfers = 0  # device->host syncs issued by engine code
        self.generated_tokens_total = 0
        self.prefill_bucket_lengths: set[int] = set()
        # speculative-decoding counters (spec_acceptance_rate reads these)
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0

        self._decode = jax.jit(
            functools.partial(
                T.decode_step, cfg, compute_dtype=compute_dtype,
                attn_impl=decode_impl,
            )
        )
        self._decode_loop = jax.jit(
            functools.partial(
                T.decode_loop, cfg, compute_dtype=compute_dtype,
                attn_impl=decode_impl, max_seq=max_seq,
            ),
            static_argnames=("k",),
            donate_argnames=("tokens", "cache", "remaining"),
        )
        self._prefill_slot = jax.jit(
            functools.partial(
                T.prefill_into_slot, cfg, max_seq=max_seq,
                impl=prefill_impl, compute_dtype=compute_dtype,
            ),
            donate_argnames=("cache",),
        )

        # --- speculative decoding (draft/target pairing) ---------------
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_cache = None
        self.spec_cfg = spec or SpecDecodeConfig()
        if self.spec_enabled:
            assert draft_cfg is not None, "draft_params without draft_cfg"
            assert draft_cfg.vocab_size == cfg.vocab_size, (
                "draft and target must share a vocabulary"
            )
            dcache = T.init_cache(draft_cfg, max_slots, max_seq, compute_dtype)
            dcache["index"] = jnp.zeros((max_slots,), jnp.int32)
            self.draft_cache = dcache
            self._spec_key = jax.random.PRNGKey(spec_seed)
            from repro.spec.loop import spec_decode_loop as _spec_fn

            self._spec_loop = jax.jit(
                functools.partial(
                    _spec_fn, cfg, draft_cfg, mode=self.spec_cfg.mode,
                    max_seq=max_seq, sim_accept_p=self.spec_cfg.sim_accept_p,
                    compute_dtype=compute_dtype, attn_impl=decode_impl,
                ),
                static_argnames=("k", "gamma"),
                donate_argnames=(
                    "tokens", "cache", "draft_cache", "remaining", "key"
                ),
            )
            self._draft_prefill = jax.jit(
                functools.partial(
                    T.prefill_into_slot, draft_cfg, max_seq=max_seq,
                    impl=prefill_impl, compute_dtype=compute_dtype,
                ),
                donate_argnames=("cache",),
            )

    # ------------------------------------------------------------------
    @property
    def spec_enabled(self) -> bool:
        return self.draft_params is not None

    @property
    def spec_acceptance_rate(self) -> float:
        """Observed draft-token acceptance across all spec rounds (pre
        budget-clamp: measures draft quality, not budget truncation)."""
        if self.spec_drafted == 0:
            return float("nan")
        return self.spec_accepted / self.spec_drafted

    # ------------------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def prefill_compile_count(self) -> int:
        """Distinct prefill programs compiled (one per prompt-length bucket)."""
        return len(self.prefill_bucket_lengths)

    def _bucket_len(self, n: int) -> int:
        """Power-of-two compile bucket for a prompt of length ``n``."""
        b = self.min_prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.max_seq)

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot.  One engine microstep."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        n = len(req.prompt)
        if n > self.max_seq:
            raise ValueError(
                f"prompt of {n} tokens exceeds engine max_seq={self.max_seq}; "
                "refusing to truncate silently"
            )
        if req.arrival_time == 0.0 and not req.online:
            # default epoch-zero arrival on an offline request: stamp from
            # the engine clock so latency metrics never mix timebases.
            # Online requests keep an explicit 0.0 — on a virtual clock that
            # is a real arrival instant, and restamping it at admission
            # would erase the request's queueing delay.
            req.arrival_time = self.clock()
        sb = self._bucket_len(n)
        prompt = np.zeros((1, sb), np.int32)
        prompt[0, :n] = np.asarray(req.prompt, np.int32)

        def embed_or_pass(params):
            if self.cfg.embed_inputs:
                # stub frontend: embed prompt tokens through the output table
                return params["embed"][jnp.asarray(prompt)].astype(
                    self.compute_dtype
                )
            return jnp.asarray(prompt)

        self.prefill_bucket_lengths.add(sb)
        tok, self.cache = self._prefill_slot(
            self.params, embed_or_pass(self.params), jnp.int32(n),
            jnp.int32(slot), self.cache,
        )
        if self.spec_enabled:
            # draft cache tracks the same prefix; its first-token output is
            # never fetched (no extra device->host transfer)
            _, self.draft_cache = self._draft_prefill(
                self.draft_params, embed_or_pass(self.draft_params),
                jnp.int32(n), jnp.int32(slot), self.draft_cache,
            )
        req.generated.append(int(tok))
        self.d2h_transfers += 1
        self.generated_tokens_total += 1
        if req.first_token_time is None:
            req.first_token_time = self.clock()
        self.tokens = self.tokens.at[slot].set(tok)
        self.slots[slot] = req
        self.steps_executed += 1
        return True

    # ------------------------------------------------------------------
    def decode_loop(self, k: int) -> list[Request]:
        """Run ``k`` fused decode microsteps on-device; returns requests that
        finished.  One device->host transfer total, regardless of ``k``.

        Finished slots freeze mid-loop on device (token, index, and budget
        held in place), so the host never needs to intervene between
        microsteps.  Callers should pick ``k`` from ``DECODE_K_BUCKETS`` to
        bound the number of compiled programs."""
        if self.num_active == 0 or k <= 0:
            return []
        remaining = np.zeros((self.max_slots,), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                remaining[i] = max(r.max_new_tokens - len(r.generated), 0)
        tokens, cache, rem, toks_seq, steps = self._decode_loop(
            self.params, self.tokens, self.cache, jnp.asarray(remaining), k=k
        )
        self.tokens, self.cache = tokens, cache
        toks_np, steps_np, rem_np, idx_np = jax.device_get(
            (toks_seq, steps, rem, cache["index"])
        )
        self.d2h_transfers += 1  # the single fused fetch above
        self.steps_executed += k
        now = self.clock()
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            n = int(steps_np[i])
            req.generated.extend(int(t) for t in toks_np[:n, i])
            self.generated_tokens_total += n
            if rem_np[i] == 0 or idx_np[i] >= self.max_seq - 1:
                req.finish_time = now
                finished.append(req)
                self.slots[i] = None
                self.cache["index"] = self.cache["index"].at[i].set(0)
        return finished

    # ------------------------------------------------------------------
    def spec_decode_loop(self, k: int, gamma: int) -> list[Request]:
        """Run ``k`` fused speculative rounds (draft-propose + chunk-verify);
        returns requests that finished.  One device->host transfer total.

        Each round spends one schedulable quantum and emits up to
        ``gamma + 1`` *verified* tokens per slot (greedy mode: byte-identical
        to the plain greedy ``decode_loop`` stream).  Pick ``k`` from
        ``DECODE_K_BUCKETS`` and ``gamma`` from the pairing's gamma buckets
        to bound the number of compiled programs.  A slot needs room for a
        whole chunk, so it retires once ``index + gamma >= max_seq`` —
        slightly earlier than the plain loop's ``max_seq - 1`` horizon."""
        assert self.spec_enabled, "engine built without a draft pairing"
        if self.num_active == 0 or k <= 0:
            return []
        remaining = np.zeros((self.max_slots,), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                remaining[i] = max(r.max_new_tokens - len(r.generated), 0)
        (
            self.tokens, self.cache, self.draft_cache, rem, self._spec_key,
            out_toks, n_out, accepted, proposed,
        ) = self._spec_loop(
            self.params, self.draft_params, self.tokens, self.cache,
            self.draft_cache, jnp.asarray(remaining), self._spec_key,
            k=k, gamma=gamma,
        )
        toks_np, n_np, acc_np, prop_np, rem_np, idx_np = jax.device_get(
            (out_toks, n_out, accepted, proposed, rem, self.cache["index"])
        )
        self.d2h_transfers += 1  # the single fused fetch above
        self.steps_executed += k
        self.spec_rounds += k
        now = self.clock()
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            for j in range(k):
                n = int(n_np[j, i])
                req.generated.extend(int(t) for t in toks_np[j, i, :n])
                self.generated_tokens_total += n
            self.spec_accepted += int(acc_np[:, i].sum())
            self.spec_drafted += int(prop_np[:, i].sum())
            if rem_np[i] == 0 or idx_np[i] + gamma >= self.max_seq:
                req.finish_time = now
                finished.append(req)
                self.slots[i] = None
                self.cache["index"] = self.cache["index"].at[i].set(0)
                self.draft_cache["index"] = (
                    self.draft_cache["index"].at[i].set(0)
                )
        return finished

    # ------------------------------------------------------------------
    def decode_microstep(self) -> list[Request]:
        """One decode step over all slots; returns requests that finished.

        Legacy single-step path: syncs to host every step, but the token
        batch and the per-slot finish-check indices come down in ONE batched
        transfer (the old code paid 1 + num_active transfers per step).
        Kept for single-step callers and as the benchmark baseline — the
        fast path is ``decode_loop``."""
        if self.num_active == 0:
            return []
        logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = next_tokens
        self.steps_executed += 1
        finished = []
        host_tokens, idx_np = jax.device_get(
            (next_tokens, self.cache["index"])
        )
        self.d2h_transfers += 1  # tokens + finish-check indices, batched
        now = self.clock()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(host_tokens[i]))
            self.generated_tokens_total += 1
            if len(req.generated) >= req.max_new_tokens or int(
                idx_np[i]
            ) >= (self.max_seq - 1):
                req.finish_time = now
                finished.append(req)
                self.slots[i] = None
                self.cache["index"] = self.cache["index"].at[i].set(0)
        return finished

    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        """Weights + cache footprint (Principle-I input)."""
        param_b = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.params))
        cache_b = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self.cache)
        )
        return param_b + cache_b
