"""Continuous-batching inference engine.

The engine is the *schedulable unit producer* for SpecInF: every public
operation is a short jitted microstep (one prefill, or one decode step over
all active slots), which is exactly the quantum the Kernel Barrier meters
tokens against (DESIGN.md §2, "admission quanta").

Slots: a fixed-capacity decode batch (size ``max_slots``) with per-slot KV
index, so requests of different lengths run concurrently (continuous
batching).  Finished slots are refilled from the queue by the caller
(``core/filling.py`` or the standalone serve loop).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

_req_counter = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [prompt_len] int32
    max_new_tokens: int
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    arrival_time: float = 0.0
    online: bool = False
    # -- filled by the engine --
    generated: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None


class InferenceEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_slots: int = 4,
        max_seq: int = 128,
        compute_dtype=jnp.bfloat16,
    ):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.compute_dtype = compute_dtype
        self.params = params
        cache = T.init_cache(cfg, max_slots, max_seq, compute_dtype)
        cache["index"] = jnp.zeros((max_slots,), jnp.int32)
        self.cache = cache
        self.slots: list[Optional[Request]] = [None] * max_slots
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.steps_executed = 0

        self._decode = jax.jit(
            functools.partial(T.decode_step, cfg, compute_dtype=compute_dtype)
        )
        self._prefill_one = jax.jit(
            functools.partial(
                T.prefill, cfg, max_seq=max_seq, compute_dtype=compute_dtype
            ),
            static_argnames=(),
        )

    # ------------------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    # ------------------------------------------------------------------
    def add_request(self, req: Request, now: Optional[float] = None) -> bool:
        """Prefill ``req`` into a free slot.  One engine microstep."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        if self.cfg.embed_inputs:
            # stub frontend: embed prompt tokens through the output table
            prompt_in = self.params["embed"][prompt].astype(self.compute_dtype)
        else:
            prompt_in = prompt
        logits, cache1 = self._prefill_one(self.params, prompt_in)
        tok = jnp.argmax(logits[0]).astype(jnp.int32)
        req.generated.append(int(tok))
        if req.first_token_time is None:
            req.first_token_time = time.monotonic() if now is None else now
        # splice single-request cache into the batch cache at ``slot``
        self.cache = _splice_cache(self.cfg, self.cache, cache1, slot)
        self.cache["index"] = self.cache["index"].at[slot].set(len(req.prompt))
        self.tokens = self.tokens.at[slot].set(tok)
        self.slots[slot] = req
        self.steps_executed += 1
        return True

    # ------------------------------------------------------------------
    def decode_microstep(self, now: Optional[float] = None) -> list[Request]:
        """One decode step over all slots; returns requests that finished."""
        if self.num_active == 0:
            return []
        logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = next_tokens
        self.steps_executed += 1
        finished = []
        host_tokens = np.asarray(next_tokens)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(host_tokens[i]))
            if len(req.generated) >= req.max_new_tokens or int(
                self.cache["index"][i]
            ) >= self.max_seq - 1:
                req.finish_time = time.monotonic() if now is None else now
                finished.append(req)
                self.slots[i] = None
                self.cache["index"] = self.cache["index"].at[i].set(0)
        return finished

    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        """Weights + cache footprint (Principle-I input)."""
        param_b = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.params))
        cache_b = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self.cache)
        )
        return param_b + cache_b


def _splice_cache(cfg: ModelConfig, batch_cache, single_cache, slot: int):
    """Write a 1-slot cache (from prefill) into batch cache position ``slot``.

    Cache layer tensors are stacked [L, B, ...]; slot is on the B axis."""

    def splice(b, s):
        if b.ndim == 0 or b.shape == s.shape and b.ndim == 1:
            return b  # index handled by caller
        return jax.lax.dynamic_update_index_in_dim(
            b, s[:, 0].astype(b.dtype), slot, axis=1
        )

    new_layers = jax.tree.map(
        lambda b, s: splice(b, s), batch_cache["layers"], single_cache["layers"]
    )
    return {"index": batch_cache["index"], "layers": new_layers}
