"""Continuous-batching inference engine.

The engine is the *schedulable unit producer* for SpecInF: every public
operation is a short jitted microstep (one prefill, or one decode step over
all active slots), which is exactly the quantum the Kernel Barrier meters
tokens against (DESIGN.md §2, "admission quanta").

Slots: a fixed-capacity decode batch (size ``max_slots``) with per-slot KV
index, so requests of different lengths run concurrently (continuous
batching).  Finished slots are refilled from the queue by the caller
(``core/filling.py`` or the standalone serve loop).

Paged KV cache (DESIGN.md §5): attention-family engines store KV in a
shared pool of fixed-size physical pages addressed through per-slot block
tables (``kv_page_size``; 0 forces the legacy dense ``[B, S_max]`` layout,
kept for recurrent families and A/B benchmarks).  Admission is
capacity-based — a request is admitted iff the pool can cover its
worst-case page need, so ``max_slots`` may exceed what the dense layout
could hold — and a radix tree over page-aligned prompt chunks serves shared
prefixes straight from cached pages: a prefix hit increfs the pages, skips
prefill compute for the covered length, and prefills only the suffix
through the chunk-verify path.  Pages are topped up lazily ahead of each
fused loop, trimmed back after speculative rollback, and released (not
index-reset) at retirement.

Fast path (DESIGN.md §3):

* ``decode_loop(k)`` fuses k microsteps into one jitted ``lax.scan`` with
  per-slot active/done masking and donated cache buffers — exactly ONE
  device->host transfer per loop, vs the per-step transfer of the legacy
  ``decode_microstep`` (kept for comparison and single-step callers).
* Chunked prefill (DESIGN.md §7, default for attention families): admission
  only *reserves* a slot; the prompt streams as fixed-width chunks
  (``prefill_chunk``) through ONE compiled batched program per model —
  replacing both the power-of-two bucket family and the per-request draft
  prefill dispatch — so a long prompt never monopolizes a step and the
  EngineCore can meter prefill against a token budget.  The legacy
  ``add_request`` contract drives the chunks to completion at admission;
  ``prefill_chunk=0`` restores monolithic bucket prefill
  (``prefill_into_slot`` writes K/V straight into the batch cache on
  device, prompts padded to power-of-two buckets), which recurrent
  families always use.

Speculative fast path (DESIGN.md §4): constructing the engine with a
``draft_cfg``/``draft_params`` pairing (``configs.base.draft_config``)
enables ``spec_decode_loop(k, gamma)`` — k fused draft-propose /
chunk-verify rounds that emit up to ``gamma + 1`` *verified* tokens per slot
per round under the same one-transfer-per-loop discipline.

Lifecycle (DESIGN.md §6): the request-management surface now lives in
``serving/core.py`` — ``EngineCore.step()`` with priority classes,
preemption, and streaming outputs.  ``add_request`` / ``decode_loop`` /
``spec_decode_loop`` remain as thin DEPRECATED shims delegating to the
core (``scripts/check_api_surface.py`` pins them); the engine keeps only
the compute primitives: ``_admit_request`` (one prefill microstep into a
free slot), ``_drive_decode_loop`` / ``_drive_spec_loop`` (the fused
device loops), and ``evict_slot`` (release a slot's pages and cache
indices WITHOUT finishing — the preempt/abort path).

Timebase: all request timestamps come from ONE clock chosen at construction
(``clock=``, default ``time.monotonic``).  Collocated runtimes rebind it to
their virtual clock so latencies never mix timebases.  Offline requests
added with the default ``arrival_time == 0.0`` are stamped from the engine
clock at admission, so latency metrics never mix an epoch-zero arrival with
a monotonic/virtual now (online requests keep their explicit arrivals —
including a genuine virtual ``t == 0`` — so queueing delay is preserved).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SpecDecodeConfig
from repro.models import transformer as T
from repro.obs import Observability
from repro.serving.kv_pool import PageAllocError, PagePool, RadixCache

_req_counter = itertools.count()


def advance_request_ids(floor: int) -> None:
    """Ensure future auto-assigned request ids are ``>= floor``.

    Journal recovery (DESIGN.md §11) re-creates requests with their
    journaled ids; without bumping the process-wide counter past them, a
    fresh ``submit()`` could collide with a replayed id."""
    global _req_counter
    nxt = next(_req_counter)
    _req_counter = itertools.count(max(nxt, int(floor)))

#: Fused-loop sizes the engine compiles on demand; callers bucket their k so
#: the set of compiled programs stays bounded (DESIGN.md §2).
DECODE_K_BUCKETS = (1, 2, 4, 8)

#: Default physical page size (tokens) for the paged KV pool.  A power of
#: two, so power-of-two prefill buckets stay page-aligned; >= 8 sublanes so
#: one page is a legal Pallas KV tile (DESIGN.md §5).
DEFAULT_KV_PAGE_SIZE = 16

#: Default chunked-prefill width (tokens per slot per wave, DESIGN.md §7).
#: One compiled program at this fixed width replaces the whole power-of-two
#: prefill bucket family for attention-family engines.
DEFAULT_PREFILL_CHUNK = 32

_ATTENTION_FAMILIES = ("dense", "moe", "audio", "vlm")


class RegistryCounterView:
    """Thin view (DESIGN.md §8): a historical ``InferenceEngine`` counter
    attribute backed by a ``repro.obs`` registry counter under a stable
    name.  ``engine.d2h_transfers += 1`` and
    ``engine.obs.metrics.counter("engine/d2h_transfers")`` are the SAME
    cell, so the legacy attribute surface and the registry can never
    diverge — ``scripts/check_api_surface.py`` pins the mapping.  The
    counter object is cached on the instance after the first access, so
    hot paths pay one ``getattr`` plus an integer add."""

    def __init__(self, name: str):
        self.name = name
        self._cache_attr = "_ctr_" + name.replace("/", "_")

    def _cell(self, obj):
        cell = getattr(obj, self._cache_attr, None)
        if cell is None:
            cell = obj.obs.metrics.counter(self.name)
            setattr(obj, self._cache_attr, cell)
        return cell

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return self._cell(obj).value

    def __set__(self, obj, value):
        self._cell(obj).set(value)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [prompt_len] int32
    max_new_tokens: int
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    arrival_time: float = 0.0
    online: bool = False
    # -- filled by the engine --
    generated: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None


class InferenceEngine:
    # Historical perf-counter attributes, now thin views over the metrics
    # registry (stable names: repro.obs.metrics.STABLE_NAMES; mapping
    # pinned by scripts/check_api_surface.py).  Reads/writes hit the same
    # cell as obs.metrics.counter(name).
    d2h_transfers = RegistryCounterView("engine/d2h_transfers")
    steps_executed = RegistryCounterView("engine/steps_executed")
    generated_tokens_total = RegistryCounterView("engine/generated_tokens")
    prefill_prompt_tokens = RegistryCounterView("engine/prefill_prompt_tokens")
    prefill_skipped_tokens = RegistryCounterView(
        "engine/prefill_skipped_tokens"
    )
    prefill_metered_tokens = RegistryCounterView(
        "engine/prefill_metered_tokens"
    )
    spec_rounds = RegistryCounterView("engine/spec_rounds")
    spec_drafted = RegistryCounterView("engine/spec_drafted")
    spec_accepted = RegistryCounterView("engine/spec_accepted")

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_slots: int = 4,
        max_seq: int = 128,
        compute_dtype=jnp.bfloat16,
        decode_impl: str = "auto",
        prefill_impl: str = "xla",
        clock: Optional[Callable[[], float]] = None,
        min_prefill_bucket: int = 8,
        draft_cfg: Optional[ModelConfig] = None,
        draft_params: Any = None,
        spec: Optional[SpecDecodeConfig] = None,
        spec_seed: int = 0,
        kv_page_size: Optional[int] = None,
        kv_pool_pages: Optional[int] = None,
        enable_prefix_cache: bool = True,
        prefill_chunk: Optional[int] = None,
        obs: Optional[Observability] = None,
        fault_injector=None,
    ):
        # observability bundle FIRST: the counter attributes below are
        # RegistryCounterView descriptors whose backing cells live in
        # ``self.obs.metrics``, so it must exist before any ``= 0`` lands
        self.obs = obs or Observability()
        #: optional seeded ``FaultInjector`` (DESIGN.md §9): consulted at
        #: the ``engine/nan_logits`` point before each fused dispatch (and
        #: handed to the page pool for ``pool/alloc_fail``); None = inert
        self.fault_injector = fault_injector
        if fault_injector is not None:
            fault_injector.metrics = self.obs.metrics
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.compute_dtype = compute_dtype
        self.params = params
        self.clock: Callable[[], float] = clock or time.monotonic
        self.min_prefill_bucket = min_prefill_bucket
        #: decode-path attention impl, kept for programs built after
        #: construction (the host-proposed tree-verify rounds)
        self._attn_impl = decode_impl

        # --- chunked prefill (DESIGN.md §7): None -> auto (on for attention
        # families, whose chunk attention is the verify shape; recurrent
        # families keep the monolithic dt-masked bucket prefill); 0 -> off.
        if prefill_chunk is None:
            prefill_chunk = (
                DEFAULT_PREFILL_CHUNK if cfg.family in _ATTENTION_FAMILIES
                else 0
            )
        if prefill_chunk:
            assert cfg.family in _ATTENTION_FAMILIES, (
                f"chunked prefill needs an attention family, not "
                f"{cfg.family!r}"
            )
        self.prefill_chunk = prefill_chunk
        #: per-slot pending prompt-token streams while PREFILLING (target
        #: and draft progress differ under prefix hits: the draft has no
        #: prefix pool and always streams the whole prompt)
        self._prefill_left: list[Optional[np.ndarray]] = [None] * max_slots
        self._draft_prefill_left: list[Optional[np.ndarray]] = (
            [None] * max_slots
        )
        #: device [B] next-token array from the wave that completed each
        #: slot's target prefill, fetched in ONE batched d2h at completion
        self._prefill_tok: list = [None] * max_slots
        #: slot -> metered tokens taken by the LAST _drive_prefill_chunks
        #: call (the core turns these into per-slot prefill-chunk spans)
        self.last_prefill_slot_tokens: dict[int, int] = {}

        # --- KV layout: paged pool (attention families) or dense rows ---
        if kv_page_size is None:
            kv_page_size = (
                DEFAULT_KV_PAGE_SIZE if cfg.family in _ATTENTION_FAMILIES
                else 0
            )
        self.paged = kv_page_size > 0
        self.kv_page_size = kv_page_size
        self.pool: Optional[PagePool] = None
        self.prefix_cache: Optional[RadixCache] = None
        if self.paged:
            assert cfg.family in _ATTENTION_FAMILIES, (
                f"paged KV cache needs an attention family, not {cfg.family!r}"
            )
            assert kv_page_size & (kv_page_size - 1) == 0, (
                "kv_page_size must be a power of two (page-aligned buckets)"
            )
            self.pages_per_slot = -(-max_seq // kv_page_size)
            # default pool: dense-equivalent logical capacity (+ sentinel);
            # callers shrink it (or raise max_slots) to trade layout slack
            # for concurrency — see benchmarks/engine_micro.py
            num_pages = kv_pool_pages or (
                max_slots * self.pages_per_slot + 1
            )
            self.pool = PagePool(num_pages, kv_page_size)
            self.pool.fault_injector = fault_injector
            if enable_prefix_cache:
                self.prefix_cache = RadixCache(self.pool)
            cache = T.init_paged_cache(
                cfg, max_slots, num_pages, kv_page_size,
                self.pages_per_slot, compute_dtype,
            )
            # prefill buckets must stay page-aligned for the page scatter
            # (round up: doubling then preserves page multiples)
            self.min_prefill_bucket = kv_page_size * (
                -(-max(min_prefill_bucket, 1) // kv_page_size)
            )
            self._slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
            self._slot_reserved = [0] * max_slots
            self._slot_idx = [0] * max_slots
            self._slot_horizon = [0] * max_slots
            # host mirror of the device block tables: mutations land here
            # and ship as ONE whole-table h2d transfer (the table is tiny;
            # per-entry device scatters cost more in dispatch than the copy)
            self._bt_host = np.zeros(
                (max_slots, self.pages_per_slot + 1), np.int32
            )
            self._bt_dirty = False
        else:
            cache = T.init_cache(cfg, max_slots, max_seq, compute_dtype)
            cache["index"] = jnp.zeros((max_slots,), jnp.int32)
        self.cache = cache
        self._core = None  # lazily-built EngineCore (the .core property)
        self.slots: list[Optional[Request]] = [None] * max_slots
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.steps_executed = 0
        # perf counters (benchmarks/engine_micro.py reads these)
        self.d2h_transfers = 0  # device->host syncs issued by engine code
        self.generated_tokens_total = 0
        #: (model, impl) -> distinct program widths compiled, where model is
        #: "target"/"draft" and impl is "bucket" (monolithic power-of-two),
        #: "suffix" (prefix-hit suffix prefill), or "chunk" (the one
        #: fixed-width chunked-prefill program).  ``prefill_compile_count``
        #: sums the buckets; ``prefill_compile_counts`` reports them.
        self._prefill_programs: dict[tuple[str, str], set] = {}
        # prefix-cache counters (prefill_skip_fraction reads these)
        self.prefill_prompt_tokens = 0
        self.prefill_skipped_tokens = 0
        #: layout-independent prefill meter (DESIGN.md §7): per admission,
        #: the max of the target's computed tokens (prompt minus prefix
        #: skip) and the draft's (always the whole prompt — no draft prefix
        #: pool), the same per-slot-per-wave metric the chunked driver
        #: charges, so ``EngineCore.step`` prices monolithic and chunked
        #: prefill identically
        self.prefill_metered_tokens = 0
        # speculative-decoding counters (spec_acceptance_rate reads these)
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0

        self._decode = jax.jit(
            functools.partial(
                T.decode_step, cfg, compute_dtype=compute_dtype,
                attn_impl=decode_impl,
            )
        )
        self._decode_loop = jax.jit(
            functools.partial(
                T.decode_loop, cfg, compute_dtype=compute_dtype,
                attn_impl=decode_impl, max_seq=max_seq,
            ),
            static_argnames=("k",),
            donate_argnames=("tokens", "cache", "remaining"),
        )
        if self.paged:
            self._prefill_slot = jax.jit(
                functools.partial(
                    T.prefill_into_slot_paged, cfg,
                    impl=prefill_impl, compute_dtype=compute_dtype,
                ),
                donate_argnames=("cache",),
            )
            self._suffix_prefill = jax.jit(
                functools.partial(
                    T.prefill_suffix_into_slot, cfg,
                    compute_dtype=compute_dtype, attn_impl=decode_impl,
                ),
                donate_argnames=("cache",),
            )
        else:
            self._prefill_slot = jax.jit(
                functools.partial(
                    T.prefill_into_slot, cfg, max_seq=max_seq,
                    impl=prefill_impl, compute_dtype=compute_dtype,
                ),
                donate_argnames=("cache",),
            )
        if self.prefill_chunk:
            # the ONE chunked-prefill program: every argument is traced, so
            # a single compile serves every mix of slots / chunk lengths /
            # prefill offsets (dense and paged branch on the cache layout)
            self._prefill_chunks = jax.jit(
                functools.partial(
                    T.prefill_chunks_into_slots, cfg,
                    compute_dtype=compute_dtype, attn_impl=decode_impl,
                ),
                donate_argnames=("cache",),
            )

        # --- speculative decoding (draft/target pairing) ---------------
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_cache = None
        self.spec_cfg = spec or SpecDecodeConfig()
        #: PRNG stream for simulated-acceptance modes (spec loop AND the
        #: host-proposed tree rounds, which exist without a draft pairing)
        self._spec_key = jax.random.PRNGKey(spec_seed)
        if self.spec_enabled:
            assert draft_cfg is not None, "draft_params without draft_cfg"
            assert draft_cfg.vocab_size == cfg.vocab_size, (
                "draft and target must share a vocabulary"
            )
            dcache = T.init_cache(draft_cfg, max_slots, max_seq, compute_dtype)
            dcache["index"] = jnp.zeros((max_slots,), jnp.int32)
            self.draft_cache = dcache
            from repro.spec.loop import spec_decode_loop as _spec_fn

            self._spec_loop = jax.jit(
                functools.partial(
                    _spec_fn, cfg, draft_cfg, mode=self.spec_cfg.mode,
                    max_seq=max_seq, sim_accept_p=self.spec_cfg.sim_accept_p,
                    compute_dtype=compute_dtype, attn_impl=decode_impl,
                ),
                static_argnames=("k", "gamma"),
                donate_argnames=(
                    "tokens", "cache", "draft_cache", "remaining", "key"
                ),
            )
            self._draft_prefill = jax.jit(
                functools.partial(
                    T.prefill_into_slot, draft_cfg, max_seq=max_seq,
                    impl=prefill_impl, compute_dtype=compute_dtype,
                ),
                donate_argnames=("cache",),
            )
            if self.prefill_chunk:
                # draft prefill folds into the same admission wave as the
                # target's (one batched dispatch per model per wave, not
                # one per admitted request); its first-token logits are
                # never read, so the program skips the vocab projection
                self._draft_prefill_chunks = jax.jit(
                    functools.partial(
                        T.prefill_chunks_into_slots, draft_cfg,
                        compute_dtype=compute_dtype, attn_impl=decode_impl,
                        need_logits=False,
                    ),
                    donate_argnames=("cache",),
                )

        # --- pluggable proposers + routing (DESIGN.md §10) --------------
        #: name -> Proposer.  ``spec_cfg.proposer`` selects the initial
        #: set: "auto" registers every applicable source on a DRAFT-PAIRED
        #: engine (the draft model plus prompt-lookup n-gram on attention
        #: families) but nothing on a plain engine — speculation stays
        #: opt-in, so engines built without a draft pairing behave exactly
        #: as before.  "draft"/"ngram" pin one ("ngram" enables host-only
        #: speculation on a plain engine); "suffix" starts empty (a
        #: corpus-backed ``StaticSuffixProposer`` needs the corpus —
        #: callers attach it via ``register_proposer``); "none" disables
        #: routing entirely.
        self._proposers: dict = {}
        self._router = None
        self._tree_round_cache: dict = {}
        #: per-slot (accepted, proposed) from the LAST fused spec loop —
        #: the router's draft-path feedback
        self._last_spec_slot_stats: dict = {}
        pchoice = self.spec_cfg.proposer
        if pchoice != "none":
            from repro.spec.proposers import DraftModelProposer, NgramProposer

            if self.spec_enabled and pchoice in ("auto", "draft"):
                self._proposers["draft"] = DraftModelProposer(
                    draft_cost_ratio=self.spec_cfg.draft_cost_ratio
                )
            if cfg.family in _ATTENTION_FAMILIES and (
                pchoice == "ngram"
                or (pchoice == "auto" and self.spec_enabled)
            ):
                self._proposers["ngram"] = NgramProposer(
                    order=self.spec_cfg.ngram_order
                )
            if self._proposers:
                self._rebuild_router()

    # ------------------------------------------------------------------
    @property
    def spec_enabled(self) -> bool:
        return self.draft_params is not None

    @property
    def host_spec_enabled(self) -> bool:
        """True when a host-side (model-free) proposer is registered — the
        tree-verify path is available even without a draft pairing."""
        return any(p.kind == "host" for p in self._proposers.values())

    @property
    def proposer_router(self):
        return self._router

    def register_proposer(self, proposer) -> None:
        """Attach an additional candidate source (e.g. a corpus-backed
        ``StaticSuffixProposer``) and rebuild the router over the new set.
        Host proposers need an attention family (tree verification needs
        parallel position scoring)."""
        if proposer.kind == "host":
            assert self.cfg.family in _ATTENTION_FAMILIES, (
                f"host proposers need an attention family, not "
                f"{self.cfg.family!r}"
            )
        self._proposers[proposer.name] = proposer
        self._rebuild_router()

    def _rebuild_router(self) -> None:
        from repro.spec.proposers import ProposerRouter

        device = tuple(
            n for n, p in self._proposers.items() if p.kind == "device"
        )
        self._router = ProposerRouter(
            list(self._proposers),
            device_names=device,
            ewma=self.spec_cfg.router_ewma,
            init_acceptance=self.spec_cfg.router_init_acceptance,
            draft_cost_ratio=self.spec_cfg.draft_cost_ratio,
        )

    def route_proposer(self, gamma: int):
        """Route the coming quantum: ONE proposer for the whole batch (the
        engine dispatches one fused program per quantum), picked by summed
        per-slot score.  Returns the name, or None when no proposer is
        registered (callers fall back to the historical dispatch)."""
        if self._router is None:
            return None
        slots = [
            i for i, r in enumerate(self.slots)
            if r is not None and not self.slot_prefilling(i)
        ]
        name = self._router.pick_majority(slots, gamma)
        self.obs.metrics.counter("spec/proposer/router_switches").set(
            self._router.switches
        )
        return name

    def proposer_round_cost(self, name: str, gamma: int) -> float:
        """Quantum steps one routed round will spend (grant pricing)."""
        return self._router.round_cost(name, gamma)

    @property
    def spec_acceptance_rate(self) -> float:
        """Observed draft-token acceptance across all spec rounds (pre
        budget-clamp: measures draft quality, not budget truncation)."""
        if self.spec_drafted == 0:
            return float("nan")
        return self.spec_accepted / self.spec_drafted

    # ------------------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def slot_prefilling(self, i: int) -> bool:
        """True while slot ``i`` still has prompt chunks to stream (target
        or draft side) — such a slot is frozen in the fused loops and never
        retires mid-prefill."""
        return (
            self._prefill_left[i] is not None
            or self._draft_prefill_left[i] is not None
        )

    @property
    def num_prefilling(self) -> int:
        return sum(
            self.slot_prefilling(i) for i in range(self.max_slots)
        )

    def _record_prefill_program(
        self, model: str, impl: str, width: int
    ) -> None:
        self._prefill_programs.setdefault((model, impl), set()).add(width)

    @property
    def prefill_compile_count(self) -> int:
        """Distinct prefill programs compiled across models and impls (one
        per (model, impl, width) triple).  Chunked prefill pins this to a
        small constant — one fixed-width program per model — where the
        bucket family grew with the prompt-length distribution."""
        return sum(len(v) for v in self._prefill_programs.values())

    def prefill_compile_counts(self) -> dict[str, int]:
        """Per-model (target/draft), per-impl (bucket/suffix/chunk) prefill
        program counts — the unconflated view of
        ``prefill_compile_count``."""
        return {
            f"{model}/{impl}": len(widths)
            for (model, impl), widths in sorted(self._prefill_programs.items())
        }

    def _bucket_len(self, n: int, page_aligned: Optional[bool] = None) -> int:
        """Power-of-two compile bucket for a prompt of length ``n``.

        Page-aligned buckets (the paged default) cap at ``max_seq`` rounded
        UP to a page multiple — the bucket-page scatter needs alignment
        even when ``max_seq`` itself is not page-aligned, and positions
        past ``max_seq`` are pad, scattered into the sentinel.  Dense
        consumers (the legacy layout, and a spec pairing's dense draft
        cache on an otherwise-paged engine) must pass
        ``page_aligned=False``: their prefill pads K/V to exactly
        ``max_seq`` and cannot take a larger bucket."""
        if page_aligned is None:
            page_aligned = self.paged
        b = self.min_prefill_bucket
        while b < n:
            b *= 2
        if page_aligned:
            return min(b, self.pages_per_slot * self.kv_page_size)
        return min(b, self.max_seq)

    # ------------------------------------------------------------------
    # Paged-pool bookkeeping
    # ------------------------------------------------------------------
    def _page_need(self, req: Request) -> tuple[int, int]:
        """(worst-case total pages, prompt pages) for ``req`` — the
        Principle-I capacity question admission answers."""
        n = len(req.prompt)
        horizon = min(n + req.max_new_tokens, self.max_seq)
        return self.pool.pages_for(horizon), self.pool.pages_for(n)

    def _shared_prefix(self, prompt: np.ndarray, record: bool = True):
        """Longest radix-cached full-page prefix of ``prompt``, capped one
        token short of the whole prompt so at least one suffix token remains
        to produce the first-token logits."""
        if self.prefix_cache is None:
            return []
        return self.prefix_cache.match(prompt[: len(prompt) - 1],
                                       record=record)

    def _ensure_capacity(self, need: int) -> bool:
        """Make ``need`` pages promisable, evicting LRU cached prefixes."""
        while self.pool.available < need:
            if self.prefix_cache is None:
                return False
            if self.prefix_cache.evict(need - self.pool.available) == 0:
                return False
        return True

    def request_fits(self, req: Request) -> bool:
        """Structural admissibility: could ``req`` EVER be admitted, even on
        an idle engine?  False means waiting will not help (prompt exceeds
        max_seq, or its worst-case page need exceeds the whole pool) —
        queue managers should fail such a request loudly instead of letting
        it starve the head of the line."""
        if len(req.prompt) > self.max_seq:
            return False
        if self.paged:
            total_pages, _ = self._page_need(req)
            return total_pages <= self.pool.num_pages - 1
        return True

    def can_admit(self, req: Request) -> bool:
        """Capacity probe for Algorithm-1 admission: a free slot exists AND
        (paged engines) the pool can cover the request's worst-case page
        need, counting evictable cached prefixes but never the pages the
        request itself would share.  Non-mutating."""
        if not self.free_slots() or not self.request_fits(req):
            return False
        if not self.paged:
            return True
        total_pages, _ = self._page_need(req)
        prompt = np.asarray(req.prompt, np.int32)
        shared = self._shared_prefix(prompt, record=False)
        evictable = 0
        if self.prefix_cache is not None:
            evictable = self.prefix_cache.evictable_pages() - sum(
                1 for p in shared if self.pool.refcount[p] == 1
            )
        return total_pages - len(shared) <= self.pool.available + evictable

    def export_prefix_pages(self):
        """Warm-state snapshot export (DESIGN.md §11): the radix cache's
        tree structure plus the KV contents of its pages, as
        ``(nodes, k, v)`` with ``k``/``v`` shaped ``[L, N, page, kvH, hd]``
        gathered in node order.  None on dense engines or when the cache
        is empty — the snapshot is strictly optional warm state."""
        if self.prefix_cache is None:
            return None
        nodes = self.prefix_cache.export_nodes()
        if not nodes:
            return None
        pages = jnp.asarray([page for _, _, page in nodes], jnp.int32)
        layers = self.cache["layers"]
        return nodes, layers["k"][:, pages], layers["v"][:, pages]

    def import_prefix_pages(self, nodes, k, v) -> int:
        """Warm the radix cache from an exported snapshot: allocate fresh
        pages (evicting colder entries if needed), write the saved KV
        contents into them, and rebuild the tree.  Nodes that don't fit
        are dropped from the tail — warm state is best-effort, never
        required for correctness.  Returns the nodes loaded."""
        if self.prefix_cache is None or not nodes:
            return 0
        keep = len(nodes)
        if not self._ensure_capacity(keep):
            # drop whole subtrees from the tail: export order is
            # parents-first, so a prefix of it is still a valid forest
            keep = self.pool.available
            nodes = nodes[:keep]
        if keep == 0:
            return 0
        pages = self.pool.alloc(keep)
        idx = jnp.asarray(pages, jnp.int32)
        dtype = self.cache["layers"]["k"].dtype
        layers = self.cache["layers"]
        layers["k"] = layers["k"].at[:, idx].set(
            jnp.asarray(k[:, :keep], dtype)
        )
        layers["v"] = layers["v"].at[:, idx].set(
            jnp.asarray(v[:, :keep], dtype)
        )
        return self.prefix_cache.load_nodes(nodes, pages)

    def _sync_block_tables(self) -> None:
        self.cache["block_tables"] = jnp.asarray(self._bt_host)
        self._bt_dirty = False

    def _set_block_table_row(
        self, slot: int, pages: list[int], sync: bool = True
    ) -> None:
        self._bt_host[slot] = 0
        self._bt_host[slot, : len(pages)] = pages
        self._bt_dirty = True
        if sync:
            self._sync_block_tables()

    def _top_up_pages(self, steps: int) -> None:
        """Extend every active slot's block table to cover the next
        ``steps`` token writes (converting admission reservations into
        physical pages) — the fused loops then never need a host alloc.

        A ``PageAllocError`` (injected transient allocator fault,
        DESIGN.md §9) is contained per slot: the failing slot is evicted
        and its request re-queued through the core's fault path; the
        other slots keep decoding."""
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            cover = min(self._slot_idx[i] + steps, self._slot_horizon[i])
            need = self.pool.pages_for(cover)
            cur = len(self._slot_pages[i])
            if need > cur:
                try:
                    got = self.pool.alloc(need - cur, reserved=True)
                except PageAllocError:
                    self.obs.metrics.counter("fault/alloc_failures").inc()
                    req = self.evict_slot(i, sync=False)
                    if self._core is not None:
                        self._core._on_slot_fault(i, req)
                    continue
                self._slot_reserved[i] -= len(got)
                self._bt_host[i, cur: cur + len(got)] = got
                self._slot_pages[i].extend(got)
                self._bt_dirty = True
        if self._bt_dirty:
            self._sync_block_tables()

    def _trim_slot_pages(self, i: int) -> None:
        """Release pages past the page holding the slot's next write
        position — speculative rollback's freed capacity returns to the
        pool (as restored reservation) instead of idling.  Marks the block
        tables dirty; the caller syncs once per sweep."""
        keep = self._slot_idx[i] // self.kv_page_size + 1
        pages = self._slot_pages[i]
        if len(pages) <= keep:
            return
        drop = pages[keep:]
        del pages[keep:]
        freed = self.pool.decref(drop)
        # trimmed pages sit past the prompt (idx >= prompt length), so the
        # radix tree never holds them: every drop frees
        assert len(freed) == len(drop), "trimmed a shared page"
        self.pool.reserve(len(drop))
        self._slot_reserved[i] += len(drop)
        self._bt_host[i, keep: keep + len(drop)] = 0
        self._bt_dirty = True

    def evict_slot(self, i: int, sync: bool = True) -> Request:
        """Release slot ``i``'s resources — pages back to the pool, BOTH
        cache indices reset (the draft index too, which the plain-loop
        paths previously left stale) — WITHOUT finishing the request.

        This is the preempt/abort primitive: the request keeps its
        generated tokens and may be re-admitted later (resume re-prefills
        ``prompt + generated``; the radix tree still holds the prompt's
        full pages, so a paged resume recomputes only the suffix).
        ``sync=False`` defers the block-table upload to the caller's sweep
        (the retirement paths batch one upload over all evictions)."""
        req = self.slots[i]
        assert req is not None, f"evict of empty slot {i}"
        self.slots[i] = None
        if self._router is not None:
            # recycled slots start from the optimistic prior again
            self._router.reset_slot(i)
        # a mid-PREFILLING eviction drops the pending chunk streams: resume
        # re-prefills from the radix-covered prefix (partial chunk work past
        # it is recomputed — its pages were released with the slot)
        self._prefill_left[i] = None
        self._draft_prefill_left[i] = None
        self._prefill_tok[i] = None
        self.cache["index"] = self.cache["index"].at[i].set(0)
        if self.spec_enabled:
            self.draft_cache["index"] = (
                self.draft_cache["index"].at[i].set(0)
            )
        if self.paged:
            self.pool.decref(self._slot_pages[i])
            self.pool.unreserve(self._slot_reserved[i])
            self._slot_pages[i] = []
            self._slot_reserved[i] = 0
            self._slot_idx[i] = 0
            self._slot_horizon[i] = 0
            self._bt_host[i] = 0
            self._bt_dirty = True
            if sync:
                self._sync_block_tables()
        return req

    def _retire_slot(self, i: int, now: float) -> Request:
        """Single retirement path for the fused loops and
        ``decode_microstep``: evict the slot, stamp the finish time, and
        notify the lifecycle core (if one is attached) so the request's
        state machine advances to FINISHED."""
        req = self.evict_slot(i, sync=False)
        req.finish_time = now
        if self._core is not None:
            self._core._on_slot_finished(i, req)
        return req

    # ------------------------------------------------------------------
    def _embed_or_pass(self, params, buf: np.ndarray):
        if self.cfg.embed_inputs:
            # stub frontend: embed prompt tokens through the output table
            return params["embed"][jnp.asarray(buf)].astype(
                self.compute_dtype
            )
        return jnp.asarray(buf)

    def _bucket_buf(
        self,
        tokens: np.ndarray,
        page_aligned: Optional[bool] = None,
        model: str = "target",
        impl: str = "bucket",
    ) -> np.ndarray:
        sb = self._bucket_len(len(tokens), page_aligned)
        self._record_prefill_program(model, impl, sb)
        buf = np.zeros((1, sb), np.int32)
        buf[0, : len(tokens)] = tokens
        return buf

    def _paged_reserve(
        self, slot: int, req: Request
    ) -> Optional[tuple[list[int], int]]:
        """The bookkeeping half of paged admission, shared by monolithic
        prefill and chunked streaming: match the radix prefix, make room
        (evicting LRU cached prefixes if needed), allocate prompt pages now
        and reserve the decode horizon.  Returns ``(block-table row, shared
        token count)``, or None on capacity.  Leaves the block tables dirty
        — callers batch the h2d upload before their first dispatch."""
        n = len(req.prompt)
        prompt = np.asarray(req.prompt, np.int32)
        total_pages, prompt_pages = self._page_need(req)
        shared_pages = self._shared_prefix(prompt)
        if shared_pages:
            # hold the matched pages before eviction can reclaim them
            self.pool.incref(shared_pages)
        if not self._ensure_capacity(total_pages - len(shared_pages)):
            if shared_pages:
                self.pool.decref(shared_pages)
            return None
        try:
            new_pages = self.pool.alloc(prompt_pages - len(shared_pages))
        except PageAllocError:
            # exhaustion or an injected allocator fault: unwind the
            # prefix hold and report "no capacity" — admission blocks
            # (the request stays queued) instead of crashing
            self.obs.metrics.counter("fault/alloc_failures").inc()
            if shared_pages:
                self.pool.decref(shared_pages)
            return None
        self.pool.reserve(total_pages - prompt_pages)
        row = shared_pages + new_pages
        self._slot_pages[slot] = list(row)
        self._slot_reserved[slot] = total_pages - prompt_pages
        self._slot_horizon[slot] = min(n + req.max_new_tokens, self.max_seq)
        self._slot_idx[slot] = len(shared_pages) * self.kv_page_size
        self._set_block_table_row(slot, row, sync=False)
        return row, len(shared_pages) * self.kv_page_size

    def _paged_admit(self, slot: int, req: Request) -> Optional[int]:
        """Capacity-based paged MONOLITHIC admission: reserve pages, then
        prefill in one dispatch — the whole prompt on a radix miss, only
        the suffix on a hit.  (Chunked engines stream instead:
        ``_begin_chunked_admit`` + ``_drive_prefill_chunks``.)"""
        res = self._paged_reserve(slot, req)
        if res is None:
            return None
        row, shared = res
        self._sync_block_tables()  # the prefill dispatch reads the tables
        n = len(req.prompt)
        prompt = np.asarray(req.prompt, np.int32)
        self._slot_idx[slot] = n
        if shared:
            suffix = prompt[shared:]
            buf = self._bucket_buf(suffix, impl="suffix")
            tok, self.cache = self._suffix_prefill(
                self.params, jnp.asarray(buf), jnp.int32(len(suffix)),
                jnp.int32(shared), jnp.int32(slot), self.cache,
            )
            self.prefill_skipped_tokens += shared
        else:
            buf = self._bucket_buf(prompt)
            tok, self.cache = self._prefill_slot(
                self.params, self._embed_or_pass(self.params, buf),
                jnp.int32(n), jnp.int32(slot), self.cache,
            )
        self.prefill_prompt_tokens += n
        self.prefill_metered_tokens += n if self.spec_enabled else n - shared
        if self.prefix_cache is not None:
            # cache the prompt's full pages for future admissions (the tree
            # takes its own reference; they outlive this slot)
            self.prefix_cache.insert(prompt, row[: n // self.kv_page_size])
        if self.spec_enabled:
            # the dense draft cache has no prefix pool: it prefill-tracks
            # the full prompt (cheap by construction; first-token output is
            # never fetched — no extra device->host transfer).  Its bucket
            # caps at max_seq, not the page-aligned roundup.
            dbuf = self._bucket_buf(prompt, page_aligned=False, model="draft")
            _, self.draft_cache = self._draft_prefill(
                self.draft_params, self._embed_or_pass(self.draft_params, dbuf),
                jnp.int32(n), jnp.int32(slot), self.draft_cache,
            )
        return tok

    def _dense_admit(self, slot: int, req: Request) -> int:
        n = len(req.prompt)
        buf = self._bucket_buf(np.asarray(req.prompt, np.int32))
        tok, self.cache = self._prefill_slot(
            self.params, self._embed_or_pass(self.params, buf),
            jnp.int32(n), jnp.int32(slot), self.cache,
        )
        self.prefill_prompt_tokens += n
        self.prefill_metered_tokens += n
        if self.spec_enabled:
            # draft cache tracks the same prefix; its first-token output is
            # never fetched (no extra device->host transfer)
            dbuf = self._bucket_buf(
                np.asarray(req.prompt, np.int32), model="draft"
            )
            _, self.draft_cache = self._draft_prefill(
                self.draft_params, self._embed_or_pass(self.draft_params, dbuf),
                jnp.int32(n), jnp.int32(slot), self.draft_cache,
            )
        return tok

    # ------------------------------------------------------------------
    # Chunked prefill (DESIGN.md §7): admission reserves, waves stream
    # ------------------------------------------------------------------
    def _begin_chunked_admit(self, slot: int, req: Request) -> bool:
        """Chunked admission: reserve the slot's capacity (paged: prompt
        pages + decode-horizon reservation, radix prefix matched and held)
        WITHOUT running any prefill compute — the prompt streams into the
        slot as fixed-width chunks across subsequent
        ``_drive_prefill_chunks`` waves.  Block-table mutations stay host-
        side; the first wave ships them as ONE h2d upload covering every
        admission in the step."""
        n = len(req.prompt)
        prompt = np.asarray(req.prompt, np.int32)
        shared = 0
        if self.paged:
            res = self._paged_reserve(slot, req)
            if res is None:
                return False
            _, shared = res
            if shared:
                # the slot's device-side progress starts past the radix-
                # covered prefix; chunk attention reads those shared pages
                # directly, so the skip costs zero FLOPs as before
                self.cache["index"] = self.cache["index"].at[slot].set(shared)
        self._prefill_left[slot] = prompt[shared:]
        if self.spec_enabled:
            self._draft_prefill_left[slot] = prompt  # no draft prefix pool
        self._prefill_tok[slot] = None
        self.prefill_prompt_tokens += n
        self.prefill_skipped_tokens += shared
        self.slots[slot] = req
        return True

    def _plan_prefill_waves(self, budget: float):
        """Host-side preview of ``_drive_prefill_chunks``: greedy slot-order
        allocation of chunk takes, wave by wave, under ``budget`` metered
        tokens.  Returns ``(waves, consumed, completing)`` where each wave
        is a list of ``(slot, target_take, draft_take)`` — deterministic,
        so schedulers can price a step's prefill cost BEFORE driving it."""
        chunk = self.prefill_chunk
        left: dict[int, list[int]] = {}
        for i in range(self.max_slots):
            t = self._prefill_left[i]
            d = self._draft_prefill_left[i]
            t_n = len(t) if t is not None else 0
            d_n = len(d) if d is not None else 0
            if t_n or d_n:
                left[i] = [t_n, d_n]
            elif self.slot_prefilling(i):
                # fully-streamed but not yet finalized (shouldn't persist)
                left[i] = [0, 0]
        waves, consumed, completing = [], 0, []
        budget_left = budget
        while left:
            wave = []
            # shortest-pending-first: a just-admitted short (online) prompt
            # completes ahead of a long stream instead of starving behind
            # it when the budget runs dry mid-wave
            order = sorted(left, key=lambda i: (max(left[i]), i))
            for i in order:
                if budget_left <= 0:
                    break
                t_n, d_n = left[i]
                tt, dd = min(chunk, t_n), min(chunk, d_n)
                cost = max(tt, dd)
                if cost > budget_left:
                    cap = int(budget_left)
                    tt, dd = min(tt, cap), min(dd, cap)
                    cost = max(tt, dd)
                if cost <= 0:
                    continue
                wave.append((i, tt, dd))
                left[i] = [t_n - tt, d_n - dd]
                budget_left -= cost
                consumed += cost
                if left[i] == [0, 0]:
                    completing.append(i)
                    del left[i]
            if not wave:
                break
            waves.append(wave)
        return waves, consumed, completing

    def _drive_prefill_chunks(self, budget: float = math.inf) -> int:
        """Stream chunk waves into every PREFILLING slot, consuming at most
        ``budget`` metered tokens (per slot per wave: max of the target and
        draft takes).  Each wave is ONE batched target dispatch plus — when
        a draft pairing is attached — ONE batched draft dispatch, replacing
        the per-request prefill (and per-request draft prefill) dispatches
        of the monolithic path.  Slots whose prompt completes get their
        first generated token from the completing wave's logits, fetched in
        ONE batched d2h transfer at the end.  Returns tokens consumed."""
        self.last_prefill_slot_tokens = {}
        if not self.prefill_chunk:
            return 0
        waves, consumed, _ = self._plan_prefill_waves(budget)
        if not waves:
            return 0
        for wave in waves:
            for i, tt, dd in wave:
                self.last_prefill_slot_tokens[i] = (
                    self.last_prefill_slot_tokens.get(i, 0) + max(tt, dd)
                )
        if self.paged and self._bt_dirty:
            self._sync_block_tables()  # one h2d wave covers every admission
        chunk = self.prefill_chunk
        completed: list[int] = []
        for wave in waves:
            t_lens = np.zeros((self.max_slots,), np.int32)
            d_lens = np.zeros((self.max_slots,), np.int32)
            t_toks = np.zeros((self.max_slots, chunk), np.int32)
            d_toks = np.zeros((self.max_slots, chunk), np.int32)
            t_done: list[int] = []
            for i, tt, dd in wave:
                if tt:
                    buf = self._prefill_left[i]
                    t_toks[i, :tt] = buf[:tt]
                    t_lens[i] = tt
                    self._prefill_left[i] = buf[tt:]
                    if len(self._prefill_left[i]) == 0:
                        t_done.append(i)
                    if self.paged:
                        self._slot_idx[i] += tt
                if dd:
                    dbuf = self._draft_prefill_left[i]
                    d_toks[i, :dd] = dbuf[:dd]
                    d_lens[i] = dd
                    self._draft_prefill_left[i] = dbuf[dd:]
            if t_lens.any():
                self._record_prefill_program("target", "chunk", chunk)
                next_toks, self.cache = self._prefill_chunks(
                    self.params, jnp.asarray(t_toks), jnp.asarray(t_lens),
                    self.cache,
                )
                for i in t_done:
                    # hold the completing wave's device logits-argmax; the
                    # slot may still owe draft chunks before finalizing
                    self._prefill_tok[i] = next_toks
            if d_lens.any():
                self._record_prefill_program("draft", "chunk", chunk)
                _, self.draft_cache = self._draft_prefill_chunks(
                    self.draft_params, jnp.asarray(d_toks),
                    jnp.asarray(d_lens), self.draft_cache,
                )
            self.steps_executed += 1
            for i, _, _ in wave:
                t = self._prefill_left[i]
                d = self._draft_prefill_left[i]
                if (t is not None and len(t) == 0) and (
                    d is None or len(d) == 0
                ):
                    completed.append(i)
        if completed:
            toks = jax.device_get([self._prefill_tok[i] for i in completed])
            self.d2h_transfers += 1  # one batched fetch covers every finish
            now = self.clock()
            for i, arr in zip(completed, toks):
                self._finish_prefill(i, int(np.asarray(arr)[i]), now)
        self.prefill_metered_tokens += consumed
        return consumed

    def _finish_prefill(self, i: int, tok: int, now: float) -> None:
        """Transition slot ``i`` PREFILLING -> RUNNING: deliver the first
        generated token, stamp TTFT, and (paged) insert the prompt's full
        pages into the radix tree — the same shape monolithic admission
        produced in one shot."""
        req = self.slots[i]
        self._prefill_left[i] = None
        self._draft_prefill_left[i] = None
        self._prefill_tok[i] = None
        req.generated.append(tok)
        self.generated_tokens_total += 1
        if req.first_token_time is None:
            req.first_token_time = now
        self.tokens = self.tokens.at[i].set(tok)
        if self.paged and self.prefix_cache is not None:
            prompt = np.asarray(req.prompt, np.int32)
            self.prefix_cache.insert(
                prompt,
                self._slot_pages[i][: len(prompt) // self.kv_page_size],
            )

    def _restore_draft_prefill_indices(self) -> None:
        """Re-pin the draft cache index of PREFILLING slots to their draft
        progress: the fused speculative loop keeps draft and target indices
        EQUAL for every slot (frozen ones included), which is wrong exactly
        while a slot's two prefill streams sit at different offsets.  One
        batched scatter, regardless of how many slots are mid-prefill."""
        slots, values = [], []
        for i in range(self.max_slots):
            if not self.slot_prefilling(i):
                continue
            d = self._draft_prefill_left[i]
            slots.append(i)
            values.append(
                len(self.slots[i].prompt) - (len(d) if d is not None else 0)
            )
        if slots:
            self.draft_cache["index"] = self.draft_cache["index"].at[
                np.asarray(slots)
            ].set(np.asarray(values, np.int32))

    # ------------------------------------------------------------------
    # Lifecycle core + deprecated shim surface
    # ------------------------------------------------------------------
    @property
    def core(self):
        """The engine's lazily-built ``EngineCore`` (serving/core.py) — the
        request-lifecycle surface (``submit``/``step``/``stream``/``abort``)
        all public admission and decode now routes through."""
        if self._core is None:
            from repro.serving.core import EngineCore

            self._core = EngineCore(self)
        return self._core

    def add_request(self, req: Request) -> bool:
        """DEPRECATED shim — delegates to ``EngineCore.add_legacy``.

        Prefer ``engine.core.submit(prompt, SamplingParams(...),
        priority=...)``: queued admission with priority classes, preemption,
        and streaming outputs.  This shim admits immediately (no queueing)
        and returns False on capacity, the historical contract."""
        return self.core.add_legacy(req)

    def decode_loop(self, k: int) -> list[Request]:
        """DEPRECATED shim — delegates to ``EngineCore.run_legacy``: one
        fused plain-decode loop, returning the requests that finished.
        Prefer ``engine.core.step(grant)``."""
        return self.core.run_legacy(k)

    def spec_decode_loop(self, k: int, gamma: int) -> list[Request]:
        """DEPRECATED shim — delegates to ``EngineCore.run_legacy``: one
        fused speculative loop, returning the requests that finished.
        Prefer ``engine.core.step(grant)``."""
        return self.core.run_legacy(k, gamma=gamma)

    # ------------------------------------------------------------------
    def _admit_request(self, req: Request, *, stream_prefill: bool = False) -> bool:
        """Admit ``req`` into a free slot.

        Monolithic engines (``prefill_chunk == 0``) prefill the whole
        prompt in one microstep, as ever.  Chunked engines only *reserve*
        the slot (pages, block-table row, pending chunk streams):

          * ``stream_prefill=True`` (the EngineCore path) leaves the slot
            PREFILLING — ``_drive_prefill_chunks`` streams the prompt
            across subsequent token-budgeted steps.
          * ``stream_prefill=False`` (the legacy shim contract) drives the
            chunks to completion before returning, preserving the
            historical "first token at admission" behavior bit-for-bit.
            NOTE: the completion drive is unmetered and batches over ALL
            PREFILLING slots — mixing the deprecated shim with core-driven
            budgeted streaming force-completes the core's pending streams
            outside any step's accounting; drive everything through
            ``EngineCore.step`` when budgets matter.

        Returns False when no slot is free — or, on paged engines, when the
        pool cannot cover the request's worst-case page need even after
        evicting unreferenced cached prefixes (capacity-based admission)."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        n = len(req.prompt)
        if n > self.max_seq:
            raise ValueError(
                f"prompt of {n} tokens exceeds engine max_seq={self.max_seq}; "
                "refusing to truncate silently"
            )
        if req.arrival_time == 0.0 and not req.online:
            # default epoch-zero arrival on an offline request: stamp from
            # the engine clock so latency metrics never mix timebases.
            # Online requests keep an explicit 0.0 — on a virtual clock that
            # is a real arrival instant, and restamping it at admission
            # would erase the request's queueing delay.
            req.arrival_time = self.clock()
        if self.prefill_chunk:
            if not self._begin_chunked_admit(slot, req):
                return False
            if not stream_prefill:
                self._drive_prefill_chunks()
            return True
        if self.paged:
            tok = self._paged_admit(slot, req)
            if tok is None:
                return False
        else:
            tok = self._dense_admit(slot, req)
        req.generated.append(int(tok))
        self.d2h_transfers += 1
        self.generated_tokens_total += 1
        if req.first_token_time is None:
            req.first_token_time = self.clock()
        self.tokens = self.tokens.at[slot].set(tok)
        self.slots[slot] = req
        self.steps_executed += 1
        return True

    # ------------------------------------------------------------------
    # Fault injection + containment (DESIGN.md §9)
    # ------------------------------------------------------------------
    def _maybe_inject_nan(self) -> None:
        """Consult the ``engine/nan_logits`` fault point before a fused
        dispatch; on fire, poison one decodable slot's KV so the next
        attention read produces NaN logits for exactly that slot.

        The poison lands on the slot's LAST WRITTEN position — always
        past the prompt's full pages (the victim must have generated at
        least one token), so a radix-cached prefix is never poisoned and
        prefix-sharing peers stay clean.  Attention families only: the
        recurrent families carry no per-position KV to poison."""
        inj = self.fault_injector
        if inj is None or not inj.should_fire("engine/nan_logits"):
            return
        if not (isinstance(self.cache["layers"], dict)
                and "k" in self.cache["layers"]):
            return
        cands = [
            i for i, r in enumerate(self.slots)
            if r is not None and not self.slot_prefilling(i)
            and len(r.generated) > 0
        ]
        if not cands:
            return
        slot = cands[inj.choice("engine/nan_logits", len(cands))]
        layers = self.cache["layers"]
        if self.paged:
            pos = self._slot_idx[slot] - 1
            page = self._slot_pages[slot][pos // self.kv_page_size]
            off = pos % self.kv_page_size
            layers["k"] = layers["k"].at[0, page, off].set(jnp.nan)
        else:
            pos = int(jax.device_get(self.cache["index"])[slot]) - 1
            layers["k"] = layers["k"].at[0, slot, pos].set(jnp.nan)

    def _scrub_slot_kv(self, i: int) -> None:
        """Zero the KV a quarantined slot wrote, BEFORE its pages/rows are
        released.  Freeing poisoned KV un-scrubbed is not safe: a masked
        attention position still contributes ``0 * NaN = NaN`` to the
        weighted sum, so the stale-overwrite invariant only holds for
        finite stale data.  Shared (radix-held) pages are left alone —
        the poison never lands on them (see ``_maybe_inject_nan``), and
        zeroing a shared prefix would corrupt its other holders."""
        layers = self.cache["layers"]
        if not (isinstance(layers, dict) and "k" in layers):
            return
        if self.paged:
            private = [
                p for p in self._slot_pages[i]
                if self.pool.refcount[p] == 1
            ]
            if private:
                idx = jnp.asarray(private, jnp.int32)
                layers["k"] = layers["k"].at[:, idx].set(0)
                layers["v"] = layers["v"].at[:, idx].set(0)
        else:
            layers["k"] = layers["k"].at[:, i].set(0)
            layers["v"] = layers["v"].at[:, i].set(0)

    def _quarantine_slot(self, i: int) -> Request:
        """Containment for a NaN-screened slot: count it, scrub its KV,
        evict it (pages freed, draft state reset), and hand the request
        to the core's fault path (bounded-retry requeue).  The poisoned
        dispatch's tokens were never absorbed, so a retry regenerates
        them and the final stream stays byte-identical to a fault-free
        run."""
        self.obs.metrics.counter("fault/nan_quarantines").inc()
        self._scrub_slot_kv(i)
        req = self.evict_slot(i, sync=False)
        if self._core is not None:
            self._core._on_slot_fault(i, req)
        return req

    # ------------------------------------------------------------------
    def _drive_decode_loop(self, k: int) -> list[Request]:
        """Run ``k`` fused decode microsteps on-device; returns requests that
        finished.  One device->host transfer total, regardless of ``k``.

        Finished slots freeze mid-loop on device (token, index, and budget
        held in place), so the host never needs to intervene between
        microsteps — PREFILLING slots of a chunked engine freeze the same
        way (zero budget) and never retire mid-prefill.  Callers should
        pick ``k`` from ``DECODE_K_BUCKETS`` to bound the number of
        compiled programs."""
        if self.num_active == 0 or k <= 0:
            return []
        if self.num_active == self.num_prefilling:
            return []  # every slot is mid-prefill: nothing to decode
        if self.paged:
            # extend block tables to cover the loop's k writes per slot
            self._top_up_pages(k)
            if self.num_active == 0:
                return []  # every slot fell to an allocator fault
        self._maybe_inject_nan()
        remaining = np.zeros((self.max_slots,), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None and not self.slot_prefilling(i):
                remaining[i] = max(r.max_new_tokens - len(r.generated), 0)
        tokens, cache, rem, toks_seq, steps, bad = self._decode_loop(
            self.params, self.tokens, self.cache, jnp.asarray(remaining), k=k
        )
        self.tokens, self.cache = tokens, cache
        toks_np, steps_np, rem_np, idx_np, bad_np = jax.device_get(
            (toks_seq, steps, rem, cache["index"], bad)
        )
        self.d2h_transfers += 1  # the single fused fetch above
        self.steps_executed += k
        now = self.clock()
        finished = []
        for i, req in enumerate(self.slots):
            if req is None or self.slot_prefilling(i):
                continue
            if bad_np[i]:
                # NaN screen (DESIGN.md §9): this slot's tokens from the
                # loop are garbage — drop them all (the screen can't say
                # which microstep went bad) and quarantine the slot; its
                # on-device index/remaining are garbage too, so no retire
                # check either
                self._quarantine_slot(i)
                continue
            n = int(steps_np[i])
            req.generated.extend(int(t) for t in toks_np[:n, i])
            self.generated_tokens_total += n
            if self.paged:
                self._slot_idx[i] = int(idx_np[i])
            if rem_np[i] == 0 or idx_np[i] >= self.max_seq - 1:
                finished.append(self._retire_slot(i, now))
        if self.paged and self._bt_dirty:
            self._sync_block_tables()  # one upload covers every retirement
        return finished

    # ------------------------------------------------------------------
    def _drive_spec_loop(self, k: int, gamma: int) -> list[Request]:
        """Run ``k`` fused speculative rounds (draft-propose + chunk-verify);
        returns requests that finished.  One device->host transfer total.

        Each round spends one schedulable quantum and emits up to
        ``gamma + 1`` *verified* tokens per slot (greedy mode: byte-identical
        to the plain greedy ``decode_loop`` stream).  Pick ``k`` from
        ``DECODE_K_BUCKETS`` and ``gamma`` from the pairing's gamma buckets
        to bound the number of compiled programs.  A slot needs room for a
        whole chunk, so it retires once ``index + gamma >= max_seq`` —
        slightly earlier than the plain loop's ``max_seq - 1`` horizon."""
        assert self.spec_enabled, "engine built without a draft pairing"
        if self.num_active == 0 or k <= 0:
            return []
        if self.num_active == self.num_prefilling:
            return []  # every slot is mid-prefill: nothing to verify
        if self.paged:
            # worst case every round accepts the whole chunk: cover
            # k * (gamma + 1) writes per slot
            self._top_up_pages(k * (gamma + 1))
            if self.num_active == 0:
                return []  # every slot fell to an allocator fault
        self._maybe_inject_nan()
        remaining = np.zeros((self.max_slots,), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None and not self.slot_prefilling(i):
                remaining[i] = max(r.max_new_tokens - len(r.generated), 0)
        (
            self.tokens, self.cache, self.draft_cache, rem, self._spec_key,
            out_toks, n_out, accepted, proposed, bad,
        ) = self._spec_loop(
            self.params, self.draft_params, self.tokens, self.cache,
            self.draft_cache, jnp.asarray(remaining), self._spec_key,
            k=k, gamma=gamma,
        )
        toks_np, n_np, acc_np, prop_np, rem_np, idx_np, bad_np = (
            jax.device_get((
                out_toks, n_out, accepted, proposed, rem,
                self.cache["index"], bad,
            ))
        )
        self.d2h_transfers += 1  # the single fused fetch above
        self.steps_executed += k
        self.spec_rounds += k
        now = self.clock()
        finished = []
        self._last_spec_slot_stats = {}
        for i, req in enumerate(self.slots):
            if req is None or self.slot_prefilling(i):
                continue
            if bad_np[i]:
                # NaN screen (DESIGN.md §9): every round's acceptance for
                # this slot is suspect — drop the whole loop's output and
                # quarantine (no acceptance-EWMA pollution either)
                self._quarantine_slot(i)
                continue
            for j in range(k):
                n = int(n_np[j, i])
                req.generated.extend(int(t) for t in toks_np[j, i, :n])
                self.generated_tokens_total += n
            slot_acc = int(acc_np[:, i].sum())
            slot_prop = int(prop_np[:, i].sum())
            self._last_spec_slot_stats[i] = (slot_acc, slot_prop)
            self.spec_accepted += slot_acc
            self.spec_drafted += slot_prop
            if self.paged:
                self._slot_idx[i] = int(idx_np[i])
            if rem_np[i] == 0 or idx_np[i] + gamma >= self.max_seq:
                finished.append(self._retire_slot(i, now))
            elif self.paged:
                # rollback freed tokens past the accepted prefix: release
                # the pages the worst-case top-up provisioned beyond them
                self._trim_slot_pages(i)
        if self.num_prefilling:
            # the fused loop pinned every frozen slot's draft index to its
            # TARGET index; mid-prefill the two streams sit at different
            # offsets, so restore the draft's own progress
            self._restore_draft_prefill_indices()
        if self.paged and self._bt_dirty:
            self._sync_block_tables()  # one upload covers trims + retires
        return finished

    # ------------------------------------------------------------------
    # Host-proposed tree verification (DESIGN.md §10)
    # ------------------------------------------------------------------
    def _tree_round_fn(self, parents: tuple, mode: str):
        """Jitted ``tree_verify_round`` for one static topology.  Topologies
        come from the gamma/width buckets, so the program set stays bounded
        the same way the k/gamma buckets bound the fused loops."""
        fn = self._tree_round_cache.get((parents, mode))
        if fn is None:
            from repro.spec.tree import tree_verify_round as _tree_fn

            fn = jax.jit(
                functools.partial(
                    _tree_fn, self.cfg, parents=parents, mode=mode,
                    max_seq=self.max_seq,
                    sim_accept_p=self.spec_cfg.sim_accept_p,
                    compute_dtype=self.compute_dtype,
                    attn_impl=self._attn_impl,
                ),
                donate_argnames=("tokens", "cache", "remaining", "key"),
            )
            self._tree_round_cache[(parents, mode)] = fn
        return fn

    def _note_proposer_round(
        self, name: str, rounds: int, accepted: int, proposed: int
    ) -> None:
        m = self.obs.metrics
        m.counter(f"spec/proposer/rounds/{name}").inc(rounds)
        m.counter(f"spec/proposer/proposed/{name}").inc(proposed)
        m.counter(f"spec/proposer/accepted/{name}").inc(accepted)
        ptot = m.counter(f"spec/proposer/proposed/{name}").value
        if ptot:
            m.gauge(f"spec/proposer/acceptance/{name}").set(
                m.counter(f"spec/proposer/accepted/{name}").value / ptot
            )

    def _drive_proposed_loop(
        self, k: int, gamma: int, proposer: Optional[str] = None
    ) -> list[Request]:
        """Run ``k`` routed speculative rounds; returns requests that
        finished.

        The routed proposer decides the machinery: the device-resident
        draft model delegates to the fused ``_drive_spec_loop`` (k rounds,
        one transfer), while a host proposer (n-gram / static-suffix) runs
        ``k`` tree-verify rounds at ONE dispatch and one device->host
        transfer EACH — the host must see a round's accepted tokens before
        it can propose the next tree.  A round where the proposer has
        nothing to offer (no history match anywhere) falls back to one
        plain fused decode step instead of paying a doomed verify pass."""
        from repro.spec.proposers.base import ProposeContext

        if proposer is None:
            proposer = self.route_proposer(gamma)
        assert proposer is not None and proposer in self._proposers, (
            f"no proposer routed (got {proposer!r})"
        )
        prop = self._proposers[proposer]
        if prop.kind == "device":
            a0, p0 = self.spec_accepted, self.spec_drafted
            r0 = self.spec_rounds
            finished = self._drive_spec_loop(k, gamma)
            self._note_proposer_round(
                proposer, self.spec_rounds - r0,
                self.spec_accepted - a0, self.spec_drafted - p0,
            )
            for i, (acc, prp) in self._last_spec_slot_stats.items():
                if self.slots[i] is not None:  # retired slots were reset
                    self._router.observe(i, proposer, acc, prp)
            return finished
        width = max(1, self.spec_cfg.tree_width)
        mode = "simulated" if self.spec_cfg.mode == "simulated" else "greedy"
        finished: list[Request] = []
        for _ in range(k):
            if self.num_active == 0 or (
                self.num_active == self.num_prefilling
            ):
                break
            remaining = np.zeros((self.max_slots,), np.int32)
            hists: list[list[int]] = [[] for _ in range(self.max_slots)]
            for i, r in enumerate(self.slots):
                if r is not None and not self.slot_prefilling(i):
                    remaining[i] = max(
                        r.max_new_tokens - len(r.generated), 0
                    )
                    hists[i] = [int(t) for t in r.prompt] + r.generated
            if not remaining.any():
                break
            tree = prop.propose(ProposeContext(
                histories=hists, active=remaining > 0, gamma=gamma,
                width=width,
            ))
            if tree is None:
                # no slot matched: the round IS zero-acceptance evidence —
                # without it the optimistic prior would route a useless
                # proposer forever (the counters stay clean: nothing was
                # actually drafted or verified)
                self.obs.metrics.counter(
                    "spec/proposer/no_match_fallbacks"
                ).inc()
                for i in np.flatnonzero(remaining > 0):
                    self._router.observe(int(i), proposer, 0, gamma)
                finished.extend(self._drive_decode_loop(1))
                continue
            n_nodes = len(tree.parents)
            if self.paged:
                # worst case the round accepts a whole root-to-leaf path;
                # node-index K/V slots need n_nodes positions regardless
                self._top_up_pages(n_nodes)
                if self.num_active == 0:
                    break  # every slot fell to an allocator fault
            self._maybe_inject_nan()
            (
                self.tokens, self.cache, rem, self._spec_key,
                out, n_out, accepted, proposed, bad,
            ) = self._tree_round_fn(tree.parents, mode)(
                self.params, self.tokens, self.cache,
                jnp.asarray(tree.tail), jnp.asarray(remaining),
                self._spec_key,
            )
            toks_np, n_np, acc_np, prop_np, rem_np, idx_np, bad_np = (
                jax.device_get((
                    out, n_out, accepted, proposed, rem,
                    self.cache["index"], bad,
                ))
            )
            self.d2h_transfers += 1  # one per round: proposals need history
            self.steps_executed += 1
            self.spec_rounds += 1
            self.obs.metrics.gauge("spec/proposer/tree_nodes").set(n_nodes)
            round_acc = round_prop = 0
            now = self.clock()
            for i, req in enumerate(self.slots):
                if req is None or self.slot_prefilling(i):
                    continue
                if bad_np[i]:
                    self._quarantine_slot(i)
                    continue
                n = int(n_np[i])
                req.generated.extend(int(t) for t in toks_np[i, :n])
                self.generated_tokens_total += n
                if self.paged:
                    self._slot_idx[i] = int(idx_np[i])
                if tree.matched[i]:
                    acc, prp = int(acc_np[i]), int(prop_np[i])
                    round_acc += acc
                    round_prop += prp
                    self._router.observe(i, proposer, acc, prp)
                    prop.observe(i, acc, prp)
                elif remaining[i] > 0:
                    # the proposer declined THIS slot while serving others:
                    # zero-acceptance routing evidence for the slot, but
                    # not a drafted proposal (its filler row was always
                    # going to be rejected), so the counters stay clean
                    self._router.observe(i, proposer, 0, gamma)
                if rem_np[i] == 0 or idx_np[i] + (
                    n_nodes - 1
                ) >= self.max_seq:
                    finished.append(self._retire_slot(i, now))
                elif self.paged:
                    # rejected siblings past the accepted path: release the
                    # pages the worst-case top-up provisioned beyond it
                    self._trim_slot_pages(i)
            self.spec_accepted += round_acc
            self.spec_drafted += round_prop
            self._note_proposer_round(proposer, 1, round_acc, round_prop)
            if self.paged and self._bt_dirty:
                self._sync_block_tables()
        return finished

    # ------------------------------------------------------------------
    def decode_microstep(self) -> list[Request]:
        """One decode step over all slots; returns requests that finished.

        Legacy single-step path: syncs to host every step, but the token
        batch and the per-slot finish-check indices come down in ONE batched
        transfer (the old code paid 1 + num_active transfers per step).
        Kept for single-step callers and as the benchmark baseline — the
        fast path is ``decode_loop``."""
        if self.num_active == 0 or self.num_active == self.num_prefilling:
            return []
        if self.paged:
            self._top_up_pages(1)
        logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = next_tokens
        self.steps_executed += 1
        if self.num_prefilling:
            # the single-step program advances EVERY slot's index; restore
            # PREFILLING slots' prefill progress in one batched scatter
            # (their garbage K/V write at the old index is overwritten by
            # the next chunk, the usual stale-overwrite invariant)
            slots_, values = [], []
            for i in range(self.max_slots):
                if self.slot_prefilling(i):
                    left = self._prefill_left[i]
                    slots_.append(i)
                    values.append(len(self.slots[i].prompt) - (
                        len(left) if left is not None else 0
                    ))
            self.cache["index"] = self.cache["index"].at[
                np.asarray(slots_)
            ].set(np.asarray(values, np.int32))
        finished = []
        host_tokens, idx_np = jax.device_get(
            (next_tokens, self.cache["index"])
        )
        self.d2h_transfers += 1  # tokens + finish-check indices, batched
        now = self.clock()
        for i, req in enumerate(self.slots):
            if req is None or self.slot_prefilling(i):
                continue
            req.generated.append(int(host_tokens[i]))
            self.generated_tokens_total += 1
            if self.paged:
                self._slot_idx[i] = int(idx_np[i])
            if len(req.generated) >= req.max_new_tokens or int(
                idx_np[i]
            ) >= (self.max_seq - 1):
                finished.append(self._retire_slot(i, now))
        if self.paged and self._bt_dirty:
            self._sync_block_tables()  # one upload covers every retirement
        return finished

    # ------------------------------------------------------------------
    @property
    def prefill_skip_fraction(self) -> float:
        """Fraction of admitted prompt tokens served from cached prefix
        pages instead of prefill compute."""
        return self.prefill_skipped_tokens / max(self.prefill_prompt_tokens, 1)

    def kv_cache_bytes(self) -> int:
        """Device bytes held by the KV cache (pool or dense rows) alone."""
        return sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self.cache)
        )

    def memory_bytes(self) -> int:
        """Weights + cache footprint (Principle-I input).

        Counts the target params and KV cache (dense rows or paged pool +
        block tables) AND — when a draft pairing is attached — the draft
        params and draft cache, which earlier revisions omitted,
        understating the capacity Algorithm 1 budgets against."""
        leaves = list(jax.tree.leaves(self.params)) + list(
            jax.tree.leaves(self.cache)
        )
        if self.spec_enabled:
            leaves += list(jax.tree.leaves(self.draft_params))
            leaves += list(jax.tree.leaves(self.draft_cache))
        return sum(x.size * x.dtype.itemsize for x in leaves)
