"""Paged KV pool: page allocator + prefix-sharing radix tree (host side).

The engine's KV memory is a shared pool of fixed-size physical pages
(``[L, P, page, kvH, hd]`` on device); each slot names its pages through a
block-table row.  This module owns the *host-side* bookkeeping — which pages
are free, who holds references, and which page sequences are reusable as
shared prompt prefixes — so admission becomes a capacity question
("do enough pages exist?") instead of a layout question ("is a dense row
free?").  DESIGN.md §5 documents the invariants.

**PagePool** — free-list allocator with refcounts and reservations.

  * Page 0 is a **sentinel**: never allocated.  Retired slots' block-table
    rows point at it, so the fused loops' masked writes for empty/frozen
    slots land in a page nobody reads instead of corrupting live data.
  * ``refcount[p]`` counts holders: each slot using the page, plus 1 if the
    radix tree caches it.  ``decref`` to zero returns the page to the free
    list.
  * **Reservations** make admission honest under lazy allocation: a request
    is admitted only if the pool can cover its *worst-case* page need
    (prompt + full token budget), but pages are physically allocated just
    ahead of the decode loops (``InferenceEngine._top_up_pages``).  The
    reserved count is the promised-but-unallocated balance; ``available``
    (free minus reserved) is what admission may spend.

**RadixCache** — prefix tree over page-aligned prompt token chunks.

  * A node is one *full* page: key = the ``page_size`` token ids it holds,
    value = the physical page.  Only pages completely covered by a prompt
    are inserted — a page holding bucket-pad garbage can never be shared.
  * ``match`` walks the longest cached prefix; the caller increfs the
    returned pages into a new slot's block table and skips prefill for the
    covered length (the hit is page-granular by construction).
  * The tree holds its own reference on every cached page, so prefixes
    survive slot retirement.  ``evict`` reclaims least-recently-used leaves
    whose only holder is the tree; because a slot that references a page
    also references its whole prefix path, a refcount-1 node can only have
    refcount-1 descendants — every tree-only subtree is evictable.
"""
from __future__ import annotations

import heapq
from typing import Iterable, Optional, Sequence

import numpy as np

__all__ = ["PageAllocError", "PagePool", "RadixCache", "SENTINEL_PAGE"]

#: Physical page reserved as the write sink for empty/frozen slots.
SENTINEL_PAGE = 0


class PageAllocError(RuntimeError):
    """Page allocation failed — genuine pool exhaustion, or an injected
    transient allocator fault (``pool/alloc_fail``).  Recoverable by
    contract: callers unwind their partial holds and either block
    admission (capacity will return as slots retire) or quarantine the
    affected slot (DESIGN.md §9).  Reservation-invariant violations stay
    ``assert`` — those are bugs, not runtime conditions."""


class PagePool:
    """Fixed-size physical page allocator with refcounts and reservations."""

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2, "pool needs the sentinel plus >= 1 real page"
        assert page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.refcount = np.zeros((num_pages,), np.int64)
        # LIFO free list (pop from the end); sentinel page 0 excluded.
        self._free = list(range(num_pages - 1, 0, -1))
        self.reserved = 0
        #: optional ``FaultInjector`` (DESIGN.md §9): when armed, the
        #: ``pool/alloc_fail`` point makes ``alloc`` raise
        #: ``PageAllocError`` as a transient allocator fault
        self.fault_injector = None

    # -- capacity ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        """Pages admission may still promise (free minus already-reserved)."""
        return len(self._free) - self.reserved

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def occupancy(self) -> dict:
        """Capacity snapshot keyed by the ``engine/pool/*`` gauge suffixes
        (DESIGN.md §8) — sampled once per scheduling quantum."""
        return {
            "pages_in_use": self.pages_in_use,
            "available": self.available,
            "reserved": self.reserved,
        }

    def pages_for(self, tokens: int) -> int:
        """Physical pages needed to back ``tokens`` KV entries."""
        return -(-tokens // self.page_size)

    # -- reservations --------------------------------------------------
    def reserve(self, n: int) -> None:
        assert n >= 0 and self.available >= n, (
            f"reserve({n}) with only {self.available} available"
        )
        self.reserved += n

    def unreserve(self, n: int) -> None:
        assert 0 <= n <= self.reserved
        self.reserved -= n

    # -- alloc / refcount ----------------------------------------------
    def alloc(self, n: int, *, reserved: bool = False) -> list[int]:
        """Pop ``n`` free pages (refcount 1 each).  ``reserved=True``
        converts previously-reserved pages into allocated ones (the lazy
        top-up path); otherwise the pages must fit in ``available``.

        Raises ``PageAllocError`` on exhaustion (not enough available
        pages) or when an armed fault injector fires ``pool/alloc_fail``
        — both are recoverable runtime conditions the caller must
        contain, never crashes."""
        if n == 0:
            return []
        inj = self.fault_injector
        if inj is not None and inj.should_fire("pool/alloc_fail"):
            raise PageAllocError(f"injected allocator fault (alloc({n}))")
        if reserved:
            assert n <= self.reserved, "top-up exceeds this pool's reservation"
            assert n <= len(self._free), "reservation invariant violated"
            self.reserved -= n
        else:
            if n > self.available:
                raise PageAllocError(
                    f"pool exhausted: alloc({n}) with only "
                    f"{self.available} available"
                )
        pages = [self._free.pop() for _ in range(n)]
        self.refcount[pages] = 1
        return pages

    def incref(self, pages: Iterable[int]) -> None:
        for p in pages:
            assert p != SENTINEL_PAGE and self.refcount[p] > 0, (
                f"incref of unallocated page {p}"
            )
            self.refcount[p] += 1

    def decref(self, pages: Iterable[int]) -> list[int]:
        """Drop one reference per page; returns the pages that became free."""
        freed = []
        for p in pages:
            assert p != SENTINEL_PAGE and self.refcount[p] > 0, (
                f"decref of unallocated page {p}"
            )
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed


class _Node:
    __slots__ = ("chunk", "page", "parent", "children", "last_use")

    def __init__(self, chunk, page: int, parent: Optional["_Node"]):
        self.chunk = chunk  # tuple of page_size token ids (None at root)
        self.page = page
        self.parent = parent
        self.children: dict = {}
        self.last_use = 0


class RadixCache:
    """Prefix tree mapping page-aligned prompt chunks to cached pages."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.root = _Node(None, SENTINEL_PAGE, None)
        self._tick = 0
        self.pages_cached = 0
        # prefix-cache counters (engine surfaces these)
        self.hits = 0
        self.misses = 0

    def _chunks(self, tokens: Sequence[int]):
        ps = self.pool.page_size
        for j in range(len(tokens) // ps):
            yield tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.last_use = self._tick

    # ------------------------------------------------------------------
    def match(self, tokens: Sequence[int], record: bool = True) -> list[int]:
        """Pages of the longest cached full-page prefix of ``tokens``.

        Does NOT take references — the caller must ``pool.incref`` the
        returned pages before anything that could trigger eviction.
        ``record=False`` makes it a pure probe (no LRU touch, no hit/miss
        counters) for capacity queries like ``engine.can_admit``."""
        node, pages = self.root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            if record:
                self._touch(child)
            pages.append(child.page)
            node = child
        if record:
            if pages:
                self.hits += 1
            else:
                self.misses += 1
        return pages

    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> None:
        """Cache the full-page prefix of ``tokens`` backed by ``pages``
        (logical order; ``pages[j]`` holds tokens ``[j*ps, (j+1)*ps)``).

        New nodes incref their page (the tree's own hold).  Chunks already
        cached keep the tree's existing page — the caller's duplicate copy
        stays private to its slot and is freed at retirement."""
        node = self.root
        for j, chunk in enumerate(self._chunks(tokens)):
            if j >= len(pages):
                break
            child = node.children.get(chunk)
            if child is None:
                child = _Node(chunk, pages[j], node)
                node.children[chunk] = child
                self.pool.incref([pages[j]])
                self.pages_cached += 1
            self._touch(child)
            node = child

    # ------------------------------------------------------------------
    def export_nodes(self) -> list[tuple[int, tuple, int]]:
        """Flatten the tree for a warm-state snapshot (DESIGN.md §11):
        ``(parent_index, chunk, page)`` per node, parents strictly before
        children (the root is implicit at index -1).  ``page`` ids are
        only meaningful against this pool instance — a restore allocates
        fresh pages and uses them to index the saved KV contents."""
        nodes: list[tuple[int, tuple, int]] = []
        stack = [(-1, child) for child in self.root.children.values()]
        while stack:
            parent_idx, node = stack.pop()
            idx = len(nodes)
            nodes.append((parent_idx, node.chunk, node.page))
            stack.extend((idx, c) for c in node.children.values())
        return nodes

    def load_nodes(
        self, nodes: Sequence[tuple[int, tuple, int]], pages: Sequence[int]
    ) -> int:
        """Rebuild exported nodes onto THIS pool: ``pages[i]`` is the
        freshly-allocated physical page for ``nodes[i]`` (already holding
        one reference from ``pool.alloc`` — that reference becomes the
        tree's own hold, so restored pages start evictable).  Nodes whose
        chunk is already cached are skipped and their page freed; returns
        the nodes actually added."""
        by_idx: dict = {}
        added = 0
        for i, (parent_idx, chunk, _) in enumerate(nodes):
            parent = self.root if parent_idx < 0 else by_idx.get(parent_idx)
            if parent is None:
                self.pool.decref([pages[i]])
                continue  # parent was a duplicate resolved to nothing
            chunk = tuple(chunk)
            child = parent.children.get(chunk)
            if child is None:
                child = _Node(chunk, pages[i], parent)
                parent.children[chunk] = child
                self.pages_cached += 1
                added += 1
            else:
                self.pool.decref([pages[i]])
            self._touch(child)
            by_idx[i] = child
        return added

    # ------------------------------------------------------------------
    def evictable_pages(self) -> int:
        """Pages reclaimable by eviction (cached pages only the tree holds)."""
        count = 0
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            if self.pool.refcount[n.page] == 1:
                count += 1
            stack.extend(n.children.values())
        return count

    def evict(self, n: int) -> int:
        """Free up to ``n`` pages, LRU leaves first; returns pages freed.

        One tree walk collects the evictable leaves into a heap; parents
        exposed by an eviction are pushed as they become leaves, so the
        whole call is near-linear in tree size rather than one full walk
        per page freed."""
        heap: list[tuple[int, int, _Node]] = []
        tie = 0  # heap tiebreak: nodes are not orderable
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif self.pool.refcount[node.page] == 1:
                heapq.heappush(heap, (node.last_use, tie, node))
                tie += 1
        freed = 0
        while freed < n and heap:
            _, _, victim = heapq.heappop(heap)
            del victim.parent.children[victim.chunk]
            freed += len(self.pool.decref([victim.page]))
            self.pages_cached -= 1
            parent = victim.parent
            if parent is not self.root and not parent.children and (
                self.pool.refcount[parent.page] == 1
            ):
                heapq.heappush(heap, (parent.last_use, tie, parent))
                tie += 1
        return freed
