r"""Request-lifecycle engine core (DESIGN.md §6).

``EngineCore`` re-founds the serving surface around an iteration-level
``step()``: every call is ONE scheduling quantum — consult a separable
``SchedulerPolicy`` (admit / preempt / pick the k bucket and gamma), drive
the engine's fused decode or speculative loop, and return ``StepOutputs``
carrying per-request token deltas, TTFT stamps, and finish reasons.  The
paper's headline guarantee (online p95 protected while offline work soaks
up training bubbles) needs exactly this shape: an ONLINE arrival may
*preempt* a RUNNING OFFLINE slot mid-flight instead of queueing behind it.

Lifecycle::

    WAITING --admit--> RUNNING --budget/horizon--> FINISHED_LENGTH
       ^                  |    \--stop token-----> FINISHED_STOPPED
       |                  |     \--abort()-------> FINISHED_ABORTED
       +----<--preempt----+            (WAITING/PREEMPTED abort too)
            (PREEMPTED)

Preemption evicts the slot's KV pages back to the ``PagePool`` (the prompt's
full pages stay radix-cached, so resume recomputes only the uncovered
suffix via the existing prefix-hit path) and re-queues the request at the
FRONT of its priority class.  Resume re-prefills ``prompt + generated`` and
continues greedy decode — deterministic, so the resumed stream is
byte-identical to an uninterrupted run (property-tested for dense + paged,
spec on/off).

The legacy ``InferenceEngine.add_request / decode_loop / spec_decode_loop``
surface survives as a thin deprecated shim delegating to this core
(``add_legacy`` / ``run_legacy``), so pre-existing callers and tests run
unchanged through the new lifecycle.  ``scripts/check_api_surface.py``
fails CI if the shim's signature drifts from the core's delegates.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import math
from typing import Any, Callable, Iterator, Optional, Union

import numpy as np

from repro.serving.engine import DECODE_K_BUCKETS, InferenceEngine, Request

__all__ = [
    "EngineCore",
    "Grant",
    "Priority",
    "PriorityPolicy",
    "EngineRequest",
    "RequestOutput",
    "RequestState",
    "SamplingParams",
    "SchedulerPolicy",
    "StepOutputs",
    "StepPlan",
    "largest_bucket",
]


class Priority(enum.Enum):
    """Request class: ONLINE is latency-sensitive (may preempt), OFFLINE is
    throughput work that soaks up spare capacity.  Replaces the old
    ``Request.online`` bool on the new surface."""

    ONLINE = "online"
    OFFLINE = "offline"


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED_STOPPED = "finished_stopped"
    FINISHED_LENGTH = "finished_length"
    FINISHED_ABORTED = "finished_aborted"

    @property
    def finished(self) -> bool:
        return self.name.startswith("FINISHED")


#: finish_reason strings per terminal state (vLLM-style short names).
FINISH_REASONS = {
    RequestState.FINISHED_STOPPED: "stop",
    RequestState.FINISHED_LENGTH: "length",
    RequestState.FINISHED_ABORTED: "abort",
}


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters.

    The engine decodes greedily (argmax); ``stop_token_ids`` are checked
    host-side after each fused loop, so a stop can land up to ``k - 1``
    device microsteps late — the surplus tokens are trimmed from the
    stream, never delivered."""

    max_new_tokens: int = 16
    stop_token_ids: tuple[int, ...] = ()


@dataclasses.dataclass(eq=False)
class EngineRequest:
    """One request's lifecycle record.  ``output_tokens`` is the canonical
    stream: it survives preemption/resume (the per-admission engine-side
    ``Request`` only ever holds the tokens since the last admission).

    ``eq=False``: requests compare by identity.  Field equality would make
    queue membership tests compare ndarray prompts elementwise — two
    same-prompt requests must still be distinct queue entries."""

    prompt: np.ndarray  # [prompt_len] int32
    sampling: SamplingParams
    priority: Priority
    request_id: int
    arrival_time: float
    state: RequestState = RequestState.WAITING
    output_tokens: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    finish_reason: Optional[str] = None
    preemptions: int = 0
    # -- core internals --
    _internal: Optional[Request] = None  # engine-side record while RUNNING
    _consumed: int = 0  # tokens of _internal.generated already absorbed
    _ttft_reported: bool = False

    @property
    def remaining_budget(self) -> int:
        return self.sampling.max_new_tokens - len(self.output_tokens)


@dataclasses.dataclass
class Grant:
    """One quantum's scheduling inputs (the Algorithm-1 decision, or the
    permissive defaults for a dedicated serving engine).

    ``tokens`` is the Kernel-Barrier grant metering OFFLINE work (online
    execution is never token-metered, only its *admission* is gated by
    ``online_ok``).  ``now`` gates arrivals; ``None`` reads the engine
    clock.  ``max_cost_steps`` caps the quantum in microstep-equivalents
    (the remaining bubble span).  ``advance_clock``, when set, is called
    with the planned cost right before the fused loop runs, so
    virtual-clock runtimes stamp retirements at quantum end."""

    tokens: float = math.inf
    online_ok: bool = True
    phase: Any = None
    now: Optional[float] = None
    max_cost_steps: float = math.inf
    advance_clock: Optional[Callable[[float], None]] = None


@dataclasses.dataclass
class StepPlan:
    """A SchedulerPolicy's decision for one quantum."""

    admit: list = dataclasses.field(default_factory=list)  # EngineRequests
    preempt: list = dataclasses.field(default_factory=list)  # slot indices
    preempt_to_admit: bool = False  # may admission evict OFFLINE victims?
    k: int = 0
    gamma: Optional[int] = None  # None -> plain decode loop
    cost_steps: float = 0.0  # quantum cost in microstep-equivalents


@dataclasses.dataclass
class RequestOutput:
    """Per-request delta for one step."""

    request_id: int
    priority: Priority
    new_tokens: list
    state: RequestState
    finish_reason: Optional[str]
    #: seconds from arrival to first token — set ONLY on the step that
    #: produced the request's first output token, None afterwards.
    ttft_s: Optional[float]


@dataclasses.dataclass
class StepOutputs:
    outputs: list = dataclasses.field(default_factory=list)
    finished: list = dataclasses.field(default_factory=list)  # EngineRequests
    admitted: list = dataclasses.field(default_factory=list)  # request ids
    preempted: list = dataclasses.field(default_factory=list)  # request ids
    k: int = 0
    gamma: Optional[int] = None
    cost_steps: float = 0.0
    spec_accepted: int = 0
    spec_proposed: int = 0


def largest_bucket(n: int, buckets: tuple = DECODE_K_BUCKETS) -> int:
    """Largest compile bucket <= n, floored at the smallest bucket."""
    best = buckets[0]
    for b in buckets:
        if b <= n:
            best = b
    return best


# ---------------------------------------------------------------------------
# Scheduler policies
# ---------------------------------------------------------------------------


class SchedulerPolicy:
    """Separable scheduling brain ``EngineCore.step()`` consults.

    Implementations decide admission order, preemption appetite, and the
    quantum shape (k bucket, draft length gamma) from a ``Grant``; the core
    executes the plan against the engine.  ``plan`` must not mutate core
    state — failed admissions simply stay queued."""

    def plan(self, core: "EngineCore", grant: Grant) -> StepPlan:
        raise NotImplementedError

    def pick_victim(
        self, core: "EngineCore", for_request: EngineRequest
    ) -> Optional[int]:
        """Slot to evict so ``for_request`` can be admitted, or None.

        Default: only an ONLINE admission may preempt, and the victim is
        the RUNNING OFFLINE slot with the shortest total sequence — the
        cheapest resume recompute (resume re-prefills prompt+generated)."""
        if for_request.priority is not Priority.ONLINE:
            return None
        best = None
        for slot, cr in core.slot_requests.items():
            if cr.priority is not Priority.OFFLINE:
                continue
            cost = len(cr.prompt) + len(cr.output_tokens)
            if best is None or cost < best[0]:
                best = (cost, slot)
        return None if best is None else best[1]

    def observe(self, outputs: StepOutputs) -> None:
        """Post-step feedback hook (e.g. acceptance EWMA updates)."""


class PriorityPolicy(SchedulerPolicy):
    """Priority-aware FCFS with preemption — the dedicated-serving default.

    Admits every arrived ONLINE request first (evicting OFFLINE slots when
    capacity blocks, if ``preemption``), then arrived OFFLINE requests
    while the grant allows.  Picks a small k while requests are waiting
    (admission stays responsive — the old serve loop's ``k=1`` heuristic),
    the largest useful bucket otherwise."""

    def __init__(
        self,
        *,
        preemption: bool = True,
        k_buckets: tuple = DECODE_K_BUCKETS,
        gamma_ctrl=None,
    ):
        self.preemption = preemption
        self.k_buckets = tuple(k_buckets)
        self.gamma_ctrl = gamma_ctrl

    def _gamma_ctrl_for(self, engine: InferenceEngine):
        if self.gamma_ctrl is None and engine.spec_enabled:
            from repro.spec.controller import AdaptiveGammaController

            sc = engine.spec_cfg
            self.gamma_ctrl = AdaptiveGammaController(
                sc.gamma_buckets, ewma=sc.accept_ewma,
                draft_cost_ratio=sc.draft_cost_ratio,
            )
        return self.gamma_ctrl

    def plan(self, core: "EngineCore", grant: Grant) -> StepPlan:
        admit = []
        if grant.online_ok:
            admit += [
                cr for cr in core.waiting[Priority.ONLINE]
                if cr.arrival_time <= grant.now
            ]
        if grant.tokens > 0:
            admit += [
                cr for cr in core.waiting[Priority.OFFLINE]
                if cr.arrival_time <= grant.now
            ]
        running = list(core.slot_requests.values())
        want = 0
        for cr in running + admit:
            want = max(want, cr.remaining_budget)
        if want <= 0:
            return StepPlan(admit=admit, preempt_to_admit=self.preemption)
        leftover = sum(len(q) for q in core.waiting.values()) > len(admit)
        steps = 1 if leftover else min(want, grant.max_cost_steps)
        plan = StepPlan(admit=admit, preempt_to_admit=self.preemption)
        ctrl = self._gamma_ctrl_for(core.engine)
        if core.engine.spec_enabled and ctrl is not None:
            g = ctrl.gamma_for(grant.phase if grant.phase is not None else "stable")
            rounds = max(int(steps / ctrl.expected_tokens_per_round(g)), 1)
            plan.k = largest_bucket(rounds, self.k_buckets)
            plan.gamma = g
            plan.cost_steps = plan.k * ctrl.round_cost_steps(g)
        else:
            plan.k = largest_bucket(int(steps), self.k_buckets)
            plan.cost_steps = float(plan.k)
        return plan

    def observe(self, outputs: StepOutputs) -> None:
        if self.gamma_ctrl is not None and outputs.spec_proposed:
            self.gamma_ctrl.observe(outputs.spec_accepted, outputs.spec_proposed)


# ---------------------------------------------------------------------------
# EngineCore
# ---------------------------------------------------------------------------


class EngineCore:
    """Iteration-level request-lifecycle core over an ``InferenceEngine``.

    Owns the WAITING queues (one FIFO per priority class; preempted
    requests resume from the front), the slot -> request map, and the
    canonical per-request output streams.  All device compute still runs
    through the engine's fused drive loops — the core only decides *what*
    each quantum does."""

    def __init__(
        self,
        engine: InferenceEngine,
        policy: Optional[SchedulerPolicy] = None,
    ):
        self.engine = engine
        # An engine has exactly ONE lifecycle core: retirements inside the
        # fused loops notify ``engine._core``, so constructing a core binds
        # it.  Rebinding while the old core still has unfinished requests
        # (RUNNING slots or queued WAITING/PREEMPTED work) would orphan
        # them in a queue nothing steps — refuse instead.
        if engine._core is not None and engine._core.has_unfinished:
            raise RuntimeError(
                "engine already has a lifecycle core with unfinished "
                "requests; drain it before attaching a new EngineCore"
            )
        engine._core = self
        self.policy = policy or PriorityPolicy()
        self.waiting: dict = {
            Priority.ONLINE: collections.deque(),
            Priority.OFFLINE: collections.deque(),
        }
        self.requests: dict = {}  # request_id -> EngineRequest
        self.slot_requests: dict = {}  # slot index -> EngineRequest (RUNNING)
        self.preemption_count = 0
        self._finished_buffer: list = []

    # ------------------------------------------------------------------
    # Submission / queries
    # ------------------------------------------------------------------
    def submit(
        self,
        prompt,
        sampling: Optional[SamplingParams] = None,
        *,
        priority: Priority = Priority.OFFLINE,
        arrival_time: Optional[float] = None,
    ) -> EngineRequest:
        """Queue a request (WAITING).  Raises ``ValueError`` when the
        request could NEVER be admitted on this engine (prompt beyond
        ``max_seq``, or worst-case page need beyond the whole pool) —
        failing loudly at submission instead of starving the queue head."""
        sampling = sampling or SamplingParams()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        probe = Request(prompt=prompt, max_new_tokens=sampling.max_new_tokens)
        if not self.engine.request_fits(probe):
            raise ValueError(
                f"request can never be admitted on this engine "
                f"(prompt {len(prompt)} tokens, "
                f"max_new={sampling.max_new_tokens}, "
                f"max_seq={self.engine.max_seq})"
            )
        if arrival_time is None:
            arrival_time = self.engine.clock()
        cr = EngineRequest(
            prompt=prompt, sampling=sampling, priority=priority,
            request_id=probe.request_id, arrival_time=arrival_time,
        )
        self.waiting[priority].append(cr)
        self.requests[cr.request_id] = cr
        return cr

    def slot_of(self, req: EngineRequest) -> Optional[int]:
        for slot, cr in self.slot_requests.items():
            if cr is req:
                return slot
        return None

    @property
    def num_waiting(self) -> int:
        return sum(len(q) for q in self.waiting.values())

    @property
    def has_unfinished(self) -> bool:
        return bool(self.num_waiting or self.slot_requests)

    # ------------------------------------------------------------------
    # One scheduling quantum
    # ------------------------------------------------------------------
    def step(self, grant: Optional[Grant] = None) -> StepOutputs:
        """Run ONE scheduling quantum: policy plan -> preempt -> admit ->
        fused loop -> collect deltas/finishes."""
        g = grant if grant is not None else Grant()
        if g.now is None:
            g = dataclasses.replace(g, now=self.engine.clock())
        self._finished_buffer = []
        active = list(self.slot_requests.values())
        base = {cr.request_id: len(cr.output_tokens) for cr in active}
        touched = {cr.request_id: cr for cr in active}
        plan = self.policy.plan(self, g)
        out = StepOutputs(k=0, gamma=None, cost_steps=0.0)
        for slot in list(plan.preempt):
            cr = self.preempt(slot)
            if cr is not None:
                out.preempted.append(cr.request_id)
        for cr in plan.admit:
            base.setdefault(cr.request_id, len(cr.output_tokens))
            touched.setdefault(cr.request_id, cr)
            if self._try_admit(
                cr,
                allow_preempt=plan.preempt_to_admit,
                on_preempt=lambda victim: (
                    out.preempted.append(victim.request_id),
                    touched.setdefault(victim.request_id, victim),
                ),
            ):
                out.admitted.append(cr.request_id)
        k = plan.k if self.engine.num_active > 0 else 0
        a0, p0 = self.engine.spec_accepted, self.engine.spec_drafted
        if k > 0:
            out.k, out.cost_steps = k, plan.cost_steps
            if g.advance_clock is not None:
                g.advance_clock(plan.cost_steps)
            if plan.gamma is not None and self.engine.spec_enabled:
                out.gamma = plan.gamma
                self.engine._drive_spec_loop(k, plan.gamma)
            else:
                self.engine._drive_decode_loop(k)
        out.spec_accepted = self.engine.spec_accepted - a0
        out.spec_proposed = self.engine.spec_drafted - p0
        for slot, cr in list(self.slot_requests.items()):
            self._absorb_running(slot, cr)
        out.finished = list(self._finished_buffer)
        for cr in out.finished:
            touched.setdefault(cr.request_id, cr)
            base.setdefault(cr.request_id, 0)
        for rid, cr in touched.items():
            new = cr.output_tokens[base.get(rid, 0):]
            ttft = None
            if cr.first_token_time is not None and not cr._ttft_reported:
                cr._ttft_reported = True
                ttft = cr.first_token_time - cr.arrival_time
            out.outputs.append(RequestOutput(
                request_id=rid, priority=cr.priority, new_tokens=list(new),
                state=cr.state, finish_reason=cr.finish_reason, ttft_s=ttft,
            ))
        self.policy.observe(out)
        return out

    # ------------------------------------------------------------------
    def stream(
        self, req: EngineRequest, grant: Optional[Grant] = None
    ) -> Iterator[int]:
        """Yield ``req``'s tokens as they are produced, driving ``step()``
        (with ``grant``, or the permissive default) whenever the stream
        runs dry.  Returns once the request reaches a terminal state."""
        sent = 0
        stalls = 0
        while True:
            while sent < len(req.output_tokens):
                yield req.output_tokens[sent]
                sent += 1
            if req.state.finished:
                return
            out = self.step(grant)
            if out.k == 0 and not out.admitted and not out.preempted:
                stalls += 1
                if stalls > 2:
                    raise RuntimeError(
                        f"stream stalled: request {req.request_id} is "
                        f"{req.state.value} and the policy scheduled no work"
                    )
            else:
                stalls = 0

    # ------------------------------------------------------------------
    def abort(self, req: EngineRequest) -> None:
        """Terminal ABORT from any non-finished state.  A RUNNING request
        is evicted immediately — its pages return to the pool and its
        draft-cache slot state is reset (mid-decode abort never leaks)."""
        if req.state.finished:
            return
        if req.state is RequestState.RUNNING:
            slot = self.slot_of(req)
            self._collect(req)
            del self.slot_requests[slot]
            self.engine.evict_slot(slot)
            req._internal = None
        else:
            try:
                self.waiting[req.priority].remove(req)
            except ValueError:
                pass
        self._finish(req, RequestState.FINISHED_ABORTED, self.engine.clock())

    # ------------------------------------------------------------------
    def preempt(self, target: Union[int, EngineRequest]) -> Optional[EngineRequest]:
        """Evict a RUNNING slot and re-queue its request (PREEMPTED) at the
        front of its priority class.  Pages go back to the pool; the
        radix-cached prompt pages survive, so resume recomputes only the
        suffix.  Returns the preempted request (None if the slot is empty).
        """
        slot = target if isinstance(target, int) else self.slot_of(target)
        cr = self.slot_requests.pop(slot, None) if slot is not None else None
        if cr is None:
            return None
        new = self._collect(cr)
        self.engine.evict_slot(slot)
        cr._internal = None
        if self._apply_stop(cr, new):
            # the tail the eviction salvaged already carried a stop token
            self._finish(cr, RequestState.FINISHED_STOPPED, self.engine.clock())
            return cr
        cr.state = RequestState.PREEMPTED
        cr.preemptions += 1
        self.preemption_count += 1
        self.waiting[cr.priority].appendleft(cr)
        return cr

    # ------------------------------------------------------------------
    # Legacy shim surface (InferenceEngine delegates here)
    # ------------------------------------------------------------------
    def add_legacy(self, req: Request) -> bool:
        """Deprecated ``InferenceEngine.add_request`` contract: admit
        ``req`` immediately (no queueing), returning False on capacity.
        The request still joins the core lifecycle, so shim- and
        core-driven streams share one bookkeeping path."""
        if not self.engine._admit_request(req):
            return False
        cr = EngineRequest(
            prompt=np.asarray(req.prompt, np.int32).reshape(-1),
            sampling=SamplingParams(max_new_tokens=req.max_new_tokens),
            priority=Priority.ONLINE if req.online else Priority.OFFLINE,
            request_id=req.request_id,
            arrival_time=req.arrival_time,
            state=RequestState.RUNNING,
        )
        cr._internal = req
        cr.first_token_time = req.first_token_time
        slot = next(
            i for i, r in enumerate(self.engine.slots) if r is req
        )
        self.slot_requests[slot] = cr
        self.requests[cr.request_id] = cr
        return True

    def run_legacy(self, k: int, gamma: Optional[int] = None) -> list:
        """Deprecated ``decode_loop`` / ``spec_decode_loop`` contract: run
        exactly one fused loop (no admission, no preemption) and return the
        engine-side ``Request`` records that finished."""
        if self.engine.num_active == 0 or k <= 0:
            return []
        self._finished_buffer = []
        if gamma is None:
            finished = self.engine._drive_decode_loop(k)
        else:
            finished = self.engine._drive_spec_loop(k, gamma)
        for slot, cr in list(self.slot_requests.items()):
            self._absorb_running(slot, cr)
        return finished

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _collect(self, cr: EngineRequest) -> list:
        """Absorb tokens the engine produced since the last collection into
        the canonical stream; returns just the new ones."""
        gen = cr._internal.generated
        new = [int(t) for t in gen[cr._consumed:]]
        cr._consumed = len(gen)
        cr.output_tokens.extend(new)
        return new

    def _apply_stop(self, cr: EngineRequest, new: list) -> bool:
        """Host-side stop-token scan over this step's delta; trims the
        stream past the first stop (stop token included)."""
        stops = cr.sampling.stop_token_ids
        if not stops:
            return False
        for j, t in enumerate(new):
            if t in stops:
                cut = len(cr.output_tokens) - len(new) + j + 1
                del cr.output_tokens[cut:]
                return True
        return False

    def _finish(
        self, cr: EngineRequest, state: RequestState, now: float
    ) -> None:
        cr.state = state
        cr.finish_reason = FINISH_REASONS[state]
        cr.finish_time = now
        self._finished_buffer.append(cr)

    def _absorb_running(self, slot: int, cr: EngineRequest) -> None:
        new = self._collect(cr)
        if self._apply_stop(cr, new):
            del self.slot_requests[slot]
            self.engine.evict_slot(slot)
            cr._internal = None
            self._finish(cr, RequestState.FINISHED_STOPPED, self.engine.clock())

    def _on_slot_finished(self, slot: int, internal: Request) -> None:
        """Engine retirement callback (budget exhausted or max_seq horizon
        reached) — also covers retirements driven through the legacy
        ``decode_microstep`` path."""
        cr = self.slot_requests.pop(slot, None)
        if cr is None:
            return
        new = self._collect(cr)
        cr._internal = None
        state = (
            RequestState.FINISHED_STOPPED
            if self._apply_stop(cr, new) else RequestState.FINISHED_LENGTH
        )
        self._finish(cr, state, internal.finish_time)

    def _try_admit(
        self,
        cr: EngineRequest,
        *,
        allow_preempt: bool = False,
        on_preempt: Optional[Callable[[EngineRequest], Any]] = None,
    ) -> bool:
        """Admit ``cr`` (prefill into a slot), evicting policy-chosen
        OFFLINE victims while admission fails and ``allow_preempt``.  On
        failure the request simply stays where it was in its queue."""
        if cr.remaining_budget <= 0:
            # a preempted request whose budget was exactly exhausted
            self.waiting[cr.priority].remove(cr)
            self._finish(cr, RequestState.FINISHED_LENGTH, self.engine.clock())
            return False
        prompt = cr.prompt
        if cr.output_tokens:
            prompt = np.concatenate(
                [prompt, np.asarray(cr.output_tokens, np.int32)]
            )
        internal = Request(
            prompt=prompt, max_new_tokens=cr.remaining_budget,
            arrival_time=cr.arrival_time,
            online=cr.priority is Priority.ONLINE,
        )
        while not self.engine._admit_request(internal):
            victim_slot = (
                self.policy.pick_victim(self, cr) if allow_preempt else None
            )
            if victim_slot is None:
                return False
            victim = self.preempt(victim_slot)
            if victim is not None and on_preempt is not None:
                on_preempt(victim)
        slot = next(
            i for i, r in enumerate(self.engine.slots) if r is internal
        )
        self.slot_requests[slot] = cr
        try:
            self.waiting[cr.priority].remove(cr)
        except ValueError:
            pass  # legacy/externally-managed request not in a queue
        cr._internal = internal
        cr._consumed = 0
        cr.state = RequestState.RUNNING
        if cr.first_token_time is None:
            cr.first_token_time = internal.first_token_time
        return True
