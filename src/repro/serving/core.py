r"""Request-lifecycle engine core (DESIGN.md §6).

``EngineCore`` re-founds the serving surface around an iteration-level
``step()``: every call is ONE scheduling quantum — consult a separable
``SchedulerPolicy`` (admit / preempt / pick the k bucket and gamma), drive
the engine's fused decode or speculative loop, and return ``StepOutputs``
carrying per-request token deltas, TTFT stamps, and finish reasons.  The
paper's headline guarantee (online p95 protected while offline work soaks
up training bubbles) needs exactly this shape: an ONLINE arrival may
*preempt* a RUNNING OFFLINE slot mid-flight instead of queueing behind it.

Lifecycle::

    WAITING --admit--> [PREFILLING] --> RUNNING --budget--> FINISHED_LENGTH
       ^                    |              |  \--stop-----> FINISHED_STOPPED
       |                    |              |   \--abort()-> FINISHED_ABORTED
       +------<--preempt----+--------------+   (WAITING/PREEMPTED/
            (PREEMPTED)                         PREFILLING abort too)

PREFILLING exists on chunked-prefill engines only (DESIGN.md §7):
admission reserves the slot and the prompt streams as fixed-width chunks
across token-budgeted steps; monolithic engines go straight to RUNNING.

Preemption evicts the slot's KV pages back to the ``PagePool`` (the prompt's
full pages stay radix-cached, so resume recomputes only the uncovered
suffix via the existing prefix-hit path) and re-queues the request at the
FRONT of its priority class.  Resume re-prefills ``prompt + generated`` and
continues greedy decode — deterministic, so the resumed stream is
byte-identical to an uninterrupted run (property-tested for dense + paged,
spec on/off).

The legacy ``InferenceEngine.add_request / decode_loop / spec_decode_loop``
surface survives as a thin deprecated shim delegating to this core
(``add_legacy`` / ``run_legacy``), so pre-existing callers and tests run
unchanged through the new lifecycle.  ``scripts/check_api_surface.py``
fails CI if the shim's signature drifts from the core's delegates.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import math
from typing import Any, Callable, Iterator, Optional, Union

import numpy as np

from repro.obs.trace import _num as _jnum
from repro.serving.engine import DECODE_K_BUCKETS, InferenceEngine, Request

__all__ = [
    "EngineCore",
    "Grant",
    "Priority",
    "PriorityPolicy",
    "EngineRequest",
    "RequestOutput",
    "RequestState",
    "RevocationSignal",
    "SamplingParams",
    "SchedulerPolicy",
    "StepOutputs",
    "StepPlan",
    "largest_bucket",
]


class Priority(enum.Enum):
    """Request class: ONLINE is latency-sensitive (may preempt), OFFLINE is
    throughput work that soaks up spare capacity.  Replaces the old
    ``Request.online`` bool on the new surface."""

    ONLINE = "online"
    OFFLINE = "offline"


class RequestState(enum.Enum):
    WAITING = "waiting"
    #: admitted to a slot on a chunked-prefill engine, prompt still
    #: streaming in fixed-width chunks across token-budgeted steps
    #: (DESIGN.md §7); monolithic engines go straight to RUNNING
    PREFILLING = "prefilling"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED_STOPPED = "finished_stopped"
    FINISHED_LENGTH = "finished_length"
    FINISHED_ABORTED = "finished_aborted"
    #: deadline (``SamplingParams.deadline_s``) elapsed while WAITING, or
    #: the overload ladder shed the request before it took a slot
    #: (DESIGN.md §9) — the request never consumed device compute
    FINISHED_EXPIRED = "finished_expired"
    #: fault-containment gave up: the request was quarantined more times
    #: than the core's retry budget allows (DESIGN.md §9)
    FINISHED_ERROR = "finished_error"

    @property
    def finished(self) -> bool:
        return self.name.startswith("FINISHED")


#: finish_reason strings per terminal state (vLLM-style short names).
FINISH_REASONS = {
    RequestState.FINISHED_STOPPED: "stop",
    RequestState.FINISHED_LENGTH: "length",
    RequestState.FINISHED_ABORTED: "abort",
    RequestState.FINISHED_EXPIRED: "expired",
    RequestState.FINISHED_ERROR: "error",
}


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters.

    The engine decodes greedily (argmax); ``stop_token_ids`` are checked
    host-side after each fused loop, so a stop can land up to ``k - 1``
    device microsteps late — the surplus tokens are trimmed from the
    stream, never delivered."""

    max_new_tokens: int = 16
    stop_token_ids: tuple[int, ...] = ()
    #: queue TTL in engine-clock seconds, measured from ``arrival_time``.
    #: A WAITING request whose deadline elapses finishes FINISHED_EXPIRED
    #: without ever taking a slot; a request already in a slot is never
    #: expired mid-flight.  None = no deadline.
    deadline_s: Optional[float] = None


@dataclasses.dataclass(eq=False)
class EngineRequest:
    """One request's lifecycle record.  ``output_tokens`` is the canonical
    stream: it survives preemption/resume (the per-admission engine-side
    ``Request`` only ever holds the tokens since the last admission).

    ``eq=False``: requests compare by identity.  Field equality would make
    queue membership tests compare ndarray prompts elementwise — two
    same-prompt requests must still be distinct queue entries."""

    prompt: np.ndarray  # [prompt_len] int32
    sampling: SamplingParams
    priority: Priority
    request_id: int
    arrival_time: float
    state: RequestState = RequestState.WAITING
    output_tokens: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    finish_reason: Optional[str] = None
    preemptions: int = 0
    #: fault-containment bookkeeping (DESIGN.md §9): quarantines survived,
    #: and the engine-clock instant before which admission must not retry
    #: (exponential backoff after each quarantine)
    faults: int = 0
    retry_at: float = 0.0
    # -- core internals --
    _internal: Optional[Request] = None  # engine-side record while RUNNING
    _consumed: int = 0  # tokens of _internal.generated already absorbed
    _ttft_reported: bool = False
    #: consecutive clean decode quanta since the last quarantine — once it
    #: reaches ``EngineCore.fault_decay_quanta`` the fault counter resets,
    #: so transient faults spread across a long life never accumulate into
    #: FINISHED_ERROR (DESIGN.md §9)
    _clean_quanta: int = 0

    @property
    def remaining_budget(self) -> int:
        return self.sampling.max_new_tokens - len(self.output_tokens)


class RevocationSignal:
    """A grant's kill switch (DESIGN.md §9).

    The runtime raises it — immediately via ``revoke()``, or ahead of time
    via ``arm(at)`` when it knows the engine-clock instant training resumes
    — and ``EngineCore.step()`` re-checks it between decode sub-dispatches
    (``Grant.revoke_check_steps`` microsteps apart), yielding the GPU within
    a bounded number of tokens instead of running the quantum to
    completion.  Latching: once ``check()`` has observed the revocation it
    stays revoked for the signal's lifetime."""

    def __init__(self) -> None:
        self._revoked = False
        self.revoke_at = math.inf
        self.reason: Optional[str] = None

    def revoke(self, reason: str = "revoked") -> None:
        self._revoked = True
        self.reason = self.reason or reason

    def arm(self, at: float, reason: str = "early_resume") -> None:
        """Schedule revocation at engine-clock instant ``at`` (earliest
        armed instant wins)."""
        if at < self.revoke_at:
            self.revoke_at = at
            self.reason = reason

    def check(self, now: float) -> bool:
        if not self._revoked and now >= self.revoke_at:
            self._revoked = True
        return self._revoked

    @property
    def revoked(self) -> bool:
        return self._revoked


@dataclasses.dataclass
class Grant:
    """One quantum's scheduling inputs (the Algorithm-1 decision, or the
    permissive defaults for a dedicated serving engine).

    ``tokens`` is the Kernel-Barrier grant metering OFFLINE work (online
    execution is never token-metered, only its *admission* is gated by
    ``online_ok``).  ``now`` gates arrivals; ``None`` reads the engine
    clock.  ``max_cost_steps`` caps the quantum in microstep-equivalents
    (the remaining bubble span).  ``token_budget`` caps the step's MIXED
    batch — prefill chunk tokens plus decode / spec-verify tokens — so the
    worst-case step latency is bounded regardless of prompt length
    (DESIGN.md §7; monolithic engines ignore it at admission, which is
    exactly the overrun chunked prefill fixes).  ``advance_clock``, when
    set, is called with the step's cost right before the device work runs,
    so virtual-clock runtimes stamp retirements at quantum end."""

    tokens: float = math.inf
    online_ok: bool = True
    phase: Any = None
    now: Optional[float] = None
    max_cost_steps: float = math.inf
    token_budget: float = math.inf
    advance_clock: Optional[Callable[[float], None]] = None
    #: revocation kill switch (DESIGN.md §9).  None (the default) keeps
    #: the historical contract — a grant, once issued, runs its quantum to
    #: completion in one fused dispatch.  Set, the decode loop splits into
    #: sub-dispatches of ``revoke_check_steps`` microsteps and re-checks
    #: the signal between them, so ``step()`` yields within
    #: ``revoke_check_steps * slots * (gamma + 1)`` tokens of the signal
    #: being raised (plus at most the quantum's already-planned prefill
    #: chunk tokens when revoked mid-wave).
    revocation: Optional[RevocationSignal] = None
    revoke_check_steps: int = 1


@dataclasses.dataclass
class StepPlan:
    """A SchedulerPolicy's decision for one quantum."""

    admit: list = dataclasses.field(default_factory=list)  # EngineRequests
    preempt: list = dataclasses.field(default_factory=list)  # slot indices
    preempt_to_admit: bool = False  # may admission evict OFFLINE victims?
    k: int = 0
    gamma: Optional[int] = None  # None -> plain decode loop
    #: routed candidate source for the speculative quantum (DESIGN.md §10):
    #: None keeps the historical draft-pairing dispatch; a name drives the
    #: engine's ``_drive_proposed_loop`` (the draft model delegates back to
    #: the fused loop, host proposers run tree-verify rounds)
    proposer: Optional[str] = None
    cost_steps: float = 0.0  # DECODE cost in microstep-equivalents
    #: prefill-token budget for this quantum (chunked engines stream up to
    #: this many metered prompt tokens; inf = drain all pending, the
    #: permissive dedicated-serving default)
    prefill_tokens: float = math.inf
    #: microstep-equivalents charged per prefill token (0 = prefill is
    #: free in the cost model, the historical behavior)
    prefill_token_cost: float = 0.0


@dataclasses.dataclass
class RequestOutput:
    """Per-request delta for one step."""

    request_id: int
    priority: Priority
    new_tokens: list
    state: RequestState
    finish_reason: Optional[str]
    #: seconds from arrival to first token — set ONLY on the step that
    #: produced the request's first output token, None afterwards.
    ttft_s: Optional[float]


@dataclasses.dataclass
class StepOutputs:
    outputs: list = dataclasses.field(default_factory=list)
    finished: list = dataclasses.field(default_factory=list)  # EngineRequests
    admitted: list = dataclasses.field(default_factory=list)  # request ids
    preempted: list = dataclasses.field(default_factory=list)  # request ids
    k: int = 0
    gamma: Optional[int] = None
    #: the candidate source the speculative quantum ran with (None for
    #: plain decode or the un-routed draft dispatch)
    proposer: Optional[str] = None
    cost_steps: float = 0.0
    #: prefill tokens this step computed — chunk tokens streamed (chunked
    #: engines) or whole-prompt compute at admission (monolithic), so
    #: ``prefill_tokens + generated-token delta`` is the step's mixed-batch
    #: token count either way
    prefill_tokens: int = 0
    spec_accepted: int = 0
    spec_proposed: int = 0
    #: True when the grant's revocation signal cut this quantum short —
    #: ``k`` and ``cost_steps`` then reflect the microsteps actually run,
    #: not the plan (exact partial-quantum accounting, DESIGN.md §9)
    revoked: bool = False


def largest_bucket(n: int, buckets: tuple = DECODE_K_BUCKETS) -> int:
    """Largest compile bucket <= n, floored at the smallest bucket."""
    best = buckets[0]
    for b in buckets:
        if b <= n:
            best = b
    return best


# ---------------------------------------------------------------------------
# Scheduler policies
# ---------------------------------------------------------------------------


class SchedulerPolicy:
    """Separable scheduling brain ``EngineCore.step()`` consults.

    Implementations decide admission order, preemption appetite, and the
    quantum shape (k bucket, draft length gamma) from a ``Grant``; the core
    executes the plan against the engine.  ``plan`` must not mutate core
    state — failed admissions simply stay queued."""

    #: microstep-equivalents charged per prefill token by ``plan_prefill``
    #: (0 = prefill is free in the cost model, the historical behavior;
    #: SpecInF runtimes set it from the profiled per-token step cost so a
    #: bubble grant can never be overrun by a long prompt — DESIGN.md §7)
    prefill_token_cost_steps: float = 0.0

    def plan(self, core: "EngineCore", grant: Grant) -> StepPlan:
        raise NotImplementedError

    @staticmethod
    def eligible(cr: EngineRequest, grant: Grant) -> bool:
        """Admission eligibility shared by every policy: the request has
        arrived AND any fault-quarantine backoff (``retry_at``) has
        elapsed — a quarantined request must not be re-admitted into the
        very next quantum (DESIGN.md §9)."""
        return cr.arrival_time <= grant.now and cr.retry_at <= grant.now

    def _clamp_k_to_budget(
        self, plan: StepPlan, core: "EngineCore", grant: Grant
    ) -> float:
        """Clamp ``plan.k`` so the quantum's worst-case decode tokens
        (1/slot, or gamma+1/slot for spec rounds) fit the grant's
        ``token_budget``; returns the decode-token allowance consumed.

        PREFILLING slots count toward the reserve: any of them may land
        its final chunk this step and decode the full k alongside the
        RUNNING slots — sizing on running slots alone let exactly that
        step overshoot the grant."""
        eng = core.engine
        slots = min(max(eng.num_active + len(plan.admit), 1), eng.max_slots)
        per_k = slots * (1 if plan.gamma is None else plan.gamma + 1)
        if math.isfinite(grant.token_budget) and plan.k > 0:
            max_k = int(grant.token_budget // per_k)
            buckets = getattr(self, "k_buckets", DECODE_K_BUCKETS)
            if max_k < min(buckets):
                plan.k, plan.cost_steps = 0, 0.0
            elif plan.k > max_k:
                per_cost = plan.cost_steps / plan.k
                plan.k = largest_bucket(max_k, buckets)
                plan.cost_steps = plan.k * per_cost
        return plan.k * per_k

    def plan_prefill(
        self,
        core: "EngineCore",
        grant: Grant,
        plan: StepPlan,
        decode_tokens: float = 0.0,
    ) -> None:
        """Budget this quantum's prefill stream (chunked engines): at most
        the grant's ``token_budget`` minus the decode tokens already
        planned, and at most what the remaining step room can pay for at
        ``prefill_token_cost_steps`` per token — the conversion that turns
        a bubble window into an un-overrunnable token budget."""
        eng = core.engine
        # monolithic engines run no chunk waves, but their admission-time
        # prefill compute is still priced at the same per-token cost — the
        # step cost model must not depend on the prefill layout
        plan.prefill_token_cost = self.prefill_token_cost_steps
        if not getattr(eng, "prefill_chunk", 0):
            plan.prefill_tokens = 0.0
            return
        # a slot whose prompt completes mid-step emits its first generated
        # token on top of the chunk stream; reserve that slack so the
        # step's TOTAL mixed batch stays within the grant
        slack = eng.num_prefilling + len(plan.admit)
        budget = grant.token_budget - decode_tokens - slack
        ptc = self.prefill_token_cost_steps
        plan.prefill_token_cost = ptc
        if ptc > 0 and math.isfinite(grant.max_cost_steps):
            room = grant.max_cost_steps - plan.cost_steps
            budget = min(budget, room / ptc)
        plan.prefill_tokens = max(budget, 0.0)

    def pick_victim(
        self, core: "EngineCore", for_request: EngineRequest
    ) -> Optional[int]:
        """Slot to evict so ``for_request`` can be admitted, or None.

        Default: only an ONLINE admission may preempt, and the victim is
        the RUNNING OFFLINE slot with the shortest total sequence — the
        cheapest resume recompute (resume re-prefills prompt+generated)."""
        if for_request.priority is not Priority.ONLINE:
            return None
        best = None
        for slot, cr in core.slot_requests.items():
            if cr.priority is not Priority.OFFLINE:
                continue
            cost = len(cr.prompt) + len(cr.output_tokens)
            if best is None or cost < best[0]:
                best = (cost, slot)
        return None if best is None else best[1]

    def observe(self, outputs: StepOutputs) -> None:
        """Post-step feedback hook (e.g. acceptance EWMA updates)."""


class PriorityPolicy(SchedulerPolicy):
    """Priority-aware FCFS with preemption — the dedicated-serving default.

    Admits every arrived ONLINE request first (evicting OFFLINE slots when
    capacity blocks, if ``preemption``), then arrived OFFLINE requests
    while the grant allows.  Picks a small k while requests are waiting
    (admission stays responsive — the old serve loop's ``k=1`` heuristic),
    the largest useful bucket otherwise."""

    def __init__(
        self,
        *,
        preemption: bool = True,
        k_buckets: tuple = DECODE_K_BUCKETS,
        gamma_ctrl=None,
        prefill_token_cost_steps: float = 0.0,
    ):
        self.preemption = preemption
        self.k_buckets = tuple(k_buckets)
        self.gamma_ctrl = gamma_ctrl
        self.prefill_token_cost_steps = prefill_token_cost_steps

    def _gamma_ctrl_for(self, engine: InferenceEngine):
        if self.gamma_ctrl is None and (
            engine.spec_enabled or engine.host_spec_enabled
        ):
            from repro.spec.controller import AdaptiveGammaController

            sc = engine.spec_cfg
            self.gamma_ctrl = AdaptiveGammaController(
                sc.gamma_buckets, ewma=sc.accept_ewma,
                draft_cost_ratio=sc.draft_cost_ratio,
            )
        return self.gamma_ctrl

    def plan(self, core: "EngineCore", grant: Grant) -> StepPlan:
        admit = []
        if grant.online_ok:
            admit += [
                cr for cr in core.waiting[Priority.ONLINE]
                if self.eligible(cr, grant)
            ]
        if grant.tokens > 0:
            admit += [
                cr for cr in core.waiting[Priority.OFFLINE]
                if self.eligible(cr, grant)
            ]
        running = list(core.slot_requests.values())
        want = 0
        for cr in running + admit:
            want = max(want, cr.remaining_budget)
        if want <= 0:
            plan = StepPlan(admit=admit, preempt_to_admit=self.preemption)
            self.plan_prefill(core, grant, plan)
            return plan
        leftover = sum(len(q) for q in core.waiting.values()) > len(admit)
        steps = 1 if leftover else min(want, grant.max_cost_steps)
        plan = StepPlan(admit=admit, preempt_to_admit=self.preemption)
        eng = core.engine
        ctrl = self._gamma_ctrl_for(eng)
        if (eng.spec_enabled or eng.host_spec_enabled) and ctrl is not None:
            g = ctrl.gamma_for(grant.phase if grant.phase is not None else "stable")
            # grant-aware routing (DESIGN.md §10): the routed proposer sets
            # the round price — a model-free host proposal spends ~1 step
            # where a draft-model round spends 1 + (gamma+1)*cost_ratio
            plan.proposer = eng.route_proposer(g)
            round_cost = (
                eng.proposer_round_cost(plan.proposer, g)
                if plan.proposer is not None else ctrl.round_cost_steps(g)
            )
            rounds = max(int(steps / ctrl.expected_tokens_per_round(g)), 1)
            plan.k = largest_bucket(rounds, self.k_buckets)
            plan.gamma = g
            plan.cost_steps = plan.k * round_cost
        else:
            plan.k = largest_bucket(int(steps), self.k_buckets)
            plan.cost_steps = float(plan.k)
        decode_tokens = self._clamp_k_to_budget(plan, core, grant)
        self.plan_prefill(core, grant, plan, decode_tokens)
        return plan

    def observe(self, outputs: StepOutputs) -> None:
        if self.gamma_ctrl is not None and outputs.spec_proposed:
            self.gamma_ctrl.observe(outputs.spec_accepted, outputs.spec_proposed)


# ---------------------------------------------------------------------------
# EngineCore
# ---------------------------------------------------------------------------


class EngineCore:
    """Iteration-level request-lifecycle core over an ``InferenceEngine``.

    Owns the WAITING queues (one FIFO per priority class; preempted
    requests resume from the front), the slot -> request map, and the
    canonical per-request output streams.  All device compute still runs
    through the engine's fused drive loops — the core only decides *what*
    each quantum does."""

    def __init__(
        self,
        engine: InferenceEngine,
        policy: Optional[SchedulerPolicy] = None,
    ):
        self.engine = engine
        # An engine has exactly ONE lifecycle core: retirements inside the
        # fused loops notify ``engine._core``, so constructing a core binds
        # it.  Rebinding while the old core still has unfinished requests
        # (RUNNING slots or queued WAITING/PREEMPTED work) would orphan
        # them in a queue nothing steps — refuse instead.
        if engine._core is not None and engine._core.has_unfinished:
            raise RuntimeError(
                "engine already has a lifecycle core with unfinished "
                "requests; drain it before attaching a new EngineCore"
            )
        engine._core = self
        #: the engine's observability bundle (DESIGN.md §8): the core
        #: records lifecycle transitions, per-quantum trace events, and the
        #: latency/TTFT histograms into it
        self.obs = engine.obs
        self.policy = policy or PriorityPolicy()
        self.waiting: dict = {
            Priority.ONLINE: collections.deque(),
            Priority.OFFLINE: collections.deque(),
        }
        self.requests: dict = {}  # request_id -> EngineRequest
        self.slot_requests: dict = {}  # slot index -> EngineRequest (RUNNING)
        self._finished_buffer: list = []
        #: optional graceful-degradation ladder (``repro.resilience``):
        #: consulted each quantum to shed load and downshift the plan
        #: under registry pressure (DESIGN.md §9)
        self.ladder = None
        #: fault containment (DESIGN.md §9): quarantines a request may
        #: survive before FINISHED_ERROR, and the backoff base — retry n
        #: waits ``fault_backoff_s * 2**(n-1)`` engine-clock seconds
        self.max_fault_retries = 3
        self.fault_backoff_s = 0.01
        #: consecutive clean decode quanta after which a request's fault
        #: counter resets (0 disables decay — the pre-decay lifetime-
        #: counter behaviour)
        self.fault_decay_quanta = 8
        #: optional write-ahead request journal
        #: (``repro.resilience.journal.RequestJournal.attach``): submits,
        #: transitions, token deltas, and finishes are logged append-only
        #: so a killed engine can replay them into a fresh core
        #: (DESIGN.md §11)
        self.journal = None

    # ------------------------------------------------------------------
    # Submission / queries
    # ------------------------------------------------------------------
    def submit(
        self,
        prompt,
        sampling: Optional[SamplingParams] = None,
        *,
        priority: Priority = Priority.OFFLINE,
        arrival_time: Optional[float] = None,
    ) -> EngineRequest:
        """Queue a request (WAITING).  Raises ``ValueError`` when the
        request could NEVER be admitted on this engine (prompt beyond
        ``max_seq``, or worst-case page need beyond the whole pool) —
        failing loudly at submission instead of starving the queue head."""
        sampling = sampling or SamplingParams()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        probe = Request(prompt=prompt, max_new_tokens=sampling.max_new_tokens)
        if not self.engine.request_fits(probe):
            raise ValueError(
                f"request can never be admitted on this engine "
                f"(prompt {len(prompt)} tokens, "
                f"max_new={sampling.max_new_tokens}, "
                f"max_seq={self.engine.max_seq})"
            )
        if arrival_time is None:
            arrival_time = self.engine.clock()
        cr = EngineRequest(
            prompt=prompt, sampling=sampling, priority=priority,
            request_id=probe.request_id, arrival_time=arrival_time,
        )
        self.waiting[priority].append(cr)
        self.requests[cr.request_id] = cr
        self.obs.tracer.transition(
            cr.request_id, None, "waiting", arrival_time,
            priority=priority.value,
        )
        if self.journal is not None:
            self.journal.record_submit(cr, self.engine.clock())
        return cr

    def slot_of(self, req: EngineRequest) -> Optional[int]:
        for slot, cr in self.slot_requests.items():
            if cr is req:
                return slot
        return None

    @property
    def num_waiting(self) -> int:
        return sum(len(q) for q in self.waiting.values())

    @property
    def has_unfinished(self) -> bool:
        return bool(self.num_waiting or self.slot_requests)

    @property
    def preemption_count(self) -> int:
        """Total ``preempt()`` evictions — a view of the registry's
        ``core/preemptions`` counter (the historical attribute surface)."""
        return self.obs.metrics.counter("core/preemptions").value

    # ------------------------------------------------------------------
    # One scheduling quantum
    # ------------------------------------------------------------------
    def step(self, grant: Optional[Grant] = None) -> StepOutputs:
        """Run ONE scheduling quantum: policy plan -> preempt -> admit ->
        prefill chunk waves -> fused loop -> collect deltas/finishes.

        On a chunked-prefill engine the quantum is the unified token-budget
        step (DESIGN.md §7): admissions only *reserve* their slot, the
        plan's ``prefill_tokens`` budget streams prompt chunks (PREFILLING
        slots), and the fused loop decodes the RUNNING slots — a slot whose
        prompt completes mid-step starts decoding in the same quantum.  The
        whole mixed batch is priced deterministically BEFORE any device
        work runs, so virtual-clock callers stamp retirements at the true
        quantum end and no step can exceed its granted budget."""
        g = grant if grant is not None else Grant()
        if g.now is None:
            g = dataclasses.replace(g, now=self.engine.clock())
        eng = self.engine
        if (eng.fault_injector is not None
                and eng.fault_injector.should_fire("process/kill")):
            # lazy import: repro.resilience's package init imports this
            # module, so a top-level import would cycle
            from repro.resilience.faults import ProcessKilled

            raise ProcessKilled("injected process death between quanta")
        self._finished_buffer = []
        active = list(self.slot_requests.values())
        base = {cr.request_id: len(cr.output_tokens) for cr in active}
        touched = {cr.request_id: cr for cr in active}
        # monolithic engines run prefill compute inside admission; the
        # engine's layout-independent meter prices it identically to the
        # chunk waves, so cost accounting never depends on the layout
        m0 = eng.prefill_metered_tokens
        self._expire_deadlines(g.now)
        if g.token_budget <= 0:
            # degenerate grant (DESIGN.md §9): an explicit no-op quantum —
            # nothing is planned or driven, but the expiries above still
            # land, the trace still records the quantum, and the
            # starvation is counted instead of falling through to planning
            self.obs.metrics.counter("core/starved_quanta").inc()
            plan = StepPlan(prefill_tokens=0.0)
        else:
            if self.ladder is not None:
                self.ladder.update(self, g)
            plan = self.policy.plan(self, g)
            if self.ladder is not None:
                self.ladder.apply(self, g, plan)
        out = StepOutputs(k=0, gamma=None, cost_steps=0.0)
        for slot in list(plan.preempt):
            cr = self.preempt(slot)
            if cr is not None:
                out.preempted.append(cr.request_id)
        for cr in plan.admit:
            base.setdefault(cr.request_id, len(cr.output_tokens))
            touched.setdefault(cr.request_id, cr)
            if self._try_admit(
                cr,
                allow_preempt=plan.preempt_to_admit,
                on_preempt=lambda victim: (
                    out.preempted.append(victim.request_id),
                    touched.setdefault(victim.request_id, victim),
                ),
            ):
                out.admitted.append(cr.request_id)
        pf_take, completing = 0, []
        if eng.prefill_chunk and plan.prefill_tokens > 0:
            # deterministic preview: price the chunk waves before driving
            _, pf_take, completing = eng._plan_prefill_waves(
                plan.prefill_tokens
            )
        # decode only runs when some slot will be RUNNING after the waves
        still_prefilling = {
            i for i in range(eng.max_slots) if eng.slot_prefilling(i)
        } - set(completing)
        runnable = sum(
            1 for i, r in enumerate(eng.slots)
            if r is not None and i not in still_prefilling
        )
        k = plan.k if runnable > 0 else 0
        if k == 0 and plan.k > 0 and eng.prefill_chunk:
            # the planned decode can't run (every slot still mid-prefill):
            # release its token reserve back to the chunk stream instead of
            # throttling prefill below the grant for nothing.  plan.admit
            # is cleared first — those requests are already admitted (and
            # counted in num_prefilling), so re-planning must not count
            # their completion slack twice
            plan.k, plan.cost_steps = 0, 0.0
            plan.admit = []
            self.policy.plan_prefill(self, g, plan, 0.0)
            if plan.prefill_tokens > 0:
                _, pf_take, completing = eng._plan_prefill_waves(
                    plan.prefill_tokens
                )
        a0, p0 = eng.spec_accepted, eng.spec_drafted
        # prefill runs BEFORE the clock advances: a completing prompt's
        # first token stamps at quantum start, the same convention as a
        # monolithic admission's (retirements still stamp at quantum end)
        if pf_take > 0:
            eng._drive_prefill_chunks(plan.prefill_tokens)
        out.prefill_tokens = eng.prefill_metered_tokens - m0
        pf_cost = out.prefill_tokens * plan.prefill_token_cost
        ran_slots: dict = {}
        if k > 0:
            # the slots the fused loop will decode (for per-slot spans);
            # captured now because retirements mutate the map mid-loop
            ran_slots = {
                slot: cr.request_id
                for slot, cr in self.slot_requests.items()
                if not eng.slot_prefilling(slot)
            }
        if g.revocation is None:
            cost = (plan.cost_steps if k > 0 else 0.0) + pf_cost
            if (k > 0 or out.prefill_tokens > 0) \
                    and g.advance_clock is not None:
                g.advance_clock(cost)
            if k > 0:
                out.k = k
                if plan.gamma is not None and plan.proposer is not None:
                    out.gamma = plan.gamma
                    out.proposer = plan.proposer
                    eng._drive_proposed_loop(k, plan.gamma, plan.proposer)
                elif plan.gamma is not None and eng.spec_enabled:
                    out.gamma = plan.gamma
                    eng._drive_spec_loop(k, plan.gamma)
                else:
                    eng._drive_decode_loop(k)
        else:
            # revocable quantum (DESIGN.md §9): pay the prefill cost
            # first, then decode in sub-dispatches, re-checking the
            # signal between them — the quantum can stop mid-plan, with
            # the clock and the plan re-priced to what actually ran
            if out.prefill_tokens > 0 and g.advance_clock is not None:
                g.advance_clock(pf_cost)
            ran = self._drive_revocable(g, plan, k, out, pf_cost)
            plan.cost_steps = ran * (plan.cost_steps / k) if k > 0 else 0.0
            cost = plan.cost_steps + pf_cost
        inj = eng.fault_injector
        if (
            inj is not None
            and (out.k > 0 or out.prefill_tokens)
            and inj.should_fire("core/step_overrun")
        ):
            # slow-step fault (DESIGN.md §9): the quantum takes 25-75%
            # longer than priced — the overrun eats real bubble span, so
            # the step-time bound checks see it
            cost *= 1.25 + 0.5 * inj.uniform("core/step_overrun")
            if g.advance_clock is not None:
                g.advance_clock(cost)
        if out.k > 0 or out.prefill_tokens:
            out.cost_steps = cost
        out.spec_accepted = eng.spec_accepted - a0
        out.spec_proposed = eng.spec_drafted - p0
        for slot, cr in list(self.slot_requests.items()):
            if (cr.state is RequestState.PREFILLING
                    and not eng.slot_prefilling(slot)):
                # the final chunk landed during this step's waves, before
                # the clock advance: flip stamps at quantum start, where
                # the first token was stamped
                cr.state = RequestState.RUNNING
                self.obs.tracer.transition(
                    cr.request_id, "prefilling", "running", g.now,
                    priority=cr.priority.value,
                )
            self._absorb_running(slot, cr)
        if inj is not None and inj.should_fire("process/kill"):
            # mid-quantum death: device work ran and its tokens were
            # absorbed into host state, but the journal append below never
            # happens — replay-resume regenerates them byte-identically
            from repro.resilience.faults import ProcessKilled

            raise ProcessKilled("injected process death mid-quantum")
        m = self.obs.metrics
        if self.fault_decay_quanta and out.k > 0:
            # fault-counter decay (DESIGN.md §9): a quarantined request
            # that then decodes N consecutive clean quanta earns its
            # retry budget back — transient faults spread across a long
            # life must not escalate to FINISHED_ERROR
            for cr in self.slot_requests.values():
                if cr.faults and cr.state is RequestState.RUNNING:
                    cr._clean_quanta += 1
                    if cr._clean_quanta >= self.fault_decay_quanta:
                        cr.faults = 0
                        cr._clean_quanta = 0
                        m.counter("fault/decays").inc()
        out.finished = list(self._finished_buffer)
        for cr in out.finished:
            touched.setdefault(cr.request_id, cr)
            # queue-side finishes (expiry, load shedding) produced no
            # tokens this step: their delta baseline is the full stream
            base.setdefault(cr.request_id, len(cr.output_tokens))
            pri = cr.priority.value
            m.counter("core/finished/" + pri).inc()
            if cr.finish_reason != "expired":
                # served latency means completed work; shed/expired
                # requests never ran and would poison the p95
                m.histogram(f"core/{pri}_latency_s").record(
                    cr.finish_time - cr.arrival_time
                )
        for rid, cr in touched.items():
            new = cr.output_tokens[base.get(rid, 0):]
            ttft = None
            if cr.first_token_time is not None and not cr._ttft_reported:
                cr._ttft_reported = True
                ttft = cr.first_token_time - cr.arrival_time
                self.obs.tracer.instant(
                    "first_token", cr.first_token_time, request_id=rid,
                    priority=cr.priority.value,
                )
                if cr.priority is Priority.ONLINE:
                    m.histogram("core/online_ttft_s").record(ttft)
            if new:
                m.counter(
                    "core/generated_tokens/" + cr.priority.value
                ).inc(len(new))
            out.outputs.append(RequestOutput(
                request_id=rid, priority=cr.priority, new_tokens=list(new),
                state=cr.state, finish_reason=cr.finish_reason, ttft_s=ttft,
            ))
        if self.journal is not None:
            self.journal.record_step(self, out)
        self._record_quantum(g, plan, out, ran_slots)
        self.policy.observe(out)
        return out

    # ------------------------------------------------------------------
    def _drive_revocable(
        self, g: Grant, plan: StepPlan, k: int, out: StepOutputs,
        pf_cost: float = 0.0,
    ) -> int:
        """Decode portion of a revocable quantum (DESIGN.md §9): run the
        ``k`` planned microsteps as sub-dispatches of at most
        ``g.revoke_check_steps`` microsteps, re-checking the revocation
        signal (on the engine clock, which the per-sub-dispatch
        ``advance_clock`` calls keep current for virtual-clock runtimes)
        before each one.  Returns the microsteps actually run and stamps
        ``out.k`` / ``out.gamma`` / ``out.revoked``.  The extra d2h sync
        per sub-dispatch is the price of revocability — dedicated engines
        keep the single-dispatch path by leaving ``Grant.revocation``
        unset."""
        eng = self.engine
        sig = g.revocation
        inj = eng.fault_injector
        per_cost = (plan.cost_steps / k) if k > 0 else 0.0
        spec = plan.gamma is not None and (
            eng.spec_enabled or plan.proposer is not None
        )
        buckets = getattr(self.policy, "k_buckets", DECODE_K_BUCKETS)
        check = max(int(g.revoke_check_steps), 1)
        ran = 0
        while ran < k and eng.num_active > eng.num_prefilling:
            if inj is not None and inj.should_fire("core/revoke_mid_quantum"):
                sig.revoke(reason="injected_revocation")
            if sig.check(eng.clock()):
                break
            k_sub = min(largest_bucket(min(check, k - ran), buckets),
                        k - ran)
            if g.advance_clock is not None:
                # absolute from quantum start: cumulative cost so far
                g.advance_clock(pf_cost + (ran + k_sub) * per_cost)
            if spec and plan.proposer is not None:
                eng._drive_proposed_loop(k_sub, plan.gamma, plan.proposer)
            elif spec:
                eng._drive_spec_loop(k_sub, plan.gamma)
            else:
                eng._drive_decode_loop(k_sub)
            ran += k_sub
        out.k = ran
        if spec and ran > 0:
            out.gamma = plan.gamma
            out.proposer = plan.proposer
        if sig.revoked and ran < k:
            out.revoked = True
            self.obs.metrics.counter("fault/revocations").inc()
        return ran

    # ------------------------------------------------------------------
    def stream(
        self, req: EngineRequest, grant: Optional[Grant] = None
    ) -> Iterator[int]:
        """Yield ``req``'s tokens as they are produced, driving ``step()``
        (with ``grant``, or the permissive default) whenever the stream
        runs dry.  Returns once the request reaches a terminal state."""
        sent = 0
        stalls = 0
        while True:
            while sent < len(req.output_tokens):
                yield req.output_tokens[sent]
                sent += 1
            if req.state.finished:
                return
            out = self.step(grant)
            if (out.k == 0 and not out.admitted and not out.preempted
                    and not out.prefill_tokens):
                stalls += 1
                if stalls > 2:
                    raise RuntimeError(
                        f"stream stalled: request {req.request_id} is "
                        f"{req.state.value} and the policy scheduled no work"
                    )
            else:
                stalls = 0

    # ------------------------------------------------------------------
    def abort(self, req: EngineRequest) -> None:
        """Terminal ABORT from any non-finished state.  A RUNNING request
        is evicted immediately — its pages return to the pool and its
        draft-cache slot state is reset (mid-decode abort never leaks)."""
        if req.state.finished:
            return
        if req.state in (RequestState.RUNNING, RequestState.PREFILLING):
            slot = self.slot_of(req)
            self._collect(req)
            del self.slot_requests[slot]
            self.engine.evict_slot(slot)
            req._internal = None
        else:
            try:
                self.waiting[req.priority].remove(req)
            except ValueError:
                pass
        self._finish(req, RequestState.FINISHED_ABORTED, self.engine.clock())
        if self.journal is not None:
            # abort() runs outside step(), so the end-of-quantum journal
            # hook never sees this finish
            self.journal.record_finish(req, self.engine.clock())

    # ------------------------------------------------------------------
    def preempt(self, target: Union[int, EngineRequest]) -> Optional[EngineRequest]:
        """Evict a RUNNING slot and re-queue its request (PREEMPTED) at the
        front of its priority class.  Pages go back to the pool; the
        radix-cached prompt pages survive, so resume recomputes only the
        suffix.  Returns the preempted request (None if the slot is empty).
        """
        slot = target if isinstance(target, int) else self.slot_of(target)
        cr = self.slot_requests.pop(slot, None) if slot is not None else None
        if cr is None:
            return None
        frm = cr.state.value
        new = self._collect(cr)
        self.engine.evict_slot(slot)
        cr._internal = None
        if self._apply_stop(cr, new):
            # the tail the eviction salvaged already carried a stop token
            self._finish(cr, RequestState.FINISHED_STOPPED, self.engine.clock())
            return cr
        cr.state = RequestState.PREEMPTED
        cr.preemptions += 1
        self.obs.metrics.counter("core/preemptions").inc()
        self.obs.tracer.transition(
            cr.request_id, frm, "preempted", self.engine.clock(),
            priority=cr.priority.value,
        )
        self.waiting[cr.priority].appendleft(cr)
        return cr

    # ------------------------------------------------------------------
    # Legacy shim surface (InferenceEngine delegates here)
    # ------------------------------------------------------------------
    def add_legacy(self, req: Request) -> bool:
        """Deprecated ``InferenceEngine.add_request`` contract: admit
        ``req`` immediately (no queueing), returning False on capacity.
        The request still joins the core lifecycle, so shim- and
        core-driven streams share one bookkeeping path."""
        if not self.engine._admit_request(req):
            return False
        cr = EngineRequest(
            prompt=np.asarray(req.prompt, np.int32).reshape(-1),
            sampling=SamplingParams(max_new_tokens=req.max_new_tokens),
            priority=Priority.ONLINE if req.online else Priority.OFFLINE,
            request_id=req.request_id,
            arrival_time=req.arrival_time,
            state=RequestState.RUNNING,
        )
        cr._internal = req
        cr.first_token_time = req.first_token_time
        slot = next(
            i for i, r in enumerate(self.engine.slots) if r is req
        )
        self.slot_requests[slot] = cr
        self.requests[cr.request_id] = cr
        tr = self.obs.tracer
        tr.transition(
            cr.request_id, None, "waiting", cr.arrival_time,
            priority=cr.priority.value,
        )
        tr.transition(
            cr.request_id, "waiting", "running", self.engine.clock(),
            priority=cr.priority.value,
        )
        return True

    def run_legacy(self, k: int, gamma: Optional[int] = None) -> list:
        """Deprecated ``decode_loop`` / ``spec_decode_loop`` contract: run
        exactly one fused loop (no admission, no preemption) and return the
        engine-side ``Request`` records that finished."""
        if self.engine.num_active == 0 or k <= 0:
            return []
        self._finished_buffer = []
        if gamma is None:
            finished = self.engine._drive_decode_loop(k)
        else:
            finished = self.engine._drive_spec_loop(k, gamma)
        for slot, cr in list(self.slot_requests.items()):
            self._absorb_running(slot, cr)
        return finished

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _record_quantum(
        self, g: Grant, plan: StepPlan, out: StepOutputs, ran_slots: dict
    ) -> None:
        """Per-quantum observability (DESIGN.md §8): sample the gauges and
        emit the structured trace events for this step — one ``quantum``
        record plus per-slot prefill/decode/spec spans.  Span boundaries
        are the engine clock's quantum endpoints; the prefill/decode split
        inside the quantum follows the plan's deterministic cost model
        (prefill runs first, before the clock advance)."""
        eng = self.engine
        m = self.obs.metrics
        m.gauge("core/queue_depth/online").set(
            len(self.waiting[Priority.ONLINE])
        )
        m.gauge("core/queue_depth/offline").set(
            len(self.waiting[Priority.OFFLINE])
        )
        m.gauge("engine/slots_active").set(eng.num_active)
        m.gauge("engine/slots_prefilling").set(eng.num_prefilling)
        if eng.pool is not None:
            for key, v in eng.pool.occupancy().items():
                m.gauge(f"engine/pool/{key}").set(v)
        tr = self.obs.tracer
        window, tr.window_state = tr.window_state, None
        if not tr.enabled:
            return
        t0, t1 = g.now, eng.clock()
        pf_cost = out.prefill_tokens * plan.prefill_token_cost
        dec_cost = plan.cost_steps if out.k > 0 else 0.0
        total = pf_cost + dec_cost
        t_mid = t0 + (t1 - t0) * (pf_cost / total if total > 0 else 0.0)
        if out.prefill_tokens:
            if eng.prefill_chunk:
                for slot, ntok in eng.last_prefill_slot_tokens.items():
                    cr = self.slot_requests.get(slot)
                    tr.span(
                        "prefill_chunk", f"slot{slot}", t0, t_mid,
                        tokens=ntok,
                        request_id=None if cr is None else cr.request_id,
                    )
            else:
                for rid in out.admitted:
                    cr = self.requests.get(rid)
                    slot = None if cr is None else self.slot_of(cr)
                    if slot is not None:
                        tr.span(
                            "prefill", f"slot{slot}", t0, t_mid,
                            request_id=rid,
                        )
        name = "spec_round" if out.gamma is not None else "decode"
        for slot, rid in ran_slots.items():
            tr.span(
                name, f"slot{slot}", t_mid, t1, k=out.k, gamma=out.gamma,
                proposer=out.proposer, request_id=rid,
            )
        tr.quantum(
            t0, t1,
            grant={
                "tokens": _jnum(g.tokens), "online_ok": g.online_ok,
                "phase": (
                    None if g.phase is None
                    else str(getattr(g.phase, "value", g.phase))
                ),
                "max_cost_steps": _jnum(g.max_cost_steps),
                "token_budget": _jnum(g.token_budget),
            },
            k=out.k, gamma=out.gamma, proposer=out.proposer,
            cost_steps=out.cost_steps,
            prefill_tokens=out.prefill_tokens, revoked=out.revoked,
            admitted=list(out.admitted), preempted=list(out.preempted),
            finished=[cr.request_id for cr in out.finished],
            spec_accepted=out.spec_accepted,
            spec_proposed=out.spec_proposed,
            window=window,
        )

    def _collect(self, cr: EngineRequest) -> list:
        """Absorb tokens the engine produced since the last collection into
        the canonical stream; returns just the new ones.  Also propagates
        the engine-side TTFT stamp, which a chunked-prefill admission only
        produces once the prompt's final chunk lands (monolithic admission
        stamped it inside ``_try_admit``)."""
        if (cr.first_token_time is None
                and cr._internal.first_token_time is not None):
            cr.first_token_time = cr._internal.first_token_time
        gen = cr._internal.generated
        new = [int(t) for t in gen[cr._consumed:]]
        cr._consumed = len(gen)
        cr.output_tokens.extend(new)
        return new

    def _apply_stop(self, cr: EngineRequest, new: list) -> bool:
        """Host-side stop-token scan over this step's delta; trims the
        stream past the first stop (stop token included)."""
        stops = cr.sampling.stop_token_ids
        if not stops:
            return False
        for j, t in enumerate(new):
            if t in stops:
                cut = len(cr.output_tokens) - len(new) + j + 1
                del cr.output_tokens[cut:]
                return True
        return False

    def _finish(
        self, cr: EngineRequest, state: RequestState, now: float
    ) -> None:
        frm = cr.state.value
        cr.state = state
        cr.finish_reason = FINISH_REASONS[state]
        cr.finish_time = now
        self._finished_buffer.append(cr)
        self.obs.metrics.counter(
            "core/finish_reason/" + cr.finish_reason
        ).inc()
        self.obs.tracer.transition(
            cr.request_id, frm, state.value, now, priority=cr.priority.value,
        )

    def _absorb_running(self, slot: int, cr: EngineRequest) -> None:
        new = self._collect(cr)
        if self._apply_stop(cr, new):
            del self.slot_requests[slot]
            self.engine.evict_slot(slot)
            cr._internal = None
            self._finish(cr, RequestState.FINISHED_STOPPED, self.engine.clock())

    def _expire_deadlines(self, now: float) -> None:
        """Deadline sweep at quantum start (DESIGN.md §9): WAITING or
        PREEMPTED requests whose ``SamplingParams.deadline_s`` elapsed
        finish FINISHED_EXPIRED without ever taking a slot.  Requests
        already in a slot are never expired mid-flight — their deadline
        only mattered while they queued."""
        for q in self.waiting.values():
            expired = [
                cr for cr in q
                if cr.sampling.deadline_s is not None
                and now >= cr.arrival_time + cr.sampling.deadline_s
            ]
            for cr in expired:
                q.remove(cr)
                self._finish(cr, RequestState.FINISHED_EXPIRED, now)

    def shed(self, cr: EngineRequest, now: float, kind: str) -> None:
        """Load-shed a queued request (overload ladder, DESIGN.md §9):
        remove it from its WAITING queue and finish it FINISHED_EXPIRED.
        ``kind`` labels the ``fault/shed/<kind>`` counter."""
        try:
            self.waiting[cr.priority].remove(cr)
        except ValueError:
            return
        self.obs.metrics.counter("fault/shed/" + kind).inc()
        self._finish(cr, RequestState.FINISHED_EXPIRED, now)

    def _on_slot_fault(self, slot: int, internal: Request) -> None:
        """Engine quarantine callback (DESIGN.md §9): the fused loop's
        per-slot NaN screen flagged this slot, the engine scrubbed and
        freed its KV, and the request must now be re-queued (front of its
        class, exponential backoff) or — once its retry budget is spent —
        finished FINISHED_ERROR.  Tokens from the poisoned dispatch were
        never absorbed, so the retry's resumed stream stays byte-identical
        to a fault-free run."""
        cr = self.slot_requests.pop(slot, None)
        if cr is None:
            return
        frm = cr.state.value
        new = self._collect(cr)
        cr._internal = None
        cr.faults += 1
        cr._clean_quanta = 0
        now = self.engine.clock()
        if self._apply_stop(cr, new):
            # the good tokens absorbed before the fault carried a stop
            self._finish(cr, RequestState.FINISHED_STOPPED, now)
            return
        m = self.obs.metrics
        if cr.faults > self.max_fault_retries:
            m.counter("fault/retry_exhausted").inc()
            self._finish(cr, RequestState.FINISHED_ERROR, now)
            return
        cr.retry_at = now + self.fault_backoff_s * 2 ** (cr.faults - 1)
        cr.state = RequestState.PREEMPTED
        m.counter("fault/requeues").inc()
        self.obs.tracer.transition(
            cr.request_id, frm, "preempted", now, priority=cr.priority.value,
        )
        self.waiting[cr.priority].appendleft(cr)

    def _on_slot_finished(self, slot: int, internal: Request) -> None:
        """Engine retirement callback (budget exhausted or max_seq horizon
        reached) — also covers retirements driven through the legacy
        ``decode_microstep`` path."""
        cr = self.slot_requests.pop(slot, None)
        if cr is None:
            return
        new = self._collect(cr)
        cr._internal = None
        state = (
            RequestState.FINISHED_STOPPED
            if self._apply_stop(cr, new) else RequestState.FINISHED_LENGTH
        )
        self._finish(cr, state, internal.finish_time)

    def _try_admit(
        self,
        cr: EngineRequest,
        *,
        allow_preempt: bool = False,
        on_preempt: Optional[Callable[[EngineRequest], Any]] = None,
    ) -> bool:
        """Admit ``cr`` (prefill into a slot), evicting policy-chosen
        OFFLINE victims while admission fails and ``allow_preempt``.  On
        failure the request simply stays where it was in its queue."""
        frm = cr.state.value
        if cr.remaining_budget <= 0:
            # a preempted request whose budget was exactly exhausted
            self.waiting[cr.priority].remove(cr)
            self._finish(cr, RequestState.FINISHED_LENGTH, self.engine.clock())
            return False
        prompt = cr.prompt
        if cr.output_tokens:
            prompt = np.concatenate(
                [prompt, np.asarray(cr.output_tokens, np.int32)]
            )
        internal = Request(
            prompt=prompt, max_new_tokens=cr.remaining_budget,
            arrival_time=cr.arrival_time,
            online=cr.priority is Priority.ONLINE,
        )
        while not self.engine._admit_request(internal, stream_prefill=True):
            victim_slot = (
                self.policy.pick_victim(self, cr) if allow_preempt else None
            )
            if victim_slot is None:
                return False
            victim = self.preempt(victim_slot)
            if victim is not None and on_preempt is not None:
                on_preempt(victim)
        slot = next(
            i for i, r in enumerate(self.engine.slots) if r is internal
        )
        self.slot_requests[slot] = cr
        try:
            self.waiting[cr.priority].remove(cr)
        except ValueError:
            pass  # legacy/externally-managed request not in a queue
        cr._internal = internal
        cr._consumed = 0
        # chunked engines leave the slot PREFILLING: the prompt streams in
        # token-budgeted chunk waves and the state flips to RUNNING on the
        # step that lands the final chunk
        cr.state = (
            RequestState.PREFILLING if self.engine.slot_prefilling(slot)
            else RequestState.RUNNING
        )
        if cr.first_token_time is None:
            cr.first_token_time = internal.first_token_time
        self.obs.tracer.transition(
            cr.request_id, frm, cr.state.value, self.engine.clock(),
            priority=cr.priority.value,
        )
        return True
