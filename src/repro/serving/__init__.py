from repro.serving.core import (
    EngineCore,
    EngineRequest,
    Grant,
    Priority,
    PriorityPolicy,
    RequestOutput,
    RequestState,
    SamplingParams,
    SchedulerPolicy,
    StepOutputs,
    StepPlan,
)
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kv_pool import PagePool, RadixCache

__all__ = [
    "EngineCore",
    "EngineRequest",
    "Grant",
    "InferenceEngine",
    "PagePool",
    "Priority",
    "PriorityPolicy",
    "RadixCache",
    "Request",
    "RequestOutput",
    "RequestState",
    "SamplingParams",
    "SchedulerPolicy",
    "StepOutputs",
    "StepPlan",
]
