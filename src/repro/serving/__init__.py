from repro.serving.engine import InferenceEngine, Request
from repro.serving.kv_pool import PagePool, RadixCache

__all__ = ["InferenceEngine", "Request", "PagePool", "RadixCache"]
