"""Fault-tolerant checkpointing: atomic, async, restorable mid-run.

Layout:  <dir>/step_<N>/
           manifest.json   {"step": N, "complete": true, "tree": <structure>}
           arrays.npz      flattened leaves keyed by tree path

Guarantees used by the train loop's failure-recovery path:
  * atomicity     -- written to ``step_<N>.tmp`` then os.rename (POSIX atomic)
  * completeness  -- manifest written last; restore ignores dirs without it
  * async         -- ``save(..., blocking=False)`` snapshots to host memory
                     synchronously (device -> np) then writes on a daemon
                     thread, so the train step dispatch is not blocked
  * retention     -- keeps the newest ``keep`` checkpoints, GCs the rest
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        self.wait()  # one in-flight async save at a time
        flat = _flatten(tree)  # device -> host snapshot happens here
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(
                    {"step": step, "complete": True, "tree": str(treedef)}, f
                )
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if (
                name.startswith("step_")
                and not name.endswith(".tmp")
                and os.path.exists(os.path.join(full, "manifest.json"))
            ):
                with open(os.path.join(full, "manifest.json")) as f:
                    m = json.load(f)
                if m.get("complete"):
                    steps.append(int(m["step"]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> tuple[Any, int]:
        """Restore into the structure/dtypes/shardings of ``template``."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path_t, leaf in leaves_t:
            key = "/".join(str(p) for p in path_t)
            arr = data[key]
            if hasattr(leaf, "sharding"):
                arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out
        )
        return tree, step

    # -- retention ------------------------------------------------------
    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
