"""Fault-tolerant checkpointing: atomic, async, restorable mid-run.

Layout:  <dir>/step_<N>/
           manifest.json   {"step": N, "complete": true, "tree": <structure>}
           arrays.npz      flattened leaves keyed by tree path

Guarantees used by the train loop's failure-recovery path:
  * atomicity     -- written to ``step_<N>.tmp`` then os.rename (POSIX atomic)
  * durability    -- arrays + manifest are fsync'd, then the directory, so
                     a torn save can't survive a power loss as a
                     complete-looking checkpoint (DESIGN.md §11)
  * completeness  -- manifest written last; restore ignores dirs without it
                     (or with ``complete: false``) and falls back to the
                     previous step — including past a dir whose arrays are
                     unreadable despite a valid manifest
  * async         -- ``save(..., blocking=False)`` snapshots to host memory
                     synchronously (device -> np) then writes on a daemon
                     thread, so the train step dispatch is not blocked
  * retention     -- keeps the newest ``keep`` checkpoints, GCs the rest
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile
from typing import Any, Optional

import jax
import numpy as np


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directories need their entries
    made durable too — the rename is only atomic, not durable, without
    it)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        self.wait()  # one in-flight async save at a time
        flat = _flatten(tree)  # device -> host snapshot happens here
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            arrays = os.path.join(tmp, "arrays.npz")
            np.savez(arrays, **flat)
            _fsync_path(arrays)  # arrays durable BEFORE the manifest exists
            manifest = os.path.join(tmp, "manifest.json")
            with open(manifest, "w") as f:
                json.dump(
                    {"step": step, "complete": True, "tree": str(treedef)}, f
                )
                f.flush()
                os.fsync(f.fileno())
            _fsync_path(tmp)  # the dir entries themselves
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _fsync_path(self.directory)  # make the rename durable
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if (
                name.startswith("step_")
                and not name.endswith(".tmp")
                and os.path.exists(os.path.join(full, "manifest.json"))
            ):
                with open(os.path.join(full, "manifest.json")) as f:
                    m = json.load(f)
                if m.get("complete"):
                    steps.append(int(m["step"]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> tuple[Any, int]:
        """Restore into the structure/dtypes/shardings of ``template``.

        Torn-save tolerant: a ``step_*`` dir whose manifest is missing or
        says ``complete: false`` is never considered, and one whose arrays
        turn out unreadable (crash mid-save on a pre-fsync filesystem) is
        skipped in favour of the previous valid step."""
        candidates = self.all_steps()
        if step is not None:
            candidates = [s for s in candidates if s <= step]
        if not candidates:
            raise FileNotFoundError(
                f"no restorable checkpoint in {self.directory}"
                + (f" at or before step {step}" if step is not None else "")
            )
        errors: list[str] = []
        for s in reversed(candidates):
            path = os.path.join(self.directory, f"step_{s:08d}", "arrays.npz")
            try:
                with np.load(path) as data:
                    leaves_t, _ = jax.tree_util.tree_flatten_with_path(
                        template
                    )
                    out = []
                    for path_t, leaf in leaves_t:
                        key = "/".join(str(p) for p in path_t)
                        arr = data[key]
                        if hasattr(leaf, "sharding"):
                            arr = jax.device_put(
                                arr.astype(leaf.dtype), leaf.sharding
                            )
                        out.append(arr)
            except (OSError, KeyError, ValueError,
                    zipfile.BadZipFile) as e:
                errors.append(f"step {s}: {e}")
                continue  # torn/corrupt: fall back to the previous step
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), out
            )
            return tree, s
        raise FileNotFoundError(
            f"every candidate checkpoint in {self.directory} is "
            f"unreadable: {'; '.join(errors)}"
        )

    # -- retention ------------------------------------------------------
    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
