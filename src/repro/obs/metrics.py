"""Metrics registry (DESIGN.md §8): counters, gauges, and fixed-memory
streaming histograms behind stable names.

Before this subsystem the engine's self-knowledge was ad-hoc attributes
scattered across ``InferenceEngine`` (``d2h_transfers``, ``spec_*``, ...),
``FillingMetrics`` (unbounded latency lists), and hand-maintained bench
counters — three divergent sources for the same quantities.  The registry
is the ONE place those numbers live:

* ``Counter`` — monotone-ish integer cell (``inc``/``set``).  The engine's
  historical attributes survive as *thin views* over registry counters
  (``repro.serving.engine.RegistryCounterView``), so ``engine.d2h_transfers
  += 1`` and the registry's ``engine/d2h_transfers`` are the same cell and
  can never diverge.  ``scripts/check_api_surface.py`` pins the view ->
  stable-name mapping.
* ``Gauge`` — last-value cell sampled per scheduling quantum (queue depths,
  pool occupancy, active slots), with min/max/count over the run.
* ``StreamingHistogram`` — fixed-memory distribution sketch with EXACT
  percentiles at bench scale: raw samples are kept verbatim up to
  ``exact_cap`` (so ``percentile(95)`` is bit-for-bit
  ``np.percentile(samples, 95)``, preserving every historical bench/metric
  value), then collapse once into ``num_bins`` fixed-width bins, after
  which memory is bounded regardless of load (the trace-driven 10-100x
  regime) and percentiles are linearly interpolated within a bin.

Stable names are path-shaped (``engine/...``, ``core/...``).  Re-requesting
a name returns the SAME instrument; requesting it as a different type is an
error (one name, one meaning).
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "StreamingHistogram",
    "MetricsRegistry",
    "STABLE_NAMES",
]

#: The stable metric names the serving stack registers (the observability
#: API surface — ``scripts/check_api_surface.py`` pins the engine-attribute
#: views onto the ``engine/*`` entries).  New metrics may be added freely;
#: renaming or retyping one of these is a breaking change.
STABLE_NAMES = {
    # engine compute counters (thin-view attributes on InferenceEngine)
    "engine/d2h_transfers": "counter",
    "engine/steps_executed": "counter",
    "engine/generated_tokens": "counter",
    "engine/prefill_prompt_tokens": "counter",
    "engine/prefill_skipped_tokens": "counter",
    "engine/prefill_metered_tokens": "counter",
    "engine/spec_rounds": "counter",
    "engine/spec_drafted": "counter",
    "engine/spec_accepted": "counter",
    # pluggable speculation proposers (DESIGN.md §10)
    "spec/proposer/rounds/draft": "counter",
    "spec/proposer/rounds/ngram": "counter",
    "spec/proposer/rounds/suffix": "counter",
    "spec/proposer/proposed/draft": "counter",
    "spec/proposer/proposed/ngram": "counter",
    "spec/proposer/proposed/suffix": "counter",
    "spec/proposer/accepted/draft": "counter",
    "spec/proposer/accepted/ngram": "counter",
    "spec/proposer/accepted/suffix": "counter",
    "spec/proposer/acceptance/draft": "gauge",
    "spec/proposer/acceptance/ngram": "gauge",
    "spec/proposer/acceptance/suffix": "gauge",
    "spec/proposer/tree_nodes": "gauge",
    "spec/proposer/router_switches": "counter",
    "spec/proposer/no_match_fallbacks": "counter",
    # request-lifecycle counters (EngineCore)
    "core/preemptions": "counter",
    "core/finish_reason/stop": "counter",
    "core/finish_reason/length": "counter",
    "core/finish_reason/abort": "counter",
    "core/finish_reason/expired": "counter",
    "core/finish_reason/error": "counter",
    "core/finished/online": "counter",
    "core/finished/offline": "counter",
    "core/generated_tokens/online": "counter",
    "core/generated_tokens/offline": "counter",
    "core/starved_quanta": "counter",
    # failure containment + graceful degradation (DESIGN.md §9)
    "fault/injected": "counter",
    "fault/nan_quarantines": "counter",
    "fault/alloc_failures": "counter",
    "fault/requeues": "counter",
    "fault/retry_exhausted": "counter",
    "fault/revocations": "counter",
    "fault/early_resume": "counter",
    "fault/shed/online": "counter",
    "fault/shed/offline": "counter",
    "fault/ladder_escalations": "counter",
    "fault/ladder_steps/normal": "counter",
    "fault/ladder_steps/spec_off": "counter",
    "fault/ladder_steps/k_shrink": "counter",
    "fault/ladder_steps/shed_offline": "counter",
    "fault/ladder_steps/shed_online": "counter",
    "fault/ladder_stage": "gauge",
    "fault/revocation_overrun_s": "histogram",
    "fault/decays": "counter",
    # crash durability: write-ahead journal + replay recovery (DESIGN.md §11)
    "journal/appends": "counter",
    "journal/fsyncs": "counter",
    "journal/bytes": "counter",
    "recovery/restores": "counter",
    "recovery/replayed_tokens": "counter",
    "recovery/requeued_waiting": "counter",
    "recovery/resumed_inflight": "counter",
    "recovery/skipped_finished": "counter",
    "recovery/torn_tail": "counter",
    "recovery/duration_s": "gauge",
    "recovery/snapshot_saves": "counter",
    "recovery/snapshot_nodes": "counter",
    "recovery/snapshot_discarded": "counter",
    # per-quantum gauges
    "core/queue_depth/online": "gauge",
    "core/queue_depth/offline": "gauge",
    "engine/slots_active": "gauge",
    "engine/slots_prefilling": "gauge",
    "engine/pool/pages_in_use": "gauge",
    "engine/pool/available": "gauge",
    "engine/pool/reserved": "gauge",
    # latency distributions (FillingMetrics' derived views)
    "core/online_ttft_s": "histogram",
    "core/online_latency_s": "histogram",
    "core/offline_latency_s": "histogram",
}


class Counter:
    """Integer cell.  ``value`` is directly readable (the thin-view
    attributes return it), so hot paths pay one attribute load."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v


class Gauge:
    """Last-value cell with run-level min/max/sample-count — ``set`` once
    per scheduling quantum gives the end-of-run summary its peak queue
    depth / pool occupancy without keeping a sample list."""

    __slots__ = ("name", "value", "min", "max", "samples")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.samples = 0

    def set(self, v) -> None:
        v = float(v)
        self.value = v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.samples += 1


class StreamingHistogram:
    """Fixed-memory streaming histogram with exact percentiles at bench
    scale.

    Samples are stored verbatim while ``count <= exact_cap`` — in that
    regime ``percentile(q)`` is literally ``np.percentile(samples, q)``, so
    every percentile the old unbounded lists produced reproduces
    bit-for-bit.  The first record past the cap collapses the buffer into
    ``num_bins`` fixed-width bins spanning the observed range; from then on
    memory is O(num_bins) forever and percentiles interpolate linearly
    within a bin (error bounded by one bin width; min/max/count/sum stay
    exact).  Out-of-range records after collapse clamp into the edge bins
    (true min/max still tracked)."""

    __slots__ = (
        "name", "exact_cap", "num_bins", "count", "sum", "min", "max",
        "_samples", "_bins", "_edges",
    )

    def __init__(self, name: str = "", exact_cap: int = 8192,
                 num_bins: int = 256):
        assert exact_cap >= 1 and num_bins >= 2
        self.name = name
        self.exact_cap = exact_cap
        self.num_bins = num_bins
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: Optional[list] = []
        self._bins: Optional[np.ndarray] = None
        self._edges: Optional[np.ndarray] = None

    @property
    def exact(self) -> bool:
        """True while every recorded sample is still held verbatim."""
        return self._samples is not None

    def record(self, x) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if self._samples is not None:
            self._samples.append(x)
            if len(self._samples) > self.exact_cap:
                self._collapse()
        else:
            i = int(np.searchsorted(self._edges, x, side="right")) - 1
            self._bins[min(max(i, 0), self.num_bins - 1)] += 1

    def _collapse(self) -> None:
        lo, hi = self.min, self.max
        if not hi > lo:  # all samples identical (or a single value)
            hi = lo + 1.0
        self._edges = np.linspace(lo, hi, self.num_bins + 1)
        self._bins, _ = np.histogram(self._samples, bins=self._edges)
        self._bins = self._bins.astype(np.int64)
        self._samples = None

    def values(self) -> list:
        """The exact sample list (the historical unbounded-list view).
        Only available while ``exact``; past the cap the samples no longer
        exist — use ``percentile``/``count``/``sum`` instead."""
        if self._samples is None:
            raise RuntimeError(
                f"histogram {self.name!r} collapsed to bins after "
                f"{self.exact_cap} samples; exact values are gone — query "
                "percentile()/count/sum instead"
            )
        return list(self._samples)

    def percentile(self, q: float) -> float:
        """q-th percentile (0..100).  Exact (``np.percentile``) while under
        the cap; bin-interpolated after.  NaN when empty."""
        if self.count == 0:
            return float("nan")
        if self._samples is not None:
            return float(np.percentile(self._samples, q))
        # nearest-rank walk over the bin CDF, interpolated within the bin
        target = q / 100.0 * self.count
        cum = np.cumsum(self._bins)
        i = int(np.searchsorted(cum, target, side="left"))
        i = min(i, self.num_bins - 1)
        prev = float(cum[i - 1]) if i > 0 else 0.0
        inbin = float(self._bins[i])
        frac = (target - prev) / inbin if inbin > 0 else 0.0
        lo, hi = float(self._edges[i]), float(self._edges[i + 1])
        return float(min(max(lo + frac * (hi - lo), self.min), self.max))

    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")


class MetricsRegistry:
    """Name -> instrument map with get-or-create semantics.  One registry
    per engine (``InferenceEngine.obs.metrics``); the core, the runtime,
    and the benches all read the same cells."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> StreamingHistogram:
        return self._get(name, StreamingHistogram, **kw)

    def names(self) -> list:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-able dump of every instrument (the end-of-run summary and
        the trace meta header read this)."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = {"type": "counter", "value": m.value}
            elif isinstance(m, Gauge):
                out[name] = {
                    "type": "gauge", "value": m.value, "samples": m.samples,
                    "min": None if m.samples == 0 else m.min,
                    "max": None if m.samples == 0 else m.max,
                }
            else:
                out[name] = {
                    "type": "histogram", "count": m.count, "sum": m.sum,
                    "min": None if m.count == 0 else m.min,
                    "max": None if m.count == 0 else m.max,
                    "exact": m.exact,
                    "p50": None if m.count == 0 else m.percentile(50),
                    "p95": None if m.count == 0 else m.percentile(95),
                    "p99": None if m.count == 0 else m.percentile(99),
                }
        return out
