"""Engine observability layer (DESIGN.md §8).

Three coupled pieces, one per module:

* ``metrics`` — the registry (counters / gauges / fixed-memory streaming
  histograms) behind stable names; the engine's historical counter
  attributes are thin views over it.
* ``trace`` — the structured step tracer (one event per scheduling
  quantum, request transitions, per-slot spans) with JSONL and
  Chrome-trace/Perfetto export, plus the per-engine ``Observability``
  bundle that ties a registry and a tracer together.
* ``attribution`` — per-request SLO decomposition (queueing / prefill /
  decode / preempted) computed from trace transitions on the engine's
  single clock.
* ``schema`` — the trace's authoritative field list and the
  dependency-free validator CI runs over the JSONL artifact.
"""
from repro.obs.attribution import RequestAttribution, attribute
from repro.obs.metrics import (
    STABLE_NAMES,
    Counter,
    Gauge,
    MetricsRegistry,
    StreamingHistogram,
)
from repro.obs.schema import validate_events, validate_jsonl
from repro.obs.trace import Observability, StepTracer, chrome_trace

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "Observability",
    "RequestAttribution",
    "STABLE_NAMES",
    "StepTracer",
    "StreamingHistogram",
    "attribute",
    "chrome_trace",
    "validate_events",
    "validate_jsonl",
]
