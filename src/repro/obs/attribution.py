"""SLO attribution (DESIGN.md §8): decompose each request's lifetime into
queueing / prefill / decode / preempted segments from trace transitions.

The decomposition is a telescoping sum over the request's state-transition
timeline: the interval between consecutive transitions is charged to the
state the request was IN during it (WAITING -> queueing, PREFILLING ->
prefill, RUNNING -> decode, PREEMPTED -> preempted), so by construction

    queueing + prefill + decode + preempted == finish_time - arrival_time

exactly (float addition of exact interval differences; tests assert it to
1e-9).  On monolithic-prefill engines the admission transition goes
straight to RUNNING with the first token stamped at the same clock instant,
so their prefill segment is the sub-interval of RUNNING before the
``first_token`` instant event — zero on the virtual clock, where monolithic
prefill is charged as part of the quantum's clock advance.  TTFT is the
queueing + prefill prefix (arrival -> first token).

Because every timestamp entering the trace comes from the engine's single
clock, these segments are directly comparable with the registry's
latency/TTFT histograms — ``FillingMetrics`` percentiles and the
attribution view are two projections of the same stamped events, not two
measurement paths.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["RequestAttribution", "attribute"]

#: state (transition ``to`` value) -> attribution bucket charged while the
#: request sits in that state
_BUCKET = {
    "waiting": "queueing",
    "prefilling": "prefill",
    "running": "decode",
    "preempted": "preempted",
}


@dataclasses.dataclass
class RequestAttribution:
    """One request's lifetime decomposition on the engine clock."""

    request_id: int
    priority: Optional[str]
    arrival_time: float
    finish_time: Optional[float]  # None while the request is still live
    finish_state: Optional[str]
    queueing: float = 0.0
    prefill: float = 0.0
    decode: float = 0.0
    preempted: float = 0.0
    first_token_time: Optional[float] = None
    preemptions: int = 0

    @property
    def total(self) -> float:
        return self.queueing + self.prefill + self.decode + self.preempted

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total"] = self.total
        d["latency_s"] = self.latency_s
        d["ttft_s"] = self.ttft_s
        return d


def attribute(events: list) -> dict:
    """Build ``{request_id: RequestAttribution}`` from trace events.

    Only ``transition`` events (plus ``first_token`` instants, used to
    split a monolithic admission's RUNNING interval into prefill + decode)
    participate.  Transitions are ordered by ``(t, seq)`` — seq breaks the
    ties a virtual clock produces when several lifecycle edges share one
    quantum-start stamp."""
    trans: dict = {}
    first_tok: dict = {}
    for ev in events:
        if ev["type"] == "transition":
            trans.setdefault(ev["request_id"], []).append(ev)
        elif ev["type"] == "instant" and ev.get("name") == "first_token":
            rid = ev["args"].get("request_id")
            if rid is not None and rid not in first_tok:
                first_tok[rid] = ev["t"]

    out: dict = {}
    for rid, evs in trans.items():
        evs.sort(key=lambda e: (e["t"], e["seq"]))
        priority = next(
            (e["priority"] for e in evs if e.get("priority")), None
        )
        ra = RequestAttribution(
            request_id=rid, priority=priority,
            arrival_time=evs[0]["t"], finish_time=None, finish_state=None,
            first_token_time=first_tok.get(rid),
        )
        for cur, nxt in zip(evs, evs[1:]):
            bucket = _BUCKET.get(cur["to"])
            if bucket is None:
                continue  # terminal state: nothing accrues after it
            a, b = cur["t"], nxt["t"]
            ft = ra.first_token_time
            if (bucket == "decode" and ft is not None and a <= ft <= b
                    and ra.prefill == 0.0 and ra.decode == 0.0):
                # monolithic admission: the first RUNNING interval holds
                # the prefill compute up to the first token
                ra.prefill += ft - a
                ra.decode += b - ft
            else:
                setattr(ra, bucket, getattr(ra, bucket) + (b - a))
            if nxt["to"] == "preempted":
                ra.preemptions += 1
        last = evs[-1]
        if last["to"].startswith("finished"):
            ra.finish_time = last["t"]
            ra.finish_state = last["to"]
        out[rid] = ra
    return out
