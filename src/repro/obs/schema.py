"""Trace schema (DESIGN.md §8): the authoritative field list for every
event kind the step tracer emits, plus a dependency-free validator CI runs
over the JSONL artifact (``scripts/check_trace_schema.py``).

The schema is deliberately plain data — ``{kind: {field: type-spec}}`` —
so the validator needs no third-party jsonschema package (nothing may be
pip-installed in CI beyond the baked image).  A type-spec is a type, a
tuple of types (union), or the sentinel ``NULLABLE(t)`` meaning ``t`` or
None.  Unknown extra fields are allowed (forward compatibility); missing
or mistyped required fields are errors.
"""
from __future__ import annotations

__all__ = ["EVENT_SCHEMAS", "INSTANT_ARG_SCHEMAS", "SPAN_ARG_SCHEMAS",
           "validate_event", "validate_events", "validate_jsonl"]


def NULLABLE(t):
    return (t, type(None))


_NUM = (int, float)

#: kind -> required fields.  ``seq`` is stamped on every recorded event;
#: the meta header (first JSONL line) is validated separately.
EVENT_SCHEMAS = {
    "quantum": {
        "t0": _NUM, "t1": _NUM, "seq": int, "args": dict,
    },
    "span": {
        "name": str, "track": str, "t0": _NUM, "t1": _NUM, "seq": int,
        "args": dict,
    },
    "instant": {
        "name": str, "track": str, "t": _NUM, "seq": int, "args": dict,
    },
    "transition": {
        "request_id": int, "frm": NULLABLE(str), "to": str, "t": _NUM,
        "seq": int, "priority": NULLABLE(str),
    },
}

META_SCHEMA = {"version": int, "events": int, "dropped": int}

#: the request states a transition may name (serving.core.RequestState
#: values; a new state must be added here AND to the attribution buckets)
TRANSITION_STATES = {
    "waiting", "prefilling", "running", "preempted",
    "finished_stopped", "finished_length", "finished_aborted",
    "finished_expired", "finished_error",
}

#: span/instant names with a pinned ``args`` contract (DESIGN.md §11).
#: Other names stay free-form; these are recovery's attribution-critical
#: events, so their args are part of the schema.
SPAN_ARG_SCHEMAS = {
    "recovery": {"requests": int, "tokens": int, "clock_shift": _NUM},
}
INSTANT_ARG_SCHEMAS = {
    "arrival_restamp": {"request_id": int, "old": _NUM, "new": _NUM},
}


def _check_fields(ev: dict, schema: dict, where: str, errors: list) -> None:
    for field, spec in schema.items():
        if field not in ev:
            errors.append(f"{where}: missing field {field!r}")
        elif not isinstance(ev[field], spec):
            errors.append(
                f"{where}: field {field!r} has type "
                f"{type(ev[field]).__name__}, expected {spec}"
            )


def validate_event(ev, where: str = "event") -> list:
    """Structural errors for one event dict (empty list = valid)."""
    errors: list = []
    if not isinstance(ev, dict):
        return [f"{where}: not an object"]
    kind = ev.get("type")
    if kind == "meta":
        _check_fields(ev, META_SCHEMA, where, errors)
        return errors
    schema = EVENT_SCHEMAS.get(kind)
    if schema is None:
        return [f"{where}: unknown event type {kind!r}"]
    _check_fields(ev, schema, where, errors)
    if errors:
        return errors
    if "t0" in schema and ev["t1"] < ev["t0"]:
        errors.append(f"{where}: t1 < t0 ({ev['t1']} < {ev['t0']})")
    if kind == "transition":
        if ev["to"] not in TRANSITION_STATES:
            errors.append(f"{where}: unknown state {ev['to']!r}")
        if ev["frm"] is not None and ev["frm"] not in TRANSITION_STATES:
            errors.append(f"{where}: unknown state {ev['frm']!r}")
    elif kind == "span":
        args_schema = SPAN_ARG_SCHEMAS.get(ev["name"])
        if args_schema is not None:
            _check_fields(ev["args"], args_schema, f"{where}.args", errors)
    elif kind == "instant":
        args_schema = INSTANT_ARG_SCHEMAS.get(ev["name"])
        if args_schema is not None:
            _check_fields(ev["args"], args_schema, f"{where}.args", errors)
    return errors


def validate_events(events, max_errors: int = 20) -> list:
    """Validate a sequence of event dicts: per-event structure plus the
    stream invariants (strictly increasing ``seq``, non-negative clock)."""
    errors: list = []
    prev_seq = -1
    for i, ev in enumerate(events):
        errors.extend(validate_event(ev, f"event[{i}]"))
        if isinstance(ev, dict) and isinstance(ev.get("seq"), int):
            if ev["seq"] <= prev_seq:
                errors.append(
                    f"event[{i}]: seq {ev['seq']} not increasing "
                    f"(prev {prev_seq})"
                )
            prev_seq = ev["seq"]
        if len(errors) >= max_errors:
            errors.append("... (further errors suppressed)")
            break
    return errors


def validate_jsonl(path: str, max_errors: int = 20) -> tuple:
    """Validate a JSONL trace file.  Returns ``(num_events, errors)``.
    Line 1 must be the meta header; every further line one event."""
    import json

    errors: list = []
    events: list = []
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines:
        return 0, [f"{path}: empty file"]
    try:
        head = json.loads(lines[0])
    except json.JSONDecodeError as e:
        return 0, [f"{path}:1: not JSON ({e})"]
    if head.get("type") != "meta":
        errors.append(f"{path}:1: first line must be the meta header")
    else:
        errors.extend(validate_event(head, f"{path}:1"))
    for ln, line in enumerate(lines[1:], start=2):
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError as e:
            errors.append(f"{path}:{ln}: not JSON ({e})")
            if len(errors) >= max_errors:
                return len(events), errors
    errors.extend(validate_events(events, max_errors=max_errors))
    if head.get("type") == "meta" and head.get("events") != len(events):
        errors.append(
            f"{path}: meta header declares {head.get('events')} events, "
            f"file holds {len(events)}"
        )
    return len(events), errors
