"""Structured step tracer (DESIGN.md §8): one event per scheduling quantum,
plus request state transitions and per-slot spans, exported as JSONL and as
a Chrome trace (open in ``chrome://tracing`` or https://ui.perfetto.dev).

Every timestamp a tracer event carries comes from the ENGINE'S clock (the
caller stamps; the tracer never reads a clock of its own), so a collocated
virtual-clock run produces a trace entirely on the virtual timebase — the
same single-clock rule the engine applies to request timestamps.  Event
kinds (``repro.obs.schema`` is the authoritative field list):

* ``quantum`` — one per ``EngineCore.step()``: the grant, the policy plan
  (k / gamma / admissions / preemptions / prefill budget), realized token
  costs, the clock advance, and the bubble-monitor window state when a
  SpecInF runtime drove the step.
* ``transition`` — one per request state change (WAITING at submission,
  admissions, preemptions, finishes), the raw material SLO attribution
  (``repro.obs.attribution``) decomposes into queueing / prefill / decode /
  preempted segments.
* ``span`` — an interval on a named track: ``train`` carries training
  compute and bubble spans; ``slot{i}`` carries that slot's prefill chunks,
  decode runs, and spec rounds.  Intra-quantum sub-spans are positioned by
  the plan's deterministic cost split (exact token counts ride in ``args``).
* ``instant`` — point events (a request's first token).

Memory is bounded: past ``max_events`` the tracer counts drops instead of
growing (``dropped``); a disabled tracer records nothing and costs one
attribute check per call site.
"""
from __future__ import annotations

import json
import math
from typing import Optional

__all__ = ["StepTracer", "Observability", "chrome_trace", "TRACE_VERSION"]

TRACE_VERSION = 1


def _num(x):
    """JSON-safe number: infinities (unbounded grants) map to None."""
    if x is None:
        return None
    x = float(x)
    if math.isinf(x) or math.isnan(x):
        return None
    return x


class StepTracer:
    """Append-only structured event log on the engine's clock."""

    def __init__(self, enabled: bool = True, max_events: int = 200_000):
        self.enabled = enabled
        self.max_events = max_events
        self.events: list = []
        self.dropped = 0
        self._seq = 0
        #: bubble-monitor window state for the NEXT quantum event — a
        #: SpecInF runtime sets it right before ``EngineCore.step`` and the
        #: core folds it into the quantum record (then clears it, so a
        #: non-runtime step never carries a stale window).
        self.window_state: Optional[dict] = None

    # ------------------------------------------------------------------
    def _emit(self, ev: dict) -> None:
        if not self.enabled:
            return
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        ev["seq"] = self._seq
        self._seq += 1
        self.events.append(ev)

    def quantum(self, t0: float, t1: float, **args) -> None:
        self._emit({
            "type": "quantum", "t0": float(t0), "t1": float(t1),
            "args": args,
        })

    def span(self, name: str, track: str, t0: float, t1: float,
             **args) -> None:
        self._emit({
            "type": "span", "name": name, "track": track,
            "t0": float(t0), "t1": float(t1), "args": args,
        })

    def instant(self, name: str, t: float, track: str = "control",
                **args) -> None:
        self._emit({
            "type": "instant", "name": name, "t": float(t), "track": track,
            "args": args,
        })

    def transition(self, request_id: int, frm: Optional[str], to: str,
                   t: float, priority: Optional[str] = None) -> None:
        self._emit({
            "type": "transition", "request_id": int(request_id),
            "frm": frm, "to": to, "t": float(t), "priority": priority,
        })

    def restamp_arrival(self, request_id: int, t: float) -> None:
        """Rewrite a request's WAITING (submission) transition timestamp —
        the hook ``SpecInFRuntime`` uses when it restamps wall-clock
        arrivals onto the virtual epoch, so the trace and the request
        records stay on one timebase."""
        for ev in self.events:
            if (ev["type"] == "transition"
                    and ev["request_id"] == request_id
                    and ev["to"] == "waiting"):
                ev["t"] = float(t)

    # ------------------------------------------------------------------
    # Derived views
    # ------------------------------------------------------------------
    def attribution(self):
        """Per-request SLO attribution computed from this trace's
        transition events (``repro.obs.attribution.attribute``)."""
        from repro.obs.attribution import attribute

        return attribute(self.events)

    # ------------------------------------------------------------------
    # Export
    # ------------------------------------------------------------------
    def meta(self, **extra) -> dict:
        m = {
            "type": "meta", "version": TRACE_VERSION,
            "events": len(self.events), "dropped": self.dropped,
        }
        m.update(extra)
        return m

    def jsonl_lines(self, **meta):
        yield json.dumps(self.meta(**meta))
        for ev in self.events:
            yield json.dumps(ev)

    def write_jsonl(self, path: str, **meta) -> None:
        with open(path, "w") as f:
            for line in self.jsonl_lines(**meta):
                f.write(line + "\n")

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(chrome_trace(self.events), f)


def chrome_trace(events: list) -> dict:
    """Render structured events as a Chrome trace (catapult JSON): spans and
    quanta become complete ('X') events, instants/transitions become
    instant ('i') events, and each track becomes a named thread so Perfetto
    shows training, bubbles, the control plane, and every slot as parallel
    timelines.  Timestamps convert from engine-clock seconds to µs."""
    tids: dict = {}

    def tid(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids) + 1
        return tids[track]

    # stable track order: control first, then train, then slots
    tid("control")
    tid("train")
    out = []
    for ev in events:
        kind = ev["type"]
        if kind == "quantum":
            out.append({
                "ph": "X", "name": "quantum", "cat": "quantum",
                "ts": ev["t0"] * 1e6,
                "dur": max(ev["t1"] - ev["t0"], 0.0) * 1e6,
                "pid": 0, "tid": tid("control"), "args": ev["args"],
            })
        elif kind == "span":
            out.append({
                "ph": "X", "name": ev["name"], "cat": "span",
                "ts": ev["t0"] * 1e6,
                "dur": max(ev["t1"] - ev["t0"], 0.0) * 1e6,
                "pid": 0, "tid": tid(ev["track"]), "args": ev["args"],
            })
        elif kind == "instant":
            out.append({
                "ph": "i", "s": "t", "name": ev["name"], "cat": "instant",
                "ts": ev["t"] * 1e6, "pid": 0, "tid": tid(ev["track"]),
                "args": ev["args"],
            })
        elif kind == "transition":
            out.append({
                "ph": "i", "s": "t",
                "name": f"req{ev['request_id']}:{ev['to']}",
                "cat": "transition", "ts": ev["t"] * 1e6,
                "pid": 0, "tid": tid("control"),
                "args": {"request_id": ev["request_id"],
                         "from": ev["frm"], "priority": ev["priority"]},
            })
    meta = [{
        "ph": "M", "name": "process_name", "pid": 0,
        "args": {"name": "specinf-engine"},
    }]
    for track, t in tids.items():
        meta.append({
            "ph": "M", "name": "thread_name", "pid": 0, "tid": t,
            "args": {"name": track},
        })
        meta.append({
            "ph": "M", "name": "thread_sort_index", "pid": 0, "tid": t,
            "args": {"sort_index": t},
        })
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


class Observability:
    """The per-engine observability bundle: ONE metrics registry + ONE step
    tracer.  Constructed by ``InferenceEngine`` when the caller does not
    inject its own; the core, the SpecInF runtime, and the benches all
    share the engine's instance, which is what makes the registry the
    single source of truth."""

    def __init__(self, tracing: bool = True, max_events: int = 200_000):
        from repro.obs.metrics import MetricsRegistry

        self.metrics = MetricsRegistry()
        self.tracer = StepTracer(enabled=tracing, max_events=max_events)
