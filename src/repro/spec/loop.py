"""The fused speculative decode loop: k propose/verify/accept rounds
entirely on-device via ``lax.scan`` — the speculative analog of
``transformer.decode_loop`` with the same host discipline: the caller
fetches everything it needs with ONE device->host transfer per loop.

Round anatomy (all per-slot, ragged over the batch):

  1. draft proposes ``gamma`` tokens (+1 catch-up step, ``spec.draft``)
  2. target scores the ``gamma+1`` chunk in one fused pass
     (``transformer.decode_chunk`` -> chunk-verify kernel)
  3. acceptance keeps the longest admissible prefix (``spec.verify``)
  4. both caches rewind to ``index + accepted + 1``; recurrent state is
     selected from the captured per-step stack (``spec.rollback``)

Freeze masking mirrors ``decode_loop``: a slot is active while its budget
holds and its cache can still fit a whole chunk
(``index + gamma < max_seq``); frozen slots keep token, index, budget, and
recurrent state in place.  A frozen slot's KV region may still receive
(ignored) chunk writes — harmless under the stale-overwrite invariant, and
slots frozen at the sequence boundary are retired by the engine right after
the loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.spec.draft import draft_propose
from repro.spec.rollback import rollback_recurrent
from repro.spec.verify import greedy_accept, sampled_accept, simulated_accept


def spec_round(
    cfg: ModelConfig,
    draft_cfg: ModelConfig,
    params,
    draft_params,
    carry,
    *,
    gamma: int,
    mode: str,
    max_seq: int,
    sim_accept_p: float,
    compute_dtype,
    attn_impl: str,
):
    """One propose/verify/accept round.  carry = (tokens, cache,
    draft_cache, remaining, key); emits (out_tokens [B, gamma+1],
    n_out [B], accepted [B], proposed [B], bad [B]).

    ``bad`` is the per-slot NaN screen (DESIGN.md §9): True when the
    target's verify logits for an *active* slot contain a non-finite
    value — acceptance and emitted tokens for that slot are garbage and
    the engine must quarantine it.  The draft's proposals need no screen
    of their own: correctness flows from the verify pass alone, and a
    poisoned draft only surfaces as (screened) verify logits."""
    tokens, cache, dcache, rem, key = carry
    key, k_draft, k_acc = jax.random.split(key, 3)
    idx0 = cache["index"]
    active = (rem > 0) & (idx0 + gamma < max_seq)
    old_t = T.chunk_recurrent_states(cfg, cache["layers"])
    old_d = T.chunk_recurrent_states(draft_cfg, dcache["layers"])

    d_toks, d_probs, dcache, d_states = draft_propose(
        draft_cfg, draft_params, tokens, dcache, gamma=gamma,
        mode="sample" if mode == "sample" else "greedy", key=k_draft,
        compute_dtype=compute_dtype, attn_impl=attn_impl,
    )
    chunk = jnp.concatenate([tokens[:, None], d_toks], axis=1)  # [B, g+1]
    logits, cache, t_states = T.decode_chunk(
        cfg, params, chunk, cache, compute_dtype=compute_dtype,
        attn_impl=attn_impl,
    )
    bad = active & ~jnp.isfinite(logits).all(axis=(-2, -1))
    if mode == "greedy":
        a, nxt, out, a_match = greedy_accept(d_toks, logits, rem)
    elif mode == "simulated":
        a, nxt, out, a_match = simulated_accept(
            k_acc, sim_accept_p, d_toks, logits, rem
        )
    elif mode == "sample":
        a, nxt, out, a_match = sampled_accept(
            k_acc, d_toks, d_probs, logits, rem
        )
    else:
        raise ValueError(f"unknown speculative mode {mode!r}")

    n_out = jnp.where(active, a + 1, 0)
    new_idx = jnp.where(active, idx0 + a + 1, idx0)
    tokens = jnp.where(active, nxt, tokens)
    # dict(cache, ...) keeps keys beyond index/layers (the paged target
    # cache's block_tables) flowing through the scan carry
    cache = dict(
        cache,
        index=new_idx,
        layers=T.merge_recurrent_states(
            cfg, cache["layers"],
            rollback_recurrent(cfg, t_states, a, active, old_t),
        ),
    )
    dcache = dict(
        dcache,
        index=new_idx,
        layers=T.merge_recurrent_states(
            draft_cfg, dcache["layers"],
            rollback_recurrent(draft_cfg, d_states, a, active, old_d),
        ),
    )
    rem = rem - n_out
    out = jnp.where(active[:, None], out, 0)
    # acceptance stats use the unclamped run: a budget cut is not a draft
    # rejection, so it must not depress the gamma controller's EWMA
    accepted = jnp.where(active, a_match, 0)
    proposed = jnp.where(active, gamma, 0)
    return (
        (tokens, cache, dcache, rem, key),
        (out, n_out, accepted, proposed, bad),
    )


def spec_decode_loop(
    cfg: ModelConfig,
    draft_cfg: ModelConfig,
    params,
    draft_params,
    tokens: jax.Array,
    cache,
    draft_cache,
    remaining: jax.Array,
    key: jax.Array,
    *,
    k: int,
    gamma: int,
    mode: str = "greedy",
    max_seq: int,
    sim_accept_p: float = 0.9,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
):
    """Run ``k`` speculative rounds on-device.

    Returns ``(tokens, cache, draft_cache, remaining, key, out_tokens
    [k, B, gamma+1], n_out [k, B], accepted [k, B], proposed [k, B],
    bad [B])``; round j emitted ``n_out[j, i]`` verified tokens
    ``out_tokens[j, i, :n]`` for slot i, and ``bad[i]`` flags slot i's
    verify logits going non-finite in ANY round (the per-slot NaN screen
    — DESIGN.md §9).  Callers bucket ``k`` (``DECODE_K_BUCKETS``) and
    ``gamma`` (``GAMMA_BUCKETS``) so the set of compiled programs stays
    bounded."""

    def body(carry, _):
        return spec_round(
            cfg, draft_cfg, params, draft_params, carry, gamma=gamma,
            mode=mode, max_seq=max_seq, sim_accept_p=sim_accept_p,
            compute_dtype=compute_dtype, attn_impl=attn_impl,
        )

    carry = (tokens, cache, draft_cache, remaining, key)
    (tokens, cache, draft_cache, remaining, key), ys = jax.lax.scan(
        body, carry, None, length=k
    )
    out_tokens, n_out, accepted, proposed, bad = ys
    return (
        tokens, cache, draft_cache, remaining, key,
        out_tokens, n_out, accepted, proposed, bad.any(axis=0),
    )
