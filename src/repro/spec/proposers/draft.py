"""Draft-model proposer: the existing device-resident speculative path,
refactored behind the ``Proposer`` interface.

The draft model lives on the device and its proposals never touch the
host: the fused ``spec.loop.spec_decode_loop`` interleaves propose, verify,
accept, and rollback for ``k`` rounds per dispatch with a single
device->host transfer at the end.  Splitting that loop to route proposals
through ``propose()`` would forfeit its one-transfer discipline, so this
class deliberately returns ``None`` — the engine sees ``kind == "device"``
and drives the fused loop — while still giving the routing controller a
uniform handle: the same per-slot acceptance feedback and, crucially, the
same *cost identity*.  A draft-model round costs
``1 + (gamma + 1) * draft_cost_ratio`` quantum steps (target chunk + draft
microsteps) where a host proposer's round costs ~1; the router prices both
with ``round_cost`` and SpecInF grants are metered accordingly.
"""
from __future__ import annotations

from typing import Optional

from repro.spec.proposers.base import ProposeContext, Proposer, TokenTree


class DraftModelProposer(Proposer):
    """Handle for the fused draft-model loop (``spec.loop``)."""

    kind = "device"

    def __init__(self, *, draft_cost_ratio: float = 0.25,
                 name: str = "draft"):
        self.draft_cost_ratio = draft_cost_ratio
        self.name = name

    def propose(self, ctx: ProposeContext) -> Optional[TokenTree]:
        # Device-resident: proposals happen inside the fused loop.
        return None
