"""Pluggable speculation proposers (DESIGN.md §10).

A ``Proposer`` turns per-slot context into a packed candidate token tree;
``spec.tree`` verifies the tree in one fused pass; ``ProposerRouter``
picks the proposer per slot per quantum from acceptance EWMAs, priced in
quantum steps so SpecInF grants stay honest.

Implementations:
  * ``DraftModelProposer``  -- the device-resident draft model (the fused
    ``spec.loop`` path behind the interface)
  * ``NgramProposer``       -- prompt-lookup over the slot's own history,
    zero model cost
  * ``StaticSuffixProposer``-- corpus-indexed continuations for
    prefix-heavy offline traffic
"""
from repro.spec.proposers.base import Proposer, ProposeContext, TokenTree
from repro.spec.proposers.draft import DraftModelProposer
from repro.spec.proposers.ngram import NgramProposer
from repro.spec.proposers.router import ProposerRouter
from repro.spec.proposers.suffix import StaticSuffixProposer

__all__ = [
    "Proposer",
    "ProposeContext",
    "TokenTree",
    "DraftModelProposer",
    "NgramProposer",
    "StaticSuffixProposer",
    "ProposerRouter",
]
