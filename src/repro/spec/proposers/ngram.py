"""N-gram / prompt-lookup proposer: zero-model-cost candidates from the
slot's own context.

Prompt-heavy workloads (summarization, code editing, RAG) repeat long
spans of their own input; a draft *model* is overkill for them.  This
proposer matches the slot's most recent ``order`` tokens against its full
history — prompt plus everything accepted so far, which the engine already
keeps host-side for the radix prefix cache — and proposes the tokens that
followed the most recent earlier occurrence.  ``width > 1`` proposes up to
``width`` branches from distinct earlier occurrences (most recent first),
packed as sibling chains under the shared root.

Wholly deterministic: proposals are a pure function of the histories (the
property a unit test pins down).  When NO active slot has a match the
proposer returns ``None`` and the engine falls back to plain (non-spec)
decode for the quantum instead of paying a doomed verify pass.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.spec.proposers.base import ProposeContext, Proposer, TokenTree
from repro.spec.tree import branching_tree, linear_chain


def _find_continuations(hist, order: int, gamma: int, width: int):
    """All distinct ``gamma``-token continuations of the trailing
    ``order``-gram, most recent occurrence first.  Pure + deterministic."""
    n = len(hist)
    if n < order + 1:
        return []
    key = tuple(hist[n - order:])
    outs: list = []
    seen = set()
    # scan candidate match positions right-to-left, excluding the trailing
    # occurrence itself
    for start in range(n - order - 1, -1, -1):
        if tuple(hist[start:start + order]) != key:
            continue
        cont = list(hist[start + order:start + order + gamma])
        if not cont:
            continue
        while len(cont) < gamma:  # short tail: repeat the last token
            cont.append(cont[-1])
        t = tuple(cont)
        if t in seen:
            continue
        seen.add(t)
        outs.append(cont)
        if len(outs) >= width:
            break
    return outs


class NgramProposer(Proposer):
    """Prompt-lookup decoding over the slot's prompt + generated history."""

    kind = "host"

    def __init__(self, *, order: int = 3, name: str = "ngram"):
        assert order >= 1
        self.order = order
        self.name = name

    def propose(self, ctx: ProposeContext) -> Optional[TokenTree]:
        gamma, width = ctx.gamma, max(1, ctx.width)
        b = len(ctx.histories)
        n_tail = width * gamma
        tail = np.zeros((b, n_tail), np.int32)
        matched = np.zeros((b,), bool)
        for i, hist in enumerate(ctx.histories):
            if not ctx.active[i]:
                continue
            conts = _find_continuations(hist, self.order, gamma, width)
            if not conts:
                continue
            matched[i] = True
            for w, cont in enumerate(conts):
                tail[i, w * gamma:(w + 1) * gamma] = cont
            for w in range(len(conts), width):  # pad branches: repeat first
                tail[i, w * gamma:(w + 1) * gamma] = conts[0]
        if not matched.any():
            return None
        parents = (
            linear_chain(gamma) if width == 1 else branching_tree(width, gamma)
        )
        return TokenTree(parents=parents, tail=tail, matched=matched)
