"""Static-suffix proposer: precomputed continuations for prefix-heavy
offline traffic.

Offline batches in SpecInF's bubble-filling regime often share templated
structure — evaluation harnesses, classification prompts, bulk rewrites —
where whole suffixes repeat across requests.  This proposer is built once
from a reference corpus (token sequences seen before, e.g. completed
requests of the same job): it indexes every ``order``-gram to the tokens
that followed its FIRST corpus occurrence (first wins, so the table is
deterministic regardless of corpus iteration order), then proposes that
continuation whenever a slot's trailing tokens hit the table.

Unlike ``NgramProposer`` it never scans the slot's own history — lookup is
O(1) per slot per round — making it the cheapest possible proposer for
traffic its corpus covers, and useless outside it (the router learns which
is which from acceptance feedback).
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from repro.spec.proposers.base import ProposeContext, Proposer, TokenTree
from repro.spec.tree import linear_chain


class StaticSuffixProposer(Proposer):
    """Table-driven suffix completion from a reference corpus."""

    kind = "host"

    def __init__(
        self,
        corpus: Iterable[Sequence[int]],
        *,
        order: int = 2,
        max_continuation: int = 16,
        name: str = "suffix",
    ):
        assert order >= 1
        self.order = order
        self.name = name
        self._table: dict = {}
        for seq in corpus:
            seq = list(seq)
            for s in range(len(seq) - order):
                key = tuple(seq[s:s + order])
                if key in self._table:  # first occurrence wins
                    continue
                cont = seq[s + order:s + order + max_continuation]
                if cont:
                    self._table[key] = cont

    def propose(self, ctx: ProposeContext) -> Optional[TokenTree]:
        gamma = ctx.gamma
        b = len(ctx.histories)
        tail = np.zeros((b, gamma), np.int32)
        matched = np.zeros((b,), bool)
        for i, hist in enumerate(ctx.histories):
            if not ctx.active[i] or len(hist) < self.order:
                continue
            cont = self._table.get(tuple(hist[-self.order:]))
            if not cont:
                continue
            matched[i] = True
            row = list(cont[:gamma])
            while len(row) < gamma:
                row.append(row[-1])
            tail[i] = row
        if not matched.any():
            return None
        return TokenTree(
            parents=linear_chain(gamma), tail=tail, matched=matched
        )
