"""Adaptive proposer routing: pick the cheapest candidate source per slot
per quantum from acceptance feedback.

Different slots want different proposers — a summarization request feeds
the n-gram proposer perfectly while a cold chat turn needs the draft
model — and the right choice shifts over a request's lifetime.  The router
keeps a per-(slot, proposer) acceptance-rate EWMA (seeded optimistically so
every proposer gets tried before being written off) and each quantum ranks
proposers by *expected verified tokens per quantum step*:

    score = E[tokens/round](p_hat, gamma) / round_cost(proposer, gamma)

using the same geometric-series expectation as the gamma controller
(``spec.controller``).  The cost side is what makes routing GRANT-AWARE:
a draft-model round spends ``1 + (gamma + 1) * draft_cost_ratio`` steps of
a SpecInF bubble grant (target chunk + draft microsteps), while a
model-free host proposal spends ~1 (the verify chunk alone).  SpecInF's
policy layer reads ``round_cost`` for the routed choice when converting
granted steps into rounds, so Algorithm-1 grants are priced by what will
actually run.
"""
from __future__ import annotations

from typing import Optional, Sequence


class ProposerRouter:
    """Per-slot acceptance-EWMA routing over registered proposers."""

    def __init__(
        self,
        names: Sequence[str],
        *,
        device_names: Sequence[str] = ("draft",),
        ewma: float = 0.5,
        init_acceptance: float = 0.7,
        draft_cost_ratio: float = 0.25,
        host_round_cost: float = 1.0,
    ):
        assert names, "router needs at least one proposer"
        self.names = tuple(names)
        self.device_names = frozenset(device_names)
        self.ewma = ewma
        self.init_acceptance = init_acceptance
        self.draft_cost_ratio = draft_cost_ratio
        self.host_round_cost = host_round_cost
        self._acc: dict = {}  # (slot, name) -> EWMA acceptance rate
        self._last_pick: dict = {}  # slot -> name
        self.switches = 0  # slot-level routing changes (observability)

    # -- feedback -------------------------------------------------------
    def acceptance(self, slot: int, name: str) -> float:
        return self._acc.get((slot, name), self.init_acceptance)

    def observe(self, slot: int, name: str, accepted: int,
                proposed: int) -> None:
        """Fold one verified round's outcome into the (slot, name) EWMA.
        ``accepted`` is the unclamped run (budget cuts are not proposer
        rejections — same rule as the gamma controller)."""
        if proposed <= 0:
            return
        rate = min(accepted / proposed, 1.0)
        prev = self.acceptance(slot, name)
        self._acc[(slot, name)] = (
            self.ewma * rate + (1.0 - self.ewma) * prev
        )

    def reset_slot(self, slot: int) -> None:
        """Forget a slot's history (the engine calls this on retire/evict
        so a recycled slot starts optimistic again)."""
        for name in self.names:
            self._acc.pop((slot, name), None)
        self._last_pick.pop(slot, None)

    # -- pricing --------------------------------------------------------
    @staticmethod
    def expected_tokens_per_round(p: float, gamma: int) -> float:
        """Geometric-series expectation, same model as the gamma
        controller: sum_{i=0..gamma} p^i."""
        p = min(max(p, 0.0), 0.99)
        return (1.0 - p ** (gamma + 1)) / (1.0 - p)

    def round_cost(self, name: str, gamma: int) -> float:
        """Quantum steps one round spends: target chunk (1) plus, for the
        device-resident draft model, its gamma+1 microsteps at the
        profiled draft/target cost ratio.  Host proposals are model-free."""
        if name in self.device_names:
            return 1.0 + (gamma + 1) * self.draft_cost_ratio
        return self.host_round_cost

    def score(self, slot: int, name: str, gamma: int) -> float:
        p = self.acceptance(slot, name)
        return self.expected_tokens_per_round(p, gamma) / self.round_cost(
            name, gamma
        )

    # -- selection ------------------------------------------------------
    def pick(self, slot: int, gamma: int,
             available: Optional[Sequence[str]] = None) -> str:
        """Best-scoring proposer for the slot (ties break toward the
        registration order).  ``available`` restricts the choice set (e.g.
        host proposers are gated off for recurrent families)."""
        pool = [n for n in self.names
                if available is None or n in available]
        assert pool, "no proposer available to route"
        best = max(pool, key=lambda n: (self.score(slot, n, gamma),
                                        -pool.index(n)))
        if self._last_pick.get(slot) not in (None, best):
            self.switches += 1
        self._last_pick[slot] = best
        return best

    def pick_majority(self, slots: Sequence[int], gamma: int,
                      available: Optional[Sequence[str]] = None) -> str:
        """One proposer for a whole batch quantum: the highest summed score
        across the given slots.  The engine dispatches one fused program
        per quantum, so routing is per-slot in *state* but per-quantum in
        *choice*."""
        pool = [n for n in self.names
                if available is None or n in available]
        assert pool, "no proposer available to route"
        if not slots:
            return pool[0]
        totals = {
            n: sum(self.score(s, n, gamma) for s in slots) for n in pool
        }
        best = max(pool, key=lambda n: (totals[n], -pool.index(n)))
        for s in slots:
            if self._last_pick.get(s) not in (None, best):
                self.switches += 1
            self._last_pick[s] = best
        return best
