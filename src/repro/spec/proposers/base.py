"""The ``Proposer`` interface: pluggable candidate sources for speculative
verification.

A proposer turns per-slot context into a packed candidate token tree
(``spec.tree``) that the target model verifies in one fused pass.  Two
kinds exist:

  * ``host`` — the proposal is computed on the host from the slot's token
    history at zero model cost (n-gram lookup, static suffixes).  The
    engine drives these through ``tree_verify_round``: one dispatch and one
    device->host transfer per round, because the proposer must see the
    accepted tokens before proposing again.
  * ``device`` — the proposal is a draft *model* resident on the device;
    ``propose`` returns ``None`` and the engine runs the fused
    ``spec_decode_loop`` instead (k rounds per dispatch).  The proposer
    object still exists so the routing controller treats every candidate
    source uniformly — same acceptance feedback, same cost accounting.

``observe`` closes the loop: after each verified round the engine reports
(accepted, proposed) per slot, feeding both the proposer's own adaptation
(if any) and the router's per-slot acceptance EWMA.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenTree:
    """A packed candidate tree (see ``spec.tree`` for the layout).

    ``parents``: static topology including the root (``parents[0] == -1``,
    parents precede children); shared across the batch.  ``tail``: [B, N-1]
    int32 candidate tokens for nodes 1..N-1 (node 0 is the slot's current
    token, supplied by the engine).  ``matched``: [B] bool, True where the
    proposer found real evidence for the slot (False rows carry filler the
    verifier will reject — they still emit one target token per round)."""

    parents: tuple
    tail: np.ndarray
    matched: np.ndarray


@dataclasses.dataclass
class ProposeContext:
    """Per-quantum proposal input.

    ``histories``: one int list per slot — prompt + accepted tokens so far
    (the engine maintains these host-side; empty list = empty slot).
    ``active``: [B] bool slots that will decode this round.  ``gamma``:
    requested candidate depth.  ``width``: requested branch count (1 =
    linear chain)."""

    histories: Sequence[Sequence[int]]
    active: np.ndarray
    gamma: int
    width: int = 1


class Proposer:
    """Base class; subclasses set ``name``/``kind`` and implement
    ``propose``."""

    name: str = "base"
    kind: str = "host"  # "host" | "device"

    def propose(self, ctx: ProposeContext) -> Optional[TokenTree]:
        """Return a candidate tree, or ``None`` when this proposer has
        nothing to offer this round (no slot matched — the engine falls
        back to plain decode) or is device-resident."""
        raise NotImplementedError

    def observe(self, slot: int, accepted: int, proposed: int) -> None:
        """Per-slot acceptance feedback after verification (default: no
        per-proposer state; the router keeps the EWMA)."""
