"""Adaptive draft-length (gamma) control.

Algorithm 1 decides *when* inference may run and how many tokens a bubble
grant is worth; this controller decides *how speculative* each granted
round should be.  Two signals:

* **Phase** gates the risk appetite.  A conservative-phase grant means
  training activity is imminent, so the round must stay short (smallest
  gamma — the quantum must stay preemptible).  Incremental allows mid
  buckets; stable opens the full range.
* **Observed acceptance** (EWMA over verify outcomes) picks the bucket that
  maximizes expected verified tokens per unit cost: a round at draft length
  g yields ``E[tokens] = (1 - p^(g+1)) / (1 - p)`` for acceptance rate p and
  costs ``1 + (g+1) * draft_cost_ratio`` target-step equivalents (one chunk
  verify + g+1 cheap draft steps).  Low acceptance collapses gamma toward 1
  (drafting is wasted work); high acceptance grows it.

Gamma is drawn from ``GAMMA_BUCKETS`` so the engine compiles a bounded set
of fused loop programs, exactly like ``DECODE_K_BUCKETS`` (DESIGN.md §2).
"""
from __future__ import annotations

#: Draft-length compile buckets (chunk = gamma + 1 target positions).
GAMMA_BUCKETS = (1, 2, 4)


class AdaptiveGammaController:
    def __init__(
        self,
        buckets: tuple[int, ...] = GAMMA_BUCKETS,
        *,
        ewma: float = 0.5,
        draft_cost_ratio: float = 0.25,
        init_acceptance: float = 0.7,
    ):
        assert buckets == tuple(sorted(buckets)) and buckets[0] >= 1
        assert 0.0 < ewma <= 1.0
        self.buckets = tuple(buckets)
        self.ewma = ewma
        self.draft_cost_ratio = draft_cost_ratio
        self.acceptance = init_acceptance

    # ------------------------------------------------------------------
    def observe(self, accepted: int, proposed: int) -> None:
        """Fold one loop's verify outcome into the acceptance EWMA."""
        if proposed > 0:
            rate = accepted / proposed
            self.acceptance += self.ewma * (rate - self.acceptance)

    # ------------------------------------------------------------------
    def expected_tokens_per_round(self, gamma: int) -> float:
        """E[verified tokens] for one round at the current acceptance."""
        p = min(max(self.acceptance, 0.0), 0.99)
        if p == 0.0:
            return 1.0
        return (1.0 - p ** (gamma + 1)) / (1.0 - p)

    def round_cost_steps(self, gamma: int) -> float:
        """Round cost in target-step equivalents (chunk verify + drafts)."""
        return 1.0 + (gamma + 1) * self.draft_cost_ratio

    # ------------------------------------------------------------------
    def gamma_for(self, phase) -> int:
        """Draft length for the next fused loop: phase-gated efficiency
        argmax over the buckets.  ``phase`` is a ``core.scheduler.Phase``
        (accepted duck-typed via ``.value`` to keep this module free of a
        core import — ``core.filling`` imports us)."""
        name = getattr(phase, "value", phase)
        if name == "conservative":
            allowed = self.buckets[:1]
        elif name == "incremental":
            allowed = self.buckets[: max(1, (len(self.buckets) + 1) // 2)]
        else:
            allowed = self.buckets
        return max(
            allowed,
            key=lambda g: self.expected_tokens_per_round(g)
            / self.round_cost_steps(g),
        )
