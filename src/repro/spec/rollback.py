"""Per-slot cache rewind past rejected draft tokens.

Two rollback regimes (DESIGN.md §4):

* **KV caches** need only the index rewind the loop already performs: a
  rejected position's K/V entry sits at ``pos >= index`` after the rewind
  and is rewritten before it is ever attended to — the same stale-overwrite
  invariant bucket-padded prefill relies on.  No data movement.
* **Recurrent state** (SSM ``h``/conv tails, Mamba2 state) is *consumed* by
  every step, so the chunk pass captures the state after each step (leading
  step axis) and acceptance selects, per slot, the state after exactly
  ``accepted + 1`` consumed tokens.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def select_step_state(
    stacked: jax.Array, sel: jax.Array, batch_axis: int
) -> jax.Array:
    """Per-slot gather along the leading step axis.

    stacked: [steps, ...] with the batch dimension at ``batch_axis``
    (counting the step axis); sel: [B] int32 step index per slot.  Returns
    the selected state with the step axis removed (batch lands at
    ``batch_axis - 1``)."""
    lb = jnp.moveaxis(stacked, batch_axis, 0)  # [B, steps, ...]
    out = jax.vmap(lambda leaf, s: leaf[s])(lb, sel)  # [B, ...]
    return jnp.moveaxis(out, 0, batch_axis - 1)


def rollback_recurrent(
    cfg: ModelConfig,
    step_states: Optional[dict],
    sel: jax.Array,
    active: jax.Array,
    old_states: Optional[dict],
) -> Optional[dict]:
    """Select each active slot's post-acceptance recurrent state; frozen
    slots keep their pre-round state untouched.

    step_states: per-step stacked recurrent pytree from ``decode_chunk`` /
    ``draft_propose`` (``None`` for pure-KV families -> returns
    ``old_states``, i.e. nothing to do); sel: [B] accepted counts (state
    after ``sel + 1`` consumed tokens is at step index ``sel``); active: [B]
    bool round-participation mask; old_states: the pre-round recurrent
    pytree used for frozen slots."""
    if step_states is None:
        return old_states
    ba = T.recurrent_state_batch_axis(cfg) + 1  # +1 for the step axis

    def pick(stacked, old):
        picked = select_step_state(stacked, sel, ba)
        shape = [1] * picked.ndim
        shape[ba - 1] = picked.shape[ba - 1]
        return jnp.where(active.reshape(shape), picked, old)

    return jax.tree.map(pick, step_states, old_states)
