"""Acceptance rules for the target chunk-verify pass.

Each rule consumes the target logits over the ``T = gamma + 1`` chunk
positions (current token + gamma drafts) and returns, per slot:

  * ``a``       -- accepted draft count, already clamped so the round emits
                   at most ``remaining`` tokens (``a + 1 <= remaining``)
  * ``nxt``     -- the next current token (target-sourced: the correction at
                   the first rejection, or the bonus when all drafts pass)
  * ``out``     -- the emitted token row [B, gamma+1]; entries past ``a``
                   are 0 and the caller reads only ``a + 1`` of them
  * ``a_match`` -- the *unclamped* accepted run, the draft-quality signal:
                   acceptance-rate stats use this so a budget cut is never
                   misread as a draft rejection (which would bias the gamma
                   controller toward short drafts on short-request loads)

Rules:
  * ``greedy_accept``    -- draft token j accepted iff it equals the target
    argmax at chunk position j.  Emitted tokens are then *exactly* the plain
    greedy chain (the equivalence the property test pins down).
  * ``sampled_accept``   -- the standard speculative-sampling ratio test
    ``u < p_target/p_draft`` with residual resampling on rejection; exactly
    the target distribution in expectation, seeded for reproducibility.
  * ``simulated_accept`` -- benchmark-only: the match outcome is drawn from
    a Bernoulli(p) stream instead of comparing tokens, so CPU CI can measure
    the speculative loop's *cost profile* at a chosen acceptance rate
    without an actually-aligned draft model.  Token content is unfaithful;
    timing, rollback, and accounting are the real code paths.

The budget clamp preserves stream fidelity: when ``remaining`` truncates an
accepted run, the final emitted token is the already-accepted draft token at
the cut (greedy: identical to the target argmax there; sampled: the token
the ratio test already admitted), never a fresh rejection sample.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _leading_run(match: jax.Array) -> jax.Array:
    """[B, g] bool -> [B] int32 length of the leading all-True run."""
    return jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)


def _emit(draft_tokens: jax.Array, a: jax.Array, nxt: jax.Array) -> jax.Array:
    """Row [B, g+1]: accepted drafts then the target-sourced next token."""
    g = draft_tokens.shape[1]
    jpos = jnp.arange(g + 1)[None, :]
    d_pad = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    return jnp.where(
        jpos < a[:, None], d_pad,
        jnp.where(jpos == a[:, None], nxt[:, None], 0),
    )


def _clamp(a_match: jax.Array, remaining: jax.Array, g: int) -> jax.Array:
    return jnp.clip(jnp.minimum(a_match, remaining - 1), 0, g)


def greedy_accept(
    draft_tokens: jax.Array,  # [B, g] int32
    target_logits: jax.Array,  # [B, g+1, V]
    remaining: jax.Array,  # [B] int32 token budgets
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    g = draft_tokens.shape[1]
    tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # [B, g+1]
    a_match = _leading_run(draft_tokens == tgt[:, :g])
    a = _clamp(a_match, remaining, g)
    # tgt[a] is correct for every exit: at a rejection it is the correction,
    # when all drafts pass it is the bonus token, and at a budget cut it
    # equals the accepted draft token (which matched the argmax).
    nxt = jnp.take_along_axis(tgt, a[:, None], axis=1)[:, 0]
    return a, nxt, _emit(draft_tokens, a, nxt), a_match


def simulated_accept(
    key: jax.Array,
    accept_p: float,
    draft_tokens: jax.Array,
    target_logits: jax.Array,
    remaining: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    g = draft_tokens.shape[1]
    match = jax.random.uniform(key, draft_tokens.shape) < accept_p
    tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)
    a_match = _leading_run(match)
    a = _clamp(a_match, remaining, g)
    nxt = jnp.take_along_axis(tgt, a[:, None], axis=1)[:, 0]
    return a, nxt, _emit(draft_tokens, a, nxt), a_match


def sampled_accept(
    key: jax.Array,
    draft_tokens: jax.Array,  # [B, g] int32
    draft_probs: jax.Array,  # [B, g, V] full draft distributions
    target_logits: jax.Array,  # [B, g+1, V]
    remaining: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    b, g = draft_tokens.shape
    p_t = jax.nn.softmax(target_logits.astype(jnp.float32), axis=-1)
    sel = draft_tokens[..., None]
    p_t_d = jnp.take_along_axis(p_t[:, :g], sel, axis=-1)[..., 0]  # [B, g]
    p_d_d = jnp.take_along_axis(draft_probs, sel, axis=-1)[..., 0]
    k_u, k_res = jax.random.split(key)
    u = jax.random.uniform(k_u, (b, g))
    # accept iff u < p_t/p_d, written multiply-through so p_d == 0 rejects
    a_match = _leading_run(u * p_d_d < p_t_d)
    a = _clamp(a_match, remaining, g)
    # Residual distribution at the cut position a: max(p_t - p_d, 0)
    # renormalized.  When a == g (all accepted) the padded draft row is zero,
    # so the residual degenerates to p_t[:, g] — the plain bonus sample.
    p_t_a = jnp.take_along_axis(p_t, a[:, None, None], axis=1)[:, 0]
    p_d_pad = jnp.pad(draft_probs, ((0, 0), (0, 1), (0, 0)))
    p_d_a = jnp.take_along_axis(p_d_pad, a[:, None, None], axis=1)[:, 0]
    res = jnp.maximum(p_t_a - p_d_a, 0.0)
    res_sum = res.sum(axis=-1, keepdims=True)
    dist = jnp.where(res_sum > 0, res / jnp.maximum(res_sum, 1e-30), p_t_a)
    nxt_sampled = jax.random.categorical(
        k_res, jnp.log(dist + 1e-38), axis=-1
    ).astype(jnp.int32)
    # Budget cut: position a was *accepted*, emit that draft token as-is.
    d_pad = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    d_at_a = jnp.take_along_axis(d_pad, a[:, None], axis=1)[:, 0]
    nxt = jnp.where(a < a_match, d_at_a, nxt_sampled)
    return a, nxt, _emit(draft_tokens, a, nxt), a_match
