"""Speculative decoding subsystem: draft-propose / target-verify.

SpecInF's namesake filling becomes *speculative* end to end: a cheap draft
model proposes ``gamma`` tokens per slot, the target model scores all
``gamma + 1`` chunk positions in ONE fused chunk-verify pass
(``kernels/verify_attention.py`` on the attention hot path), and acceptance
logic keeps the longest target-consistent prefix, rolling each slot's cache
index (and SSM/conv state) back past rejected tokens.  Every accepted round
turns one schedulable quantum into up to ``gamma + 1`` verified tokens
without lengthening the quantum itself — more tokens per bubble grant
(DESIGN.md §4).

Modules:
  * ``draft``      -- draft-model proposer (greedy / seeded-sampling)
  * ``verify``     -- acceptance rules: greedy, sampled (residual), simulated
  * ``rollback``   -- per-slot cache/state rewind past rejected tokens
  * ``loop``       -- the fused k-round ``spec_decode_loop`` (lax.scan)
  * ``controller`` -- adaptive gamma from Algorithm-1 phase + acceptance
  * ``tree``       -- packed-tree verification: ancestor-mask kernel round,
                      root-to-leaf acceptance, KV path compaction
  * ``proposers``  -- pluggable candidate sources (draft model / n-gram /
                      static suffix) + the acceptance-EWMA router
"""
from repro.spec.controller import GAMMA_BUCKETS, AdaptiveGammaController
from repro.spec.draft import draft_propose
from repro.spec.loop import spec_decode_loop
from repro.spec.tree import (
    branching_tree,
    linear_chain,
    tree_greedy_accept,
    tree_verify_round,
)
from repro.spec.verify import greedy_accept, sampled_accept, simulated_accept

__all__ = [
    "GAMMA_BUCKETS",
    "AdaptiveGammaController",
    "draft_propose",
    "spec_decode_loop",
    "greedy_accept",
    "sampled_accept",
    "simulated_accept",
    "branching_tree",
    "linear_chain",
    "tree_greedy_accept",
    "tree_verify_round",
]
