"""Tree verification: score a packed candidate *tree* in one fused pass and
accept the longest target-consistent root-to-leaf path.

The linear speculative round (``spec.loop``) verifies one draft chain per
slot.  Host-side proposers (``spec.proposers``) can cheaply produce several
candidate branches — e.g. two n-gram continuations — and a single
chunk-verify pass can score all of them at once if the intra-chunk causal
triangle becomes an ancestor mask (``kernels/tree_verify_attention.py``).

Packed-tree layout (the wire format every proposer emits):

  * ``parents`` — a static tuple of length N; ``parents[0] == -1`` (node 0
    is the ROOT: the slot's current, already-committed token) and
    ``parents[j] < j`` (topological order), so any root-to-leaf path visits
    strictly increasing node indices.  The topology is shared across the
    batch per dispatch (it is compile-time static, like gamma); token
    *content* is per-slot.
  * node j's K/V occupies cache position ``index + j`` — the slot a linear
    chunk would use — while its RoPE position is ``index + depth(j)`` so
    sibling branches rotate identically.
  * ``anc[j]`` — int32 bitmask of j's ancestors including j itself; bit i
    set means node i is visible from node j.  N <= 31.

Acceptance (greedy): walk from the root; at each step the child whose token
equals the target argmax at the current node extends the path (first child
wins on duplicate sibling tokens).  Emitted tokens are the target argmaxes
along the accepted path plus the bonus/correction at the path's end —
byte-identical to plain greedy decode, and to ``verify.greedy_accept`` when
the tree is a single chain.  After acceptance the accepted path's K/V is
COMPACTED to contiguous positions ``index .. index + a`` (gather-then-
scatter; sources always sit at-or-after their destinations, and rejected
siblings beyond the new index are dead under the stale-overwrite
invariant).

Attention families only: tree verification needs parallel position scoring,
which the recurrent families' sequential state rules out — the engine keeps
the draft-model chain path for those.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

#: int32 ancestor bitmasks bound the packed tree size.
MAX_TREE_NODES = 31


# ---------------------------------------------------------------------------
# Static topology helpers (pure Python over the parents tuple)
# ---------------------------------------------------------------------------


def validate_parents(parents: tuple) -> None:
    n = len(parents)
    if n < 1 or n > MAX_TREE_NODES:
        raise ValueError(f"tree must have 1..{MAX_TREE_NODES} nodes, got {n}")
    if parents[0] != -1:
        raise ValueError("node 0 must be the root (parents[0] == -1)")
    for j, p in enumerate(parents[1:], start=1):
        if not 0 <= p < j:
            raise ValueError(
                f"parents[{j}] = {p}: parents must precede children"
            )


def linear_chain(gamma: int) -> tuple:
    """The chain topology: root + gamma nodes, each the previous one's
    child.  Tree verification over this topology is bit-identical to the
    linear chunk-verify path."""
    return (-1,) + tuple(range(gamma))


def branching_tree(width: int, depth: int) -> tuple:
    """``width`` independent chains of ``depth`` nodes sharing the root —
    the packed layout for multi-candidate n-gram continuations."""
    parents = [-1]
    for _ in range(width):
        prev = 0
        for _ in range(depth):
            parents.append(prev)
            prev = len(parents) - 1
    return tuple(parents)


def tree_depths(parents: tuple) -> np.ndarray:
    """[N] int32 node depths (root = 0)."""
    validate_parents(parents)
    d = np.zeros(len(parents), np.int32)
    for j, p in enumerate(parents[1:], start=1):
        d[j] = d[p] + 1
    return d


def tree_ancestor_masks(parents: tuple) -> np.ndarray:
    """[N] int32 ancestor bitmasks (self bit set).  A linear chain yields
    cumulative masks ``0b1, 0b11, 0b111, ...`` — the causal triangle."""
    validate_parents(parents)
    anc = np.zeros(len(parents), np.int32)
    anc[0] = 1
    for j, p in enumerate(parents[1:], start=1):
        anc[j] = anc[p] | (1 << j)
    return anc


def tree_max_depth(parents: tuple) -> int:
    return int(tree_depths(parents).max())


# ---------------------------------------------------------------------------
# Acceptance
# ---------------------------------------------------------------------------


def tree_greedy_accept(
    parents: tuple,
    tree_tokens: jax.Array,  # [B, N] int32; node 0 = current token
    target_logits: jax.Array,  # [B, N, V]
    remaining: jax.Array,  # [B] int32 token budgets
    *,
    match: jax.Array | None = None,  # override: [B, N] bool (simulated mode)
):
    """Greedy root-to-leaf acceptance over a packed tree.

    Returns ``(a, nxt, out, a_match, path_idx)``: ``a`` the accepted
    candidate count (clamped to the budget, ``a + 1 <= remaining``),
    ``nxt`` the next current token, ``out`` [B, D+1] the emitted row
    (D = max tree depth; entries past ``a`` are 0), ``a_match`` the
    unclamped accepted run (the proposer-quality signal), and ``path_idx``
    [B, N] the node index of the accepted path at each depth (identity
    past the path — the KV compaction map)."""
    b, n = tree_tokens.shape
    depths = tree_depths(parents)
    d_max = int(depths.max())
    tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # [B, N]
    if match is None:
        # candidate j extends the path iff its token equals the target
        # argmax at its parent
        par = jnp.asarray([max(p, 0) for p in parents], jnp.int32)
        tgt_par = jnp.take_along_axis(tgt, jnp.broadcast_to(par, (b, n)), 1)
        match = tree_tokens == tgt_par
    # Walk in node order (parents precede children): a node is on the path
    # iff its parent is, its token matches, and no earlier sibling already
    # claimed the parent (first child wins on duplicates).
    on = jnp.zeros((b, n), bool).at[:, 0].set(True)
    claimed = jnp.zeros((b, n), bool)
    for j in range(1, n):
        p = parents[j]
        ok = on[:, p] & match[:, j] & ~claimed[:, p]
        on = on.at[:, j].set(ok)
        claimed = claimed.at[:, p].set(claimed[:, p] | ok)
    a_match = on.sum(axis=1).astype(jnp.int32) - 1  # candidates on the path
    a = jnp.clip(jnp.minimum(a_match, remaining - 1), 0, d_max)
    # path_idx[b, d] = index of the path node at depth d (0 past the leaf):
    # one-hot over depths contracted against the on-path indicator.
    depth_sel = (jnp.asarray(depths)[None, :] == jnp.arange(n)[:, None])
    node_ids = jnp.arange(n, dtype=jnp.int32)[None, :]
    path_at_depth = jnp.einsum(
        "bn,dn->bd", (on * node_ids).astype(jnp.int32), depth_sel.astype(jnp.int32)
    )  # [B, N] (depth axis padded to N)
    # Emitted row: target argmaxes along the path — out[j] = tgt[path[j]]
    # for j <= a (at j == a this is the bonus/correction), 0 beyond.
    jpos = jnp.arange(d_max + 1)[None, :]
    gather = jnp.take_along_axis(tgt, path_at_depth[:, : d_max + 1], axis=1)
    out = jnp.where(jpos <= a[:, None], gather, 0)
    nxt = jnp.take_along_axis(gather, a[:, None], axis=1)[:, 0]
    # KV compaction map: path node at each depth while on the path,
    # identity beyond (those slots are stale either way).
    node_pos = jnp.arange(n, dtype=jnp.int32)[None, :]
    path_idx = jnp.where(node_pos <= a[:, None], path_at_depth, node_pos)
    return a, nxt, out, a_match, path_idx


# ---------------------------------------------------------------------------
# KV path compaction
# ---------------------------------------------------------------------------


def _compact_dense(kc: jax.Array, idx0: jax.Array, comp: jax.Array):
    """Gather the accepted path's rows to contiguous positions.

    kc: [B, S, kvH, hd]; idx0: [B]; comp: [B, N] source node index for each
    destination slot d (``comp[b, d] >= d``, so the gather completes before
    any destination it reads from is overwritten)."""
    s = kc.shape[1]
    src = jnp.minimum(idx0[:, None] + comp, s - 1)  # [B, N]
    vals = jnp.take_along_axis(kc, src[:, :, None, None], axis=1)
    upd = jax.vmap(
        lambda c, v, i: jax.lax.dynamic_update_slice_in_dim(c, v, i, axis=0)
    )
    return upd(kc, vals.astype(kc.dtype), idx0)


def _compact_paged(pool, block_tables, idx0, comp):
    """Paged analog of ``_compact_dense``: gather through the block table,
    scatter back at node-index positions (``layers.paged_kv_write``)."""
    from repro.models import layers as L

    n = comp.shape[1]
    page = pool.shape[1]
    w = block_tables.shape[1]
    src = idx0[:, None] + comp  # [B, N] logical positions
    cols = jnp.minimum(src // page, w - 1)
    pages = jnp.take_along_axis(block_tables, cols, axis=1)  # [B, N]
    vals = pool[pages, src % page]  # [B, N, kvH, hd]
    dst = idx0[:, None] + jnp.arange(n)[None, :]
    return L.paged_kv_write(pool, vals, block_tables, dst)


def compact_accepted_path(cache, comp: jax.Array):
    """Rewrite every layer's chunk-region K/V so the accepted path is
    contiguous at ``index .. index + a`` (cache index not yet advanced).
    ``comp`` [B, N] maps destination slot -> source node; inactive slots
    pass the identity map (a value-preserving rewrite)."""
    idx0 = cache["index"]
    bt = cache.get("block_tables")
    k, v = cache["layers"]["k"], cache["layers"]["v"]
    if bt is None:
        fn = jax.vmap(lambda c: _compact_dense(c, idx0, comp))
    else:
        fn = jax.vmap(lambda c: _compact_paged(c, bt, idx0, comp))
    return dict(cache, layers={"k": fn(k), "v": fn(v)})


# ---------------------------------------------------------------------------
# The host-proposed tree-verify round
# ---------------------------------------------------------------------------


def tree_verify_round(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,  # [B] current tokens (the tree roots)
    cache,
    tail_tokens: jax.Array,  # [B, N-1] proposed candidate tokens
    remaining: jax.Array,  # [B] int32 budgets
    key: jax.Array,
    *,
    parents: tuple,
    mode: str = "greedy",
    max_seq: int,
    sim_accept_p: float = 0.9,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
):
    """ONE propose-free verify/accept round over a packed candidate tree.

    The candidates come from a host-side proposer (n-gram / static-suffix)
    at zero model cost, so the round is: embed the N tree nodes, ONE fused
    tree-verify pass, accept the longest root-to-leaf path, compact its
    K/V, rewind the index.  One dispatch and one device->host transfer per
    round — the host must see the accepted tokens before it can propose the
    next tree.

    Returns ``(tokens, cache, remaining, key, out [B, D+1], n_out [B],
    accepted [B], proposed [B], bad [B])`` with the same per-slot freeze
    semantics and NaN screen as ``spec.loop.spec_round``."""
    n = len(parents)
    validate_parents(parents)
    depths = jnp.asarray(tree_depths(parents))
    anc_row = jnp.asarray(tree_ancestor_masks(parents))
    b = tokens.shape[0]
    idx0 = cache["index"]
    active = (remaining > 0) & (idx0 + (n - 1) < max_seq)
    tree_tokens = jnp.concatenate([tokens[:, None], tail_tokens], axis=1)
    logits, cache, _ = T.decode_chunk(
        cfg, params, tree_tokens, cache, compute_dtype=compute_dtype,
        attn_impl=attn_impl, anc=jnp.broadcast_to(anc_row, (b, n)),
        depths=depths,
    )
    bad = active & ~jnp.isfinite(logits).all(axis=(-2, -1))
    if mode == "greedy":
        a, nxt, out, a_match, path_idx = tree_greedy_accept(
            parents, tree_tokens, logits, remaining
        )
    elif mode == "simulated":
        # benchmark-only (see verify.simulated_accept): path-extension
        # outcomes are Bernoulli draws, the cost profile is the real path
        key, k_acc = jax.random.split(key)
        match = jax.random.uniform(key=k_acc, shape=(b, n)) < sim_accept_p
        a, nxt, out, a_match, path_idx = tree_greedy_accept(
            parents, tree_tokens, logits, remaining, match=match
        )
    else:
        raise ValueError(f"unknown tree verification mode {mode!r}")

    # decode_chunk advanced index by N; rebase before compaction + rewind
    cache = dict(cache, index=idx0)
    comp = jnp.where(active[:, None], path_idx, jnp.arange(n)[None, :])
    cache = compact_accepted_path(cache, comp)
    n_out = jnp.where(active, a + 1, 0)
    new_idx = jnp.where(active, idx0 + a + 1, idx0)
    tokens = jnp.where(active, nxt, tokens)
    cache = dict(cache, index=new_idx)
    remaining = remaining - n_out
    out = jnp.where(active[:, None], out, 0)
    accepted = jnp.where(active, a_match, 0)
    proposed = jnp.where(active, n - 1, 0)
    return (
        tokens, cache, remaining, key, out, n_out, accepted, proposed, bad
    )
