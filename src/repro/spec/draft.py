"""Draft-model proposer: ``gamma`` speculative tokens per slot.

The draft runs ``gamma + 1`` single-token decode steps inside one
``lax.scan``: steps ``0..gamma-1`` produce the draft tokens, and the final
*catch-up* step consumes the last draft token so that a fully-accepted chunk
leaves the draft cache one-token-aligned with the target (both rewind to
``index + accepted + 1`` — see ``spec.loop``).  The draft is cheap by
construction (``configs.base.draft_config``), so the extra step costs far
less than the host round-trip it avoids.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def draft_propose(
    cfg: ModelConfig,
    params,
    token: jax.Array,
    cache,
    *,
    gamma: int,
    mode: str = "greedy",
    key: Optional[jax.Array] = None,
    compute_dtype=jnp.bfloat16,
    attn_impl: str = "auto",
) -> tuple[jax.Array, Optional[jax.Array], dict, Optional[dict]]:
    """Propose ``gamma`` draft tokens per slot from ``token`` [B] int32.

    ``mode``:
      * "greedy" -- argmax chain (deterministic; used for greedy and
                    simulated-acceptance speculative decoding)
      * "sample" -- seeded categorical sampling; returns the full per-step
                    draft distributions for the residual acceptance test

    Returns ``(draft_tokens [B, gamma], draft_probs [B, gamma, V] | None,
    cache, step_states)``.  ``step_states`` stacks the recurrent per-layer
    state after each of the ``gamma + 1`` steps (leading step axis) for
    SSM/conv rollback; ``None`` for pure-KV drafts, whose rollback is an
    index rewind.  The cache index advances by ``gamma + 1`` — callers
    overwrite it with the post-acceptance index.
    """
    assert mode in ("greedy", "sample"), mode
    if mode == "sample":
        assert key is not None, "seeded-sampling draft needs a PRNG key"
        keys = jax.random.split(key, gamma + 1)
    else:
        keys = jnp.zeros((gamma + 1, 2), jnp.uint32)

    def step(carry, key_t):
        tok, c = carry
        logits, c = T.decode_step(
            cfg, params, tok, c, compute_dtype=compute_dtype,
            attn_impl=attn_impl,
        )
        logits32 = logits.astype(jnp.float32)
        if mode == "sample":
            nxt = jax.random.categorical(key_t, logits32, axis=-1).astype(
                jnp.int32
            )
            probs = jax.nn.softmax(logits32, axis=-1)
        else:
            nxt = jnp.argmax(logits32, axis=-1).astype(jnp.int32)
            probs = None
        states = T.chunk_recurrent_states(cfg, c["layers"])
        return (nxt, c), (nxt, probs, states)

    (_, cache), (toks, probs, states) = jax.lax.scan(
        step, (token, cache), keys
    )
    draft_tokens = toks[:gamma].T  # [B, gamma]; the catch-up token is dropped
    draft_probs = None if probs is None else probs[:gamma].transpose(1, 0, 2)
    return draft_tokens, draft_probs, cache, states
