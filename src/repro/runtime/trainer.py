"""Fault-tolerant training driver.

Production-loop features (DESIGN.md §3 runtime):
  * checkpoint/restart  -- atomic async checkpoints every N steps; on step
    failure the loop restores the latest complete checkpoint (params, opt
    state, data-stream position) and continues
  * straggler mitigation -- per-step EMA timing; a straggling step (or an
    external straggler signal) triggers SpecInF *filling backoff*: the
    collocated-inference token ceiling is scaled down so recovery compute
    isn't contended (the paper's training-first guarantee, inverted into
    the control plane)
  * elastic re-mesh      -- ``remesh()`` rebuilds the jitted step on a new
    mesh and re-shards the live state onto it (grow/shrink events)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import SyntheticDataset
from repro.runtime.step import make_train_step


@dataclasses.dataclass
class TrainerReport:
    steps: int = 0
    losses: list = dataclasses.field(default_factory=list)
    step_times_s: list = dataclasses.field(default_factory=list)
    restores: int = 0
    straggler_events: int = 0
    checkpoints: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        mesh,
        *,
        seq_len: int,
        global_batch: int,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 50,
        straggler_factor: float = 3.0,
        on_straggler: Optional[Callable[[], None]] = None,
        host_index: int = 0,
        host_count: int = 1,
    ):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.seq_len, self.global_batch = seq_len, global_batch
        self.artifacts = make_train_step(cfg, tcfg, mesh)
        self.step_fn = self.artifacts.jitted(donate=False)
        self.dataset = SyntheticDataset(
            cfg=cfg, seq_len=seq_len, global_batch=global_batch,
            host_index=host_index, host_count=host_count, seed=tcfg.seed,
        )
        self.state = self.artifacts.init_state(jax.random.PRNGKey(tcfg.seed))
        self.step_no = 0
        self.ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_every = checkpoint_every
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self._ema: Optional[float] = None
        self.report = TrainerReport()
        # failure-injection hook for tests: callable(step_no) -> bool
        self.fail_hook: Optional[Callable[[int], bool]] = None

    # ------------------------------------------------------------------
    def _snapshot(self) -> dict:
        return {"state": self.state, "data_step": np.int64(self.dataset._step)}

    def _maybe_checkpoint(self) -> None:
        if self.ckpt and self.step_no % self.checkpoint_every == 0:
            self.ckpt.save(self.step_no, self._snapshot(), blocking=False)
            self.report.checkpoints += 1

    def restore_latest(self) -> bool:
        if not self.ckpt or self.ckpt.latest_step() is None:
            return False
        template = self._snapshot()
        restored, step = self.ckpt.restore(template)
        self.state = restored["state"]
        self.dataset._step = int(restored["data_step"])
        self.step_no = step
        self.report.restores += 1
        return True

    # ------------------------------------------------------------------
    def _batch(self):
        b = self.dataset.next_batch()
        shardings = self.artifacts.batch_shardings()
        return {
            k: jax.device_put(v, shardings[k]) for k, v in b.items()
        }

    def train(self, num_steps: int) -> TrainerReport:
        target = self.step_no + num_steps
        while self.step_no < target:
            batch = self._batch()
            t0 = time.monotonic()
            try:
                if self.fail_hook and self.fail_hook(self.step_no):
                    raise RuntimeError(f"injected failure @ step {self.step_no}")
                self.state, metrics = self.step_fn(self.state, batch)
                loss = float(metrics["loss"])
            except Exception:
                if not self.restore_latest():
                    # no checkpoint yet: restart from scratch, same seed
                    self.state = self.artifacts.init_state(
                        jax.random.PRNGKey(self.tcfg.seed)
                    )
                    self.dataset._step = 0
                    self.step_no = 0
                    self.report.restores += 1
                continue
            dt = time.monotonic() - t0
            self.step_no += 1
            self.report.steps += 1
            self.report.losses.append(loss)
            self.report.step_times_s.append(dt)
            # straggler detection on the step-time EMA
            if self._ema is not None and dt > self.straggler_factor * self._ema:
                self.report.straggler_events += 1
                if self.on_straggler:
                    self.on_straggler()
            self._ema = dt if self._ema is None else 0.9 * self._ema + 0.1 * dt
            self._maybe_checkpoint()
        if self.ckpt:
            self.ckpt.save(self.step_no, self._snapshot(), blocking=True)
            self.report.checkpoints += 1
        return self.report

    # ------------------------------------------------------------------
    def remesh(self, new_mesh) -> None:
        """Elastic scaling: rebuild step artifacts on ``new_mesh`` and
        re-shard the live state onto it."""
        self.mesh = new_mesh
        self.artifacts = make_train_step(self.cfg, self.tcfg, new_mesh)
        self.step_fn = self.artifacts.jitted(donate=False)
        shardings = self.artifacts.state_shardings()
        self.state = jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), self.state, shardings
        )


def specinf_backoff(scheduler) -> Callable[[], None]:
    """Straggler -> filling backoff: halve the collocated-inference token
    ceiling on the live Algorithm-1 scheduler (restored by the next
    conservative->stable cycle's config)."""

    def backoff():
        scheduler._tokens = scheduler._tokens / 2.0

    return backoff
