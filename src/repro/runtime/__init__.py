from repro.runtime.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
    state_specs,
)
from repro.runtime.step import make_serve_step, make_train_step

__all__ = [
    "param_specs",
    "batch_specs",
    "cache_specs",
    "state_specs",
    "make_train_step",
    "make_serve_step",
]
