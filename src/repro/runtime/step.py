"""Train / serve step builders over the production mesh.

``make_train_step`` assembles the full training step (microbatched grad
accumulation -> global-norm clip -> AdamW with schedule) and returns it with
matching sharding trees, so callers (launcher, dry-run, tests) never
re-derive specs by hand.  ``make_serve_step`` / ``make_prefill_step`` build
the inference programs the decode/prefill shapes lower.

All builders are allocation-free: ``abstract_*`` products are
ShapeDtypeStructs via ``jax.eval_shape``, which is what the 512-device
dry-run feeds to ``.lower()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as T
from repro.models.act_sharding import activation_sharding
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, make_schedule
from repro.runtime import sharding as S

Tree = Any


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainStepArtifacts:
    step: Callable[[Tree, Tree], tuple[Tree, Tree]]
    cfg: ModelConfig
    tcfg: TrainConfig
    mesh: Any
    state_specs: Tree
    batch_specs: Tree
    metric_specs: Tree

    # -- shardings (NamedSharding trees) ---------------------------------
    def state_shardings(self) -> Tree:
        return S.named(self.mesh, self.state_specs)

    def batch_shardings(self) -> Tree:
        return S.named(self.mesh, self.batch_specs)

    def jitted(self, donate: bool = True):
        return jax.jit(
            self.step,
            in_shardings=(self.state_shardings(), self.batch_shardings()),
            out_shardings=(
                self.state_shardings(),
                S.named(self.mesh, self.metric_specs),
            ),
            donate_argnums=(0,) if donate else (),
        )

    # -- abstract inputs for AOT lowering ---------------------------------
    def abstract_state(self) -> Tree:
        return abstract_train_state(self.cfg, self.tcfg)

    def abstract_batch(self, shape: ShapeConfig) -> Tree:
        return abstract_batch(self.cfg, shape)

    # -- real initialization ----------------------------------------------
    def init_state(self, key) -> Tree:
        params = T.init_params(self.cfg, key, jnp.dtype(self.tcfg.param_dtype))
        return {"params": params, "opt": adamw_init(params)}


def _microbatch_split(x: jax.Array, n_micro: int) -> jax.Array:
    """[B, ...] -> [n_micro, B/n_micro, ...] keeping the batch shards local:
    reshape peels the microbatch index off the *minor* position of the batch
    dim (each shard keeps contiguous rows), then moves it to axis 0."""
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    x = x.reshape(b // n_micro, n_micro, *x.shape[1:])
    return jnp.swapaxes(x, 0, 1)


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh,
    *,
    impl: str = "auto",
) -> TrainStepArtifacts:
    schedule = make_schedule(tcfg)
    compute_dtype = jnp.dtype(tcfg.compute_dtype)
    n_micro = max(1, tcfg.microbatches)

    # Specs up front: the step body pins intermediate shardings with
    # with_sharding_constraint — without it GSPMD mis-propagates through the
    # microbatch reshape/swapaxes and replicates the batch over ``data``
    # (observed: 16x redundant compute on the dry-run HLO).
    state_abs = abstract_train_state(cfg, tcfg)
    param_sp = S.param_specs(
        cfg, state_abs["params"], mesh=mesh, fsdp=tcfg.fsdp, layout=tcfg.layout
    )
    dp = S.dp_axes(mesh, tcfg.layout)
    param_sh = S.named(mesh, param_sp)

    def _constrain_micro(x):
        spec = P(None, dp, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    act_specs = S.activation_specs(
        cfg, mesh, batch_sharded=True, layout=tcfg.layout
    )

    def loss_fn(params, inputs, labels):
        with activation_sharding(mesh, act_specs):
            return T.lm_loss(
                cfg,
                params,
                inputs,
                labels,
                impl=impl,
                remat_policy=tcfg.remat_policy,
                compute_dtype=compute_dtype,
            )

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        inputs, labels = batch["inputs"], batch["labels"]

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, inputs, labels)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            inputs_m = _constrain_micro(_microbatch_split(inputs, n_micro))
            labels_m = _constrain_micro(_microbatch_split(labels, n_micro))

            def micro(acc, xs):
                inp, lab = xs
                (l, m), g = grad_fn(params, inp, lab)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g
                )
                acc = jax.lax.with_sharding_constraint(acc, param_sh)
                return acc, (l, m["ce"], m["moe_aux"])

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, (losses, ces, auxes) = jax.lax.scan(
                micro, acc0, (inputs_m, labels_m)
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = losses.mean()
            metrics = {"ce": ces.mean(), "moe_aux": auxes.mean()}
        grads = jax.lax.with_sharding_constraint(grads, param_sh)

        if tcfg.grad_compression == "int8_ef":
            # int8 error-feedback quantization of the cross-device gradient
            # (wire-level savings measured via the shard_map pod exchange in
            # the §Perf harness; here the EF loop keeps optimizer math honest)
            from repro.optim import ef_int8_compress_decompress

            err = state["err"]
            pairs = jax.tree.map(ef_int8_compress_decompress, grads, err)
            grads = jax.tree.map(
                lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple)
            )
            new_err = jax.tree.map(
                lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple)
            )

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip_norm)
        lr = schedule(opt["step"])
        new_params, new_opt = adamw_update(grads, opt, params, lr=lr, cfg=tcfg)
        new_state = {"params": new_params, "opt": new_opt}
        if tcfg.grad_compression == "int8_ef":
            new_state["err"] = new_err
        out_metrics = {
            "loss": loss,
            "ce": metrics["ce"],
            "moe_aux": metrics["moe_aux"],
            "grad_norm": gnorm,
            "lr": lr,
        }
        return new_state, out_metrics

    # -- specs -------------------------------------------------------------
    state_specs = {
        "params": param_sp,
        "opt": S.opt_state_specs(
            cfg, state_abs["params"], tcfg.zero1, mesh, fsdp=tcfg.fsdp,
            layout=tcfg.layout,
        ),
    }
    if tcfg.grad_compression == "int8_ef":
        state_specs["err"] = param_sp
    batch_sp = S.batch_specs(cfg, None, mesh, layout=tcfg.layout)
    metric_specs = {k: P() for k in ("loss", "ce", "moe_aux", "grad_norm", "lr")}
    return TrainStepArtifacts(
        step=train_step,
        cfg=cfg,
        tcfg=tcfg,
        mesh=mesh,
        state_specs=state_specs,
        batch_specs=batch_sp,
        metric_specs=metric_specs,
    )


# ---------------------------------------------------------------------------
# Abstract pytrees (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> Tree:
    return jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), jnp.dtype(dtype))
    )


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig) -> Tree:
    params = abstract_params(cfg, jnp.dtype(tcfg.param_dtype))
    state = {"params": params, "opt": jax.eval_shape(adamw_init, params)}
    if tcfg.grad_compression == "int8_ef":
        state["err"] = jax.eval_shape(
            lambda p: jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p
            ),
            params,
        )
    return state


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig) -> Tree:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"labels": sds((b, s), jnp.int32)}
    if cfg.embed_inputs:
        batch["inputs"] = sds((b, s, cfg.d_model), jnp.float32)
    else:
        batch["inputs"] = sds((b, s), jnp.int32)
    return batch


def abstract_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> Tree:
    return jax.eval_shape(
        functools.partial(T.init_cache, cfg, batch, max_seq, jnp.dtype(dtype))
    )


# ---------------------------------------------------------------------------
# Serve steps (decode / prefill)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeStepArtifacts:
    step: Callable
    cfg: ModelConfig
    mesh: Any
    shape: ShapeConfig
    param_specs: Tree
    input_specs: Tree  # tokens / prompt inputs
    cache_specs: Optional[Tree]
    out_specs: Tree
    compute_dtype: Any

    def jitted(self, donate_cache: bool = True):
        if self.cache_specs is not None:
            in_sh = (
                S.named(self.mesh, self.param_specs),
                S.named(self.mesh, self.input_specs),
                S.named(self.mesh, self.cache_specs),
            )
            donate = (2,) if donate_cache else ()
        else:
            in_sh = (
                S.named(self.mesh, self.param_specs),
                S.named(self.mesh, self.input_specs),
            )
            donate = ()
        return jax.jit(
            self.step,
            in_shardings=in_sh,
            out_shardings=S.named(self.mesh, self.out_specs),
            donate_argnums=donate,
        )

    def abstract_inputs(self) -> tuple:
        raise NotImplementedError  # built by the factory below


def _serve_fsdp(cfg: ModelConfig, mesh, override: Optional[bool]) -> bool:
    """FSDP serve weights when the model-sharded copy alone would crowd HBM
    (> ~8 GiB/chip in bf16)."""
    if override is not None:
        return override
    model = S.axis_size(mesh, "model")
    return cfg.param_count() * 2 / model > 8 * 1024**3


def make_serve_step(
    cfg: ModelConfig,
    mesh,
    shape: ShapeConfig,
    *,
    compute_dtype=jnp.bfloat16,
    fsdp: Optional[bool] = None,
    cache_dtype=None,
) -> ServeStepArtifacts:
    """One-token decode microstep: (params, tokens [B], cache) ->
    (next_tokens [B], cache).  This is SpecInF's admission quantum.
    ``cache_dtype`` (e.g. float8_e4m3fn) stores the KV cache quantized —
    halves the dominant decode memory term (§Perf)."""
    cache_dtype = cache_dtype or compute_dtype

    dp_size = 1
    for a in S.dp_axes(mesh):
        dp_size *= mesh.shape[a]
    batch_sharded = (
        shape.global_batch % dp_size == 0 and shape.global_batch >= dp_size
    )
    act_specs = S.activation_specs(cfg, mesh, batch_sharded=batch_sharded)

    def serve_step(params, tokens, cache):
        with activation_sharding(mesh, act_specs):
            logits, cache = T.decode_step(
                cfg, params, tokens, cache, compute_dtype=compute_dtype
            )
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, cache

    params_abs = abstract_params(cfg, compute_dtype)
    cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len, cache_dtype)
    p_specs = S.param_specs(
        cfg, params_abs, mesh=mesh, fsdp=_serve_fsdp(cfg, mesh, fsdp)
    )
    c_specs = S.cache_specs(cfg, cache_abs, shape, mesh)
    dp = S.dp_axes(mesh)
    tok_spec = P(dp) if batch_sharded else P()
    art = ServeStepArtifacts(
        step=serve_step,
        cfg=cfg,
        mesh=mesh,
        shape=shape,
        param_specs=p_specs,
        input_specs=tok_spec,
        cache_specs=c_specs,
        out_specs=(tok_spec, c_specs),
        compute_dtype=compute_dtype,
    )

    def abstract_inputs():
        tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        return params_abs, tokens, cache_abs

    art.abstract_inputs = abstract_inputs
    return art


def make_prefill_step(
    cfg: ModelConfig,
    mesh,
    shape: ShapeConfig,
    *,
    compute_dtype=jnp.bfloat16,
    impl: str = "auto",
    fsdp: Optional[bool] = None,
    cache_dtype=None,
) -> ServeStepArtifacts:
    """Full-sequence prefill: (params, inputs [B, S]) ->
    (last logits [B, V], cache at seq_len)."""
    cache_dtype = cache_dtype or compute_dtype

    dp_size = 1
    for a in S.dp_axes(mesh):
        dp_size *= mesh.shape[a]
    batch_sharded = (
        shape.global_batch % dp_size == 0 and shape.global_batch >= dp_size
    )
    act_specs = S.activation_specs(cfg, mesh, batch_sharded=batch_sharded)

    def prefill_step(params, inputs):
        with activation_sharding(mesh, act_specs):
            return T.prefill(
                cfg, params, inputs, shape.seq_len, impl=impl,
                compute_dtype=compute_dtype, cache_dtype=cache_dtype,
            )

    params_abs = abstract_params(cfg, compute_dtype)
    cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len, cache_dtype)
    p_specs = S.param_specs(
        cfg, params_abs, mesh=mesh, fsdp=_serve_fsdp(cfg, mesh, fsdp)
    )
    c_specs = S.cache_specs(cfg, cache_abs, shape, mesh)
    dp = S.dp_axes(mesh)
    if cfg.embed_inputs:
        in_spec = P(dp, None, None)
    else:
        in_spec = P(dp, None)
    plan = S.ShardingPlan(cfg, mesh)
    logits_spec = P(dp, plan.vocab())
    art = ServeStepArtifacts(
        step=prefill_step,
        cfg=cfg,
        mesh=mesh,
        shape=shape,
        param_specs=p_specs,
        input_specs=in_spec,
        cache_specs=None,
        out_specs=(logits_spec, c_specs),
        compute_dtype=compute_dtype,
    )

    def abstract_inputs():
        b, s = shape.global_batch, shape.seq_len
        if cfg.embed_inputs:
            inp = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
        else:
            inp = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return params_abs, inp

    art.abstract_inputs = abstract_inputs
    return art
