"""Partition-spec rules: DP / TP / EP / SP / FSDP over the production mesh.

Axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Batch rides ``(pod, data)``; weights ride ``model``:

  * TP (Megatron pairing): attention heads + FFN hidden on ``model`` —
    column-parallel in (wq/wk/wv, wg/wu), row-parallel out (wo, wd), so each
    block costs one fwd all-reduce + one bwd all-reduce.
  * EP: MoE expert dim on ``model``; router replicated.
  * Vocab: embedding + LM head sharded on ``model`` (the loss's logsumexp
    reduces over the sharded vocab with one small psum).
  * SP (decode): when the KV-head count does not divide ``model``, the KV
    cache shards its *sequence* dim on ``model`` instead — GSPMD then lowers
    decode softmax to flash-decode semantics (local partial stats + tiny
    psum) rather than gathering the cache.
  * FSDP: parameters/moments additionally shard a large *free* dim over
    ``data`` (ZeRO-3 via GSPMD; all-gather per scan step, reduce-scatter in
    backward).  Enabled for training and for serve-weights that exceed a
    per-chip budget.

**Divisibility rule** (jit argument shardings must divide exactly): a dim is
sharded only when ``dim % axis_size == 0``; otherwise the dim stays
replicated and (for big tensors) FSDP covers memory.  Consequences recorded
in DESIGN.md: qwen2-7b (28H/kv4) and deepseek-coder-33b (56H/kv8) run
attention replicated over ``model`` in the baseline — the §Perf hillclimb
adds physical head padding to recover TP there.

Specs are assigned by parameter-path pattern over the real pytree, so new
weights fail loudly (no silent replication of a TB-scale tensor): any leaf
with >= 2 dims must match a rule.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

Tree = Any

FSDP_MIN_ELEMENTS = 1 << 20  # don't bother FSDP-sharding tiny leaves


def dp_axes(mesh, layout: str = "tp") -> tuple:
    """Axes carrying the batch.  ``dp256`` folds the model axis into data
    parallelism (pure DP + ZeRO-3) — the §Perf layout for small archs where
    TP's activation collectives dwarf their compute."""
    if layout == "dp256":
        return tuple(
            a for a in mesh.axis_names if a in ("pod", "data", "model")
        )
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


class ShardingPlan:
    """Divisibility-resolved axis choices for one (cfg, mesh, layout)."""

    def __init__(self, cfg: ModelConfig, mesh, layout: str = "tp"):
        self.cfg = cfg
        self.layout = layout
        self.model = axis_size(mesh, "model") if layout == "tp" else 1
        self.data = axis_size(mesh, "data")
        m = self.model
        h_phys = cfg.num_heads_physical
        self.heads_shardable = m > 1 and h_phys > 0 and h_phys % m == 0
        self.kv_shardable = m > 1 and cfg.num_kv_heads > 0 and cfg.num_kv_heads % m == 0
        self.ff_shardable = m > 1 and cfg.d_ff > 0 and cfg.d_ff % m == 0
        self.vocab_shardable = m > 1 and cfg.vocab_size % m == 0
        self.di_shardable = (
            m > 1 and cfg.d_inner % m == 0 if cfg.ssm_state else False
        )
        self.experts_shardable = (
            m > 1 and cfg.num_experts > 0 and cfg.num_experts % m == 0
        )

    def h(self):  # attention q/o head axis
        return "model" if self.heads_shardable else None

    def kv(self):  # attention k/v head axis
        return "model" if self.kv_shardable else None

    def ff(self):
        return "model" if self.ff_shardable else None

    def vocab(self):
        return "model" if self.vocab_shardable else None

    def di(self):
        return "model" if self.di_shardable else None

    def e(self):
        return "model" if self.experts_shardable else None


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def _param_rule(path: str, ndim: int, cfg: ModelConfig, plan: ShardingPlan) -> P:
    """Spec for one parameter leaf.  Leading stacked-layer dims (scan) are
    unsharded; the rule applies to the trailing weight dims."""
    stack = 0
    if path.startswith("layers/"):
        stack = 2 if cfg.family == "hybrid" else 1
    lead = (None,) * stack
    trailing = ndim - stack

    def spec(*tail):
        assert len(tail) == trailing, (path, ndim, tail)
        return P(*lead, *tail)

    if re.search(r"(^|/)embed$", path):
        return P(plan.vocab(), None)
    if re.search(r"(^|/)lm_head$", path):
        return P(None, plan.vocab())
    if re.search(r"final_norm$", path):
        return P(None)
    # --- attention ---
    if re.search(r"attn/wq$", path):
        return spec(None, plan.h(), None)  # [d, H, hd]
    if re.search(r"attn/w[kv]$", path):
        return spec(None, plan.kv(), None)  # [d, kvH, hd]
    if re.search(r"attn/wo$", path):
        return spec(plan.h(), None, None)  # [H, hd, d]
    if re.search(r"attn/bq$", path):
        return spec(plan.h(), None)
    if re.search(r"attn/b[kv]$", path):
        return spec(plan.kv(), None)
    if re.search(r"attn/(q|k)_norm$", path):
        return spec(None)
    # --- dense MLP ---
    if re.search(r"ffn/w[gu]$", path) and cfg.family != "moe":
        return spec(None, plan.ff())
    if re.search(r"ffn/wd$", path) and cfg.family != "moe":
        return spec(plan.ff(), None)
    # --- MoE (expert parallel) ---
    if re.search(r"ffn/router$", path):
        return spec(None, None)
    if re.search(r"ffn/w[gud]$", path):
        return spec(plan.e(), None, None)  # [E, d, f] / [E, f, d]
    # --- norms ---
    if re.search(r"ln\d?$", path) or re.search(r"/ln$", path):
        return spec(None)
    # --- mamba1 ---
    if re.search(r"mixer/in_proj$", path):
        return spec(None, plan.di())
    if re.search(r"mixer/(conv_w|conv_x_w|conv_bc_w)$", path):
        return spec(None, plan.di()) if "bc" not in path else spec(None, None)
    if re.search(r"mixer/(conv_b|conv_x_b)$", path):
        return spec(plan.di())
    if re.search(r"mixer/conv_bc_b$", path):
        return spec(None)
    if re.search(r"mixer/x_proj$", path):
        return spec(plan.di(), None)
    if re.search(r"mixer/dt_proj$", path):
        return spec(None, plan.di())
    if re.search(r"mixer/dt_bias$", path):
        return spec(plan.di()) if cfg.ssm_version == 1 else spec(None)
    if re.search(r"mixer/A_log$", path):
        return spec(plan.di(), None) if cfg.ssm_version == 1 else spec(None)
    if re.search(r"mixer/D$", path):
        return spec(plan.di()) if cfg.ssm_version == 1 else spec(None)
    if re.search(r"mixer/out_proj$", path):
        return spec(plan.di(), None)
    # --- mamba2 ---
    if re.search(r"mixer/in_proj_zx$", path):
        return spec(None, plan.di())
    if re.search(r"mixer/in_proj_bcdt$", path):
        return spec(None, None)
    if re.search(r"mixer/gate_norm$", path):
        return spec(plan.di())
    raise ValueError(f"no sharding rule for parameter {path!r} (ndim={ndim})")


def _add_fsdp(
    spec: P, shape: tuple, stack: int, data_size: int,
    axes: tuple = ("data",), axis_sizes: dict | None = None,
) -> P:
    """Shard the largest still-free trailing dim over the fsdp ``axes``
    (ZeRO-3).  With ``axes=("data", "model")`` (dp256 layout) it tries the
    joint product first, then each axis separately on distinct dims."""
    if data_size <= 1:
        return spec
    n_el = 1
    for d in shape:
        n_el *= d
    if n_el < FSDP_MIN_ELEMENTS:
        return spec
    sizes = axis_sizes or {"data": data_size}
    parts = list(spec) + [None] * (len(shape) - len(spec))

    def place(ax_group) -> bool:
        size = 1
        for a in ax_group:
            size *= sizes.get(a, 1)
        best, best_dim = -1, -1
        for i in range(stack, len(shape)):
            if parts[i] is None and shape[i] % size == 0 and shape[i] > best:
                best, best_dim = shape[i], i
        if best_dim >= 0:
            parts[best_dim] = ax_group if len(ax_group) > 1 else ax_group[0]
            return True
        return False

    if len(axes) > 1 and place(tuple(axes)):
        return P(*parts)
    for a in axes:
        place((a,))
    if any(p is not None for p in parts[stack:]) or spec != P(*parts):
        return P(*parts)
    return spec


def param_specs(
    cfg: ModelConfig, params_shape: Tree, *, mesh, fsdp: bool = False,
    layout: str = "tp",
) -> Tree:
    """PartitionSpec tree mirroring ``params_shape`` (shapes or arrays)."""
    plan = ShardingPlan(cfg, mesh, layout)
    data_size = axis_size(mesh, "data")
    fsdp_axes = ("data", "model") if layout == "dp256" else ("data",)
    axis_sizes = {a: axis_size(mesh, a) for a in ("data", "model")}

    def assign(path, leaf):
        p = _path_str(path)
        spec = _param_rule(p, len(leaf.shape), cfg, plan)
        if fsdp:
            stack = 0
            if p.startswith("layers/"):
                stack = 2 if cfg.family == "hybrid" else 1
            spec = _add_fsdp(
                spec, leaf.shape, stack, data_size,
                axes=fsdp_axes, axis_sizes=axis_sizes,
            )
        return spec

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def opt_state_specs(
    cfg: ModelConfig, params_shape: Tree, zero1: bool, mesh, *,
    fsdp: bool = False, layout: str = "tp",
) -> Tree:
    """AdamW moment specs.  With ``zero1`` the moments additionally shard
    over ``data`` on the first dim that divides evenly (ZeRO-1: sharded
    optimizer update, GSPMD all-gathers the fresh params)."""
    base = param_specs(cfg, params_shape, mesh=mesh, fsdp=fsdp, layout=layout)
    if not zero1:
        mom = base
    else:
        data_size = axis_size(mesh, "data")

        def add_data(path, leaf, spec):
            parts = list(spec)
            parts += [None] * (len(leaf.shape) - len(parts))
            used = set()
            for pt in parts:
                if pt is not None:
                    used |= set(pt if isinstance(pt, tuple) else (pt,))
            if "data" in used:  # fsdp already covers it
                return spec
            for i, (dim, ax) in enumerate(zip(leaf.shape, parts)):
                if ax is None and dim % data_size == 0 and dim >= data_size:
                    parts[i] = "data"
                    return P(*parts)
            return spec

        mom = jax.tree_util.tree_map_with_path(add_data, params_shape, base)
    return {"mu": mom, "nu": mom, "step": P()}


def state_specs(
    cfg: ModelConfig, state_shape: Tree, *, zero1: bool, mesh, fsdp: bool = False
) -> Tree:
    return {
        "params": param_specs(cfg, state_shape["params"], mesh=mesh, fsdp=fsdp),
        "opt": opt_state_specs(
            cfg, state_shape["params"], zero1, mesh, fsdp=fsdp
        ),
    }


# ---------------------------------------------------------------------------
# Data / cache specs
# ---------------------------------------------------------------------------


def batch_specs(
    cfg: ModelConfig, shape: Optional[ShapeConfig], mesh, layout: str = "tp"
) -> Tree:
    dp = dp_axes(mesh, layout)
    spec = {"labels": P(dp, None)}
    if cfg.embed_inputs:
        spec["inputs"] = P(dp, None, None)
    else:
        spec["inputs"] = P(dp, None)
    return spec


def cache_specs(cfg: ModelConfig, cache_shape: Tree, shape: ShapeConfig, mesh) -> Tree:
    """Decode-cache specs.

    Batch rides (pod, data) when it covers the axis; otherwise (long-context
    batch=1) the sequence dim rides it.  KV heads ride ``model`` when they
    divide it; otherwise the cache *sequence* dim rides ``model`` instead
    (flash-decode sequence parallelism)."""
    plan = ShardingPlan(cfg, mesh)
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_shardable = (
        shape.global_batch % dp_size == 0 and shape.global_batch >= dp_size
    )
    b_ax = dp if batch_shardable else None
    # attention cache: prefer kv-head sharding on model; else seq on model;
    # when batch can't cover (pod, data), seq takes dp instead.
    if plan.kv_shardable:
        kvh_ax, s_model = "model", None
    else:
        kvh_ax, s_model = None, "model"
    s_ax: Any = s_model
    if not batch_shardable:
        s_ax = (dp + (s_model,)) if s_model else dp
        if isinstance(s_ax, tuple) and len(s_ax) == 1:
            s_ax = s_ax[0]

    def assign(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if p == "index":
            return P() if nd == 0 else P(b_ax)
        # attention kv caches: [L, B, S, kvH, hd] (or [C, B, S, kvH, hd] hybrid)
        if re.search(r"(^|/)(k|v|shared_k|shared_v)$", p):
            return P(None, b_ax, s_ax, kvh_ax, None)
        # mamba states (leading stack dims: 1 for ssm, 2 for hybrid)
        stack = 2 if cfg.family == "hybrid" else 1
        lead = (None,) * stack
        if p.endswith("conv") or p.endswith("conv_x"):
            return P(*lead, b_ax, None, plan.di())
        if p.endswith("conv_bc"):
            return P(*lead, b_ax, None, None)
        if p.endswith("/h") or p == "h":
            if cfg.family == "hybrid":  # [C, k, B, nh, hp, ds]
                return P(*lead, b_ax, plan.di(), None, None)
            return P(*lead, b_ax, plan.di(), None)  # [L, B, di, ds]
        raise ValueError(f"no cache sharding rule for {p!r}")

    return {
        "index": P(),
        "layers": jax.tree_util.tree_map_with_path(
            lambda pth, l: assign(pth, l), cache_shape["layers"]
        ),
    }


def logits_spec(cfg: ModelConfig, mesh) -> P:
    plan = ShardingPlan(cfg, mesh)
    return P(dp_axes(mesh), None, plan.vocab())


def activation_specs(
    cfg: ModelConfig, mesh, *, batch_sharded: bool = True, layout: str = "tp"
) -> dict:
    """Kind -> PartitionSpec table for ``models.act_sharding.shard``.

    Kinds (leading ``b`` = batch, ``t`` = seq/time position):
      btd   [B, S, d_model]      residual stream
      bthd  [B, S, H, hd]        q / attention out, heads on model
      btkv  [B, S, kvH, hd]      k / v
      btf   [B, S, d_ff]         MLP hidden
      btv   [B, S, vocab]        logits
      bti   [B, S, d_inner]      mamba inner stream
      becd  [B, E, C, d]         MoE expert buffer (experts on model)
      bv    [B, vocab]           decode logits
    """
    plan = ShardingPlan(cfg, mesh, layout)
    b = dp_axes(mesh, layout) if batch_sharded else None
    return {
        "btd": P(b, None, None),
        "bthd": P(b, None, plan.h(), None),
        "btkv": P(b, None, plan.kv(), None),
        "btf": P(b, None, plan.ff()),
        "btv": P(b, None, plan.vocab()),
        "bti": P(b, None, plan.di()),
        "bi": P(b, plan.di()),
        "ecd": P(plan.e(), None, None),  # inside vmap over batch groups
        "bv": P(b, plan.vocab()),
        # flash-attention scan carries ([B, H, S, hd] / [B, H, S])
        "bhtd": P(b, plan.h(), None, None),
        "bht": P(b, plan.h(), None),
    }


def named(mesh, spec_tree: Tree) -> Tree:
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
