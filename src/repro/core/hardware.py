"""Accelerator hardware constants for roofline terms and profile synthesis."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bandwidth: float  # bytes/s per chip
    link_bandwidth: float  # bytes/s per ICI/NVLink link
    hbm_bytes: int
    mfu_assumption: float = 0.4  # sustained fraction for analytic time estimates


# TPU v5e — the deployment target (constants fixed by the assignment).
V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    link_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
)

# A100-40GB — the paper's testbed; used only by the paper-fidelity benches.
A100_40G = HardwareSpec(
    name="a100-40g",
    peak_flops=312e12,
    hbm_bandwidth=1555e9,
    link_bandwidth=300e9,
    hbm_bytes=40 * 1024**3,
)
