"""Training-iteration and inference profiles for the timeline simulator.

Two sources:
  * analytic  -- 6ND-style napkin math over a HardwareSpec (used by the
    paper-fidelity benches: deterministic, no dry-run artifacts needed);
  * dry-run   -- roofline terms of the actually-compiled step (used by the
    §Roofline/§Perf pipeline; see benchmarks/roofline.py).

A profile is the per-iteration segment structure one accelerator observes:
alternating (compute | bubble) spans.  Parallel modes shape it differently
(paper §2.1): DP exposes one gradient-sync tail bubble; MP/TP exposes
many short per-layer collective bubbles; PP exposes per-microbatch gaps
plus warmup/drain bubbles.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.collocation import InstanceProfile, TrainingProfile
from repro.core.hardware import HardwareSpec

Segment = tuple[str, float]  # ("compute" | "bubble", seconds)


@dataclasses.dataclass(frozen=True)
class IterationProfile:
    """One training iteration's segment timeline on a single accelerator."""

    name: str
    segments: tuple[Segment, ...]
    mode: str  # "dp" | "mp" | "pp"

    @property
    def iteration_s(self) -> float:
        return sum(d for _, d in self.segments)

    @property
    def compute_s(self) -> float:
        return sum(d for k, d in self.segments if k == "compute")

    @property
    def bubble_s(self) -> float:
        return sum(d for k, d in self.segments if k == "bubble")

    @property
    def bubble_fraction(self) -> float:
        return self.bubble_s / max(self.iteration_s, 1e-12)

    @property
    def max_bubble_s(self) -> float:
        return max((d for k, d in self.segments if k == "bubble"), default=0.0)

    def as_training_profile(self, peak_memory_bytes: int) -> TrainingProfile:
        return TrainingProfile(
            name=self.name,
            peak_memory_bytes=peak_memory_bytes,
            iteration_time_s=self.iteration_s,
            max_bubble_s=self.max_bubble_s,
            bubble_fraction=self.bubble_fraction,
        )


# ---------------------------------------------------------------------------
# Segment-structure constructors
# ---------------------------------------------------------------------------


def dp_profile(
    name: str,
    compute_s: float,
    comm_s: float,
    overlap: float = 0.3,
    num_buckets: int = 2,
):
    """DP (DDP-style): backward interleaves per-bucket gradient all-reduces,
    so the exposed communication appears as a few mid-backward stalls plus a
    larger tail (last bucket + optimizer sync) — this is the multi-gap
    utilization trace of the paper's Fig. 1a."""
    exposed = comm_s * (1.0 - overlap)
    fwd = compute_s * 0.33
    bwd = compute_s * 0.67
    tail = exposed * 0.42
    fwd_gap = exposed * 0.04  # host-sync / input-pipeline hiccups in forward
    per_bucket_b = (exposed - tail - 2 * fwd_gap) / num_buckets
    per_bucket_c = bwd / num_buckets
    segs = [
        ("compute", fwd * 0.4),
        ("bubble", fwd_gap),
        ("compute", fwd * 0.6),
        ("bubble", fwd_gap),
    ]
    for _ in range(num_buckets):
        segs.append(("compute", per_bucket_c))
        segs.append(("bubble", per_bucket_b))
    segs.append(("bubble", tail))
    return IterationProfile(name, tuple(segs), "dp")


def mp_profile(name: str, compute_s: float, comm_s: float, num_layers: int):
    """MP/TP: per-layer compute followed by a short activation collective.
    2 collectives per layer fwd + 2 bwd (Megatron pairing)."""
    n = max(num_layers, 1)
    c, b = compute_s / n, comm_s / n
    segs = tuple(
        seg for _ in range(n) for seg in (("compute", c), ("bubble", b))
    )
    return IterationProfile(name, segs, "mp")


def pp_profile(
    name: str, compute_s: float, comm_s: float, num_microbatches: int = 12,
):
    """PP: warmup/drain bubbles at iteration boundaries (~35% of exposed
    idle) plus per-microbatch send gaps.  Dividing the mini-batch into
    microbatches shortens each gap to the edge of monitor detectability —
    the paper's stated reason SpecInF's PP gains are marginal (§5.2)."""
    m = max(num_microbatches, 1)
    warm = comm_s * 0.35
    per_mb_c = compute_s / m
    per_mb_b = comm_s * 0.65 / m
    segs = [("bubble", warm * 0.5)]
    for _ in range(m):
        segs.append(("compute", per_mb_c))
        segs.append(("bubble", per_mb_b))
    segs.append(("bubble", warm * 0.5))
    return IterationProfile(name, tuple(segs), "pp")


# ---------------------------------------------------------------------------
# Analytic estimation from model configs (paper-fidelity benches)
# ---------------------------------------------------------------------------


def train_flops(cfg: ModelConfig, tokens: int) -> float:
    """6 * N_active * D."""
    return 6.0 * cfg.active_param_count() * tokens


def analytic_iteration(
    cfg: ModelConfig,
    *,
    seq_len: int,
    per_device_batch: int,
    num_devices: int,
    mode: str,
    hw: HardwareSpec,
    overlap: float = 0.3,
    target_bubble_fraction: float | None = None,
) -> IterationProfile:
    """``target_bubble_fraction``: calibrate exposed communication to a
    *measured* idle fraction (the paper's Fig. 1 traces: ~0.30 for DP, ~0.35
    for MP, ~0.15 for PP) instead of the idealized link-peak estimate —
    production all-reduces at DDP message sizes never reach link peak."""
    tokens = per_device_batch * seq_len
    compute_s = train_flops(cfg, tokens) / (hw.peak_flops * hw.mfu_assumption)
    p_bytes = cfg.param_count() * 2  # bf16 grads on the wire
    if target_bubble_fraction is not None:
        f = target_bubble_fraction
        exposed = compute_s * f / (1.0 - f)
        if mode == "dp":
            return dp_profile(cfg.name, compute_s, exposed, overlap=0.0)
        if mode == "mp":
            return mp_profile(cfg.name, compute_s, exposed, cfg.num_layers)
        if mode == "pp":
            return pp_profile(cfg.name, compute_s, exposed)
        raise ValueError(mode)
    if mode == "dp":
        # ring all-reduce: 2 * size * (n-1)/n per device
        comm_s = 2 * p_bytes * (num_devices - 1) / num_devices / hw.link_bandwidth
        return dp_profile(cfg.name, compute_s, comm_s, overlap)
    if mode == "mp":
        # Megatron TP: 4 all-reduces of [B, S, d] activations per layer
        act = per_device_batch * seq_len * cfg.d_model * 2
        per_ar = 2 * act * (num_devices - 1) / num_devices / hw.link_bandwidth
        comm_s = 4 * per_ar * cfg.num_layers
        return mp_profile(cfg.name, compute_s, comm_s, cfg.num_layers)
    if mode == "pp":
        act = per_device_batch * seq_len * cfg.d_model * 2
        comm_s = 2 * act / hw.link_bandwidth  # boundary sends fwd+bwd
        return pp_profile(cfg.name, compute_s, comm_s)
    raise ValueError(mode)


def analytic_inference_profile(
    cfg: ModelConfig,
    *,
    batch: int,
    seq_or_context: int,
    hw: HardwareSpec,
    kind: str = "decode",
    online: bool = False,
    name: str | None = None,
) -> InstanceProfile:
    """Memory + latency footprint of one inference microstep.

    decode: one token for ``batch`` slots against a ``seq_or_context`` cache —
    memory-bandwidth-bound (reads all active params + cache).
    batch_infer: one full forward at ``seq_or_context`` length (offline
    classification-style microstep; compute-bound).
    """
    p_bytes = cfg.active_param_count() * 2
    if kind == "decode":
        hd = cfg.resolved_head_dim
        cache_bytes = (
            cfg.num_layers * 2 * cfg.num_kv_heads * hd * seq_or_context * batch * 2
            if cfg.num_kv_heads
            else cfg.num_layers * cfg.d_inner * cfg.ssm_state * batch * 4
        )
        latency = (p_bytes + cache_bytes) / hw.hbm_bandwidth
        mem = p_bytes + cache_bytes
    else:
        tokens = batch * seq_or_context
        flops = 2.0 * cfg.active_param_count() * tokens
        latency = flops / (hw.peak_flops * hw.mfu_assumption)
        mem = p_bytes + tokens * cfg.d_model * 8  # activations
    return InstanceProfile(
        name=name or f"{cfg.name}-{kind}",
        peak_memory_bytes=int(mem),
        min_exec_time_s=float(latency),
        online=online,
    )


# -- CV inference workloads from the paper (ResNet152 / VGG19) enter as cost
#    profiles only; there is no CNN in the LM model zoo (DESIGN.md §3).
def cv_profile(name: str, hw: HardwareSpec, *, online: bool = False):
    GFLOPS = {"resnet152": 11.5e9, "vgg19": 19.6e9}
    MEM = {"resnet152": 0.9e9, "vgg19": 1.2e9}
    flops = GFLOPS[name] * 8  # batch 8 per microstep
    return InstanceProfile(
        name=name,
        peak_memory_bytes=int(MEM[name]),
        min_exec_time_s=flops / (hw.peak_flops * 0.25),  # CNNs reach lower MFU
        online=online,
    )
