"""Adaptive Kernel Scheduling — Algorithm 1 of the paper, verbatim.

Three phases driven by the Bubble Monitor's consecutive zero-count ``Z_c``:

  conservative (Z_c <  alpha): tokens = 0,                status = busy
  incremental  (Z_c <= beta) : tokens = min(LL, t*gamma)/m, status = busy
  stable       (Z_c >  beta) : tokens = min(UL, t*gamma)/m, status = idle

``tokens`` feeds the offline-inference Kernel Barrier; ``status`` gates the
online pull-and-execute path.  The only deviation from the paper's listing is
``token_seed``: the listing multiplies the previous token count by gamma,
which would pin tokens at 0 forever after a conservative phase — we restart
growth from a small seed, which is the obvious intended behavior.
"""
from __future__ import annotations

import dataclasses
import enum

from repro.configs.base import SpecInFConfig


class Status(enum.Enum):
    BUSY = "busy"
    IDLE = "idle"


class Phase(enum.Enum):
    CONSERVATIVE = "conservative"
    INCREMENTAL = "incremental"
    STABLE = "stable"


@dataclasses.dataclass
class ScheduleDecision:
    tokens: float  # per collocated offline instance
    status: Status
    phase: Phase


class AdaptiveKernelScheduler:
    """Per-accelerator CKS instance (paper §3.3, Algorithm 1)."""

    def __init__(self, cfg: SpecInFConfig, num_instances: int = 1):
        assert cfg.alpha <= cfg.beta, "alpha must not exceed beta"
        assert num_instances >= 1
        self.cfg = cfg
        self.m = num_instances
        self._tokens = 0.0  # shared pool value before the /m split
        self.last_decision = ScheduleDecision(0.0, Status.BUSY, Phase.CONSERVATIVE)

    def update(self, zero_count: int) -> ScheduleDecision:
        cfg = self.cfg
        if zero_count < cfg.alpha:
            self._tokens = 0.0
            decision = ScheduleDecision(0.0, Status.BUSY, Phase.CONSERVATIVE)
        elif zero_count <= cfg.beta:
            grown = max(self._tokens, cfg.token_seed) * cfg.gamma
            self._tokens = min(cfg.lower_limit, grown)
            decision = ScheduleDecision(
                self._tokens / self.m, Status.BUSY, Phase.INCREMENTAL
            )
        else:
            grown = max(self._tokens, cfg.token_seed) * cfg.gamma
            self._tokens = min(cfg.upper_limit, grown)
            decision = ScheduleDecision(
                self._tokens / self.m, Status.IDLE, Phase.STABLE
            )
        self.last_decision = decision
        return decision

    def reset(self) -> None:
        self._tokens = 0.0
        self.last_decision = ScheduleDecision(0.0, Status.BUSY, Phase.CONSERVATIVE)
