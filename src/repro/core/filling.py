"""Speculative-filling executors binding real JAX compute to the paper's
control plane (monitor -> Algorithm 1 -> barrier / pull-and-execute).

Two modes (DESIGN.md §2):

* ``SpecInFRuntime`` — host-interleaved: each training iteration dispatches
  the real jitted train step, then the collective window (the bubble, whose
  span comes from the iteration profile) is filled with real inference-engine
  microsteps admitted by Algorithm 1.  On CPU the device serializes, so
  *timing* flows on a virtual clock driven by the profile while *compute* is
  real — functional truth with calibrated time (documented limitation).

* ``make_collocated_step`` — the beyond-paper fused program: train_step and
  k decode microsteps compiled into ONE jitted function with no data
  dependence between them, so the XLA scheduler may overlap inference compute
  with training collectives.  k is bucketed to avoid recompiles; Algorithm 1
  picks the bucket each iteration.

Engines built with a draft/target pairing route every quantum through the
speculative loop instead (``engine.spec_decode_loop``), and the token grant
is spent in *verified* tokens: the gamma controller (``spec.controller``)
maps Algorithm-1's phase + observed acceptance to a draft length, and the k
bucket is sized by the expected verified-token yield per round
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.configs.base import SpecInFConfig
from repro.core.bubble_monitor import BubbleMonitor
from repro.core.profiles import IterationProfile
from repro.core.scheduler import AdaptiveKernelScheduler, Status
from repro.serving.engine import DECODE_K_BUCKETS, InferenceEngine, Request
from repro.spec.controller import AdaptiveGammaController


@dataclasses.dataclass
class FillingMetrics:
    train_iterations: int = 0
    train_losses: list = dataclasses.field(default_factory=list)
    offline_microsteps: int = 0
    offline_tokens_generated: int = 0
    online_served: int = 0
    online_latencies_s: list = dataclasses.field(default_factory=list)
    virtual_time_s: float = 0.0
    phase_counts: dict = dataclasses.field(default_factory=dict)
    spec_rounds: int = 0

    def p95_latency_s(self) -> float:
        if not self.online_latencies_s:
            return float("nan")
        return float(np.percentile(self.online_latencies_s, 95))


class SpecInFRuntime:
    """Collocates one training driver with inference engines on a device set,
    running the deployable Algorithm-1 control plane over real JAX compute."""

    def __init__(
        self,
        *,
        train_step: Callable[[Any, Any], tuple[Any, Any]],  # (state, batch) -> (state, metrics)
        train_state: Any,
        batch_iter,
        profile: IterationProfile,
        engine: Optional[InferenceEngine] = None,
        online_requests: Optional[list[Request]] = None,
        cfg: SpecInFConfig = SpecInFConfig(),
        decode_microstep_s: float = 0.005,
        gamma_controller: Optional[AdaptiveGammaController] = None,
    ):
        self.train_step = train_step
        self.state = train_state
        self.batch_iter = batch_iter
        self.profile = profile
        self.engine = engine
        self.cfg = cfg
        self.monitor = BubbleMonitor(cfg)
        self.scheduler = AdaptiveKernelScheduler(cfg, num_instances=1)
        self.metrics = FillingMetrics()
        self.decode_microstep_s = decode_microstep_s
        # Speculative engines spend grants in verified tokens: the gamma
        # controller sizes each round from phase + observed acceptance,
        # parameterized by the engine's draft/target pairing config.
        self.gamma_ctrl = gamma_controller
        if (
            self.gamma_ctrl is None
            and engine is not None
            and engine.spec_enabled
        ):
            sc = engine.spec_cfg
            self.gamma_ctrl = AdaptiveGammaController(
                sc.gamma_buckets, ewma=sc.accept_ewma,
                draft_cost_ratio=sc.draft_cost_ratio,
            )
        self._online_pending = sorted(
            online_requests or [], key=lambda r: r.arrival_time
        )
        self._window_s = cfg.window_ms / 1e3
        # Bind the engine to the runtime's virtual clock: every request
        # timestamp then comes from ONE timebase (never mixed with
        # time.monotonic), and latencies are internally consistent.
        self._vnow = 0.0
        if engine is not None:
            engine.clock = lambda: self._vnow

    # ------------------------------------------------------------------
    def _observe_windows(self, n: int, activity: int = 0):
        """Feed monitor + Algorithm 1 for ``n`` windows; returns the last
        decision.  One observe per window keeps accounting identical whether
        microsteps run fused or one-by-one."""
        d = None
        for _ in range(n):
            zc = self.monitor.observe(activity)
            d = self.scheduler.update(zc)
            ph = d.phase.value
            self.metrics.phase_counts[ph] = self.metrics.phase_counts.get(ph, 0) + 1
        return d

    def _advance_windows(self, span_s: float, activity: int) -> None:
        """Feed the monitor/scheduler for every 2 ms window inside a span."""
        self._observe_windows(
            max(1, int(round(span_s / self._window_s))), activity
        )

    @staticmethod
    def _k_bucket(steps: int) -> int:
        """Largest fused-loop bucket not exceeding ``steps`` (min 1)."""
        return max(pick_bucket(steps, 1.0, DECODE_K_BUCKETS), 1)

    def _spec_min_grant(self, phase) -> float:
        """Smallest Algorithm-1 grant (in verified tokens) that pays for one
        speculative round at the phase's draft length."""
        g = self.gamma_ctrl.gamma_for(phase)
        return self.gamma_ctrl.expected_tokens_per_round(g)

    def _spec_quantum(
        self, phase, token_budget: float, max_spend_s: float, base_now: float
    ) -> tuple[int, float]:
        """One fused speculative loop sized so its *expected verified-token*
        yield stays within ``token_budget`` — the grant is spent in verified
        tokens, not microsteps.  The gamma controller picks the draft length
        from the Algorithm-1 phase and the engine's observed acceptance;
        each round costs ``round_cost_steps`` microstep-equivalents of
        virtual time.  Returns ``(microstep_equivalents, elapsed_s)`` so the
        caller observes monitor windows in proportion to the virtual time
        actually spent (one observe per microstep-equivalent, the same
        convention as the plain path)."""
        g = self.gamma_ctrl.gamma_for(phase)
        exp_tokens = self.gamma_ctrl.expected_tokens_per_round(g)
        round_s = self.decode_microstep_s * self.gamma_ctrl.round_cost_steps(g)
        afford = max(int(token_budget / max(exp_tokens, 1e-9)), 1)
        left = max(int(max_spend_s / round_s), 1)
        k = self._k_bucket(min(afford, left))
        dt = k * round_s
        self._vnow = base_now + dt
        a0, p0 = self.engine.spec_accepted, self.engine.spec_drafted
        self.engine.spec_decode_loop(k, g)
        self.gamma_ctrl.observe(
            self.engine.spec_accepted - a0, self.engine.spec_drafted - p0
        )
        self.metrics.spec_rounds += k
        quanta = max(k, int(round(dt / self.decode_microstep_s)))
        return quanta, dt

    def _fill_bubble(self, bubble_s: float) -> None:
        """Fill a virtual bubble of ``bubble_s`` with real engine compute.

        Microsteps run through the sync-free fused path
        (``engine.decode_loop``): Algorithm 1's token grant picks a k bucket,
        the device runs k microsteps with one host round-trip, and the
        monitor/scheduler are fed the k windows the loop covered.

        Speculative engines route every quantum through
        ``engine.spec_decode_loop`` instead: each round multiplies the
        tokens extracted per grant by the accepted draft length, so the
        grant is spent in *verified* tokens (``_spec_quantum``)."""
        if self.engine is None:
            self.metrics.virtual_time_s += bubble_s
            self._advance_windows(bubble_s, activity=0)
            return
        now = self.metrics.virtual_time_s
        spent = 0.0
        step_cost = self.decode_microstep_s
        cost_tokens = step_cost / 1e-3  # 1 token == 1 ms (KB metering)
        use_spec = self.engine.spec_enabled and self.gamma_ctrl is not None
        while spent < bubble_s:
            d = self._observe_windows(1)
            did_work = False
            budget_steps = max(int((bubble_s - spent) / step_cost), 1)
            # online pull-and-execute on idle signal.  Admission consults
            # real capacity first (free slot AND, on paged engines, pool
            # pages for the request's worst-case need — Principle-I memory
            # accounting): a request the engine cannot hold *yet* stays
            # pending instead of being popped and dropped, while one it can
            # NEVER hold fails loudly rather than starving the queue head.
            if self._online_pending and not self.engine.request_fits(
                self._online_pending[0]
            ):
                bad = self._online_pending.pop(0)
                raise ValueError(
                    f"online request {bad.request_id} can never be admitted "
                    f"(prompt {len(bad.prompt)} tokens, "
                    f"max_new={bad.max_new_tokens}) on this engine"
                )
            if d.status is Status.IDLE and self._online_pending and (
                self._online_pending[0].arrival_time <= now + spent
            ) and self.engine.can_admit(self._online_pending[0]):
                req = self._online_pending.pop(0)
                self._vnow = now + spent
                ok = self.engine.add_request(req)
                if ok:
                    # the outer observe above covers one window of the first
                    # inner loop; every later window gets its own observe
                    covered = 1
                    total0 = self.engine.generated_tokens_total
                    req0 = len(req.generated)
                    while req.finish_time is None and spent < bubble_s:
                        want = max(req.max_new_tokens - len(req.generated), 1)
                        if use_spec:
                            k, dt = self._spec_quantum(
                                d.phase, float(want), bubble_s - spent,
                                now + spent,
                            )
                        else:
                            left = max(int((bubble_s - spent) / step_cost), 1)
                            k = self._k_bucket(min(left, want))
                            dt = k * step_cost
                            self._vnow = now + spent + dt
                            self.engine.decode_loop(k)
                        spent += dt
                        self._observe_windows(k - covered)
                        covered = 0
                    # offline slots piggyback on the online loop's fused
                    # microsteps; credit their tokens to the offline meter
                    self.metrics.offline_tokens_generated += (
                        self.engine.generated_tokens_total - total0
                    ) - (len(req.generated) - req0)
                    if req.finish_time is not None:
                        self.metrics.online_served += 1
                        self.metrics.online_latencies_s.append(
                            req.finish_time - req.arrival_time
                        )
                    did_work = True
            # offline quanta under token metering (speculative engines spend
            # the grant in verified tokens, plain engines in microsteps);
            # either way the grant must cover one whole quantum — a spec
            # round is only admitted once the grant affords its expected
            # verified-token yield, so small conservative/incremental grants
            # never over-spend the bubble budget
            elif self.engine.num_active > 0 and (
                d.tokens >= self._spec_min_grant(d.phase)
                if use_spec else d.tokens >= cost_tokens
            ):
                before = self.engine.generated_tokens_total
                if use_spec:
                    k, dt = self._spec_quantum(
                        d.phase, d.tokens, bubble_s - spent, now + spent
                    )
                else:
                    k = self._k_bucket(
                        min(int(d.tokens // cost_tokens), budget_steps)
                    )
                    dt = k * step_cost
                    self._vnow = now + spent + dt
                    self.engine.decode_loop(k)
                self.metrics.offline_microsteps += k
                self.metrics.offline_tokens_generated += (
                    self.engine.generated_tokens_total - before
                )
                spent += dt
                self._observe_windows(k - 1)
                did_work = True
            if not did_work:
                spent += self._window_s
        self.metrics.virtual_time_s += bubble_s
        self._vnow = self.metrics.virtual_time_s

    # ------------------------------------------------------------------
    def run(self, num_iterations: int) -> FillingMetrics:
        for _ in range(num_iterations):
            batch = next(self.batch_iter)
            self.state, step_metrics = self.train_step(self.state, batch)
            loss = step_metrics.get("loss")
            if loss is not None:
                self.metrics.train_losses.append(float(loss))
            for kind, dur in self.profile.segments:
                if kind == "compute":
                    self.metrics.virtual_time_s += dur
                    self._advance_windows(dur, activity=1)
                else:
                    self._fill_bubble(dur)
            self.metrics.train_iterations += 1
        return self.metrics


# ---------------------------------------------------------------------------
# Beyond-paper: fused collocated step (bucketed k)
# ---------------------------------------------------------------------------


def make_collocated_step(
    train_step_fn: Callable,
    decode_step_fn: Callable,
    *,
    k_buckets: tuple[int, ...] = (0, 1, 2, 4, 8),
    decode_loop_fn: Optional[Callable] = None,
):
    """Build jitted fused programs ``{k: fn}`` where fn runs the train step
    plus k chained decode microsteps in one XLA program.  The decode chain
    has no data dependence on the train step, so the latency-hiding scheduler
    overlaps it with the training collectives (verified in §Perf by the
    fused program's collective/compute schedule).

    The decode chain is a ``lax.scan`` over microsteps (the engine's
    ``decode_loop`` shape), so the fused program's HLO stays O(1) in k
    instead of unrolling — all buckets share the same compile-size budget.
    Pass ``decode_loop_fn(params, tokens, cache, k) -> (tokens, cache)`` to
    supply a custom loop (e.g. ``transformer.decode_loop`` with masking);
    by default the chain is built from ``decode_step_fn``.
    """
    if decode_loop_fn is None:

        def decode_loop_fn(params, tokens, cache, k):
            def body(carry, _):
                t, c = carry
                logits, c = decode_step_fn(params, t, c)
                t = jax.numpy.argmax(logits, axis=-1).astype(jax.numpy.int32)
                return (t, c), None

            (t, c), _ = jax.lax.scan(body, (tokens, cache), None, length=k)
            return t, c

    def fused(k):
        def fn(train_state, batch, infer_params, tokens, cache):
            new_state, metrics = train_step_fn(train_state, batch)
            t, c = decode_loop_fn(infer_params, tokens, cache, k)
            return new_state, metrics, t, c

        return jax.jit(fn, donate_argnums=(0, 4))

    return {k: fused(k) for k in k_buckets}


def pick_bucket(tokens: float, microstep_tokens: float, buckets=(0, 1, 2, 4, 8)) -> int:
    """Largest bucket affordable under the current Algorithm-1 token grant."""
    affordable = int(tokens // max(microstep_tokens, 1e-9))
    best = 0
    for b in buckets:
        if b <= affordable:
            best = b
    return best
