"""Speculative-filling executors binding real JAX compute to the paper's
control plane (monitor -> Algorithm 1 -> barrier / pull-and-execute).

Two modes (DESIGN.md §2):

* ``SpecInFRuntime`` — host-interleaved: each training iteration dispatches
  the real jitted train step, then the collective window (the bubble, whose
  span comes from the iteration profile) is filled with real inference-engine
  microsteps admitted by Algorithm 1.  On CPU the device serializes, so
  *timing* flows on a virtual clock driven by the profile while *compute* is
  real — functional truth with calibrated time (documented limitation).

* ``make_collocated_step`` — the beyond-paper fused program: train_step and
  k decode microsteps compiled into ONE jitted function with no data
  dependence between them, so the XLA scheduler may overlap inference compute
  with training collectives.  k is bucketed to avoid recompiles; Algorithm 1
  picks the bucket each iteration.

Engines built with a draft/target pairing route every quantum through the
speculative loop instead, and the token grant is spent in *verified*
tokens: the gamma controller (``spec.controller``) maps Algorithm-1's
phase + observed acceptance to a draft length, and the k bucket is sized
by the expected verified-token yield per round (DESIGN.md §4).

Since the EngineCore redesign (DESIGN.md §6) Algorithm 1 is ONE pluggable
``SchedulerPolicy`` (``SpecInFPolicy``): the runtime feeds each monitor
window's decision to ``EngineCore.step(grant)`` and the policy decides
admission (online pull-and-execute, preempting RUNNING offline slots when
capacity blocks), the offline token metering, and the k/gamma quantum
shape.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.configs.base import SpecInFConfig
from repro.core.bubble_monitor import BubbleMonitor
from repro.core.profiles import IterationProfile
from repro.core.scheduler import AdaptiveKernelScheduler, Status
from repro.obs import Observability
from repro.obs.trace import _num as _jnum
from repro.resilience.faults import FaultInjector
from repro.serving.core import (
    Grant,
    Priority,
    RequestState,
    RevocationSignal,
    SamplingParams,
    SchedulerPolicy,
    StepOutputs,
    StepPlan,
    largest_bucket,
)
from repro.serving.engine import InferenceEngine, Request
from repro.spec.controller import AdaptiveGammaController


class FillingMetrics:
    """Run-level metrics for one SpecInF filling run.

    Since the observability layer (DESIGN.md §8) the latency/TTFT
    distributions and the lifecycle counters are DERIVED VIEWS over the
    engine's metrics registry: the core records every sample once, as it
    happens, on the engine's single clock, and this class projects the
    run's slice of it.  Baselines snapshot the registry at construction, so
    a pre-warmed engine never leaks earlier activity into a fresh run.

    The old unbounded ``online_latencies_s`` / ``online_ttft_s`` list
    fields survive as properties over the registry's streaming histograms:
    while a histogram still holds its raw samples (up to its exact cap) the
    lists — and therefore every percentile — reproduce the historical
    values bit-for-bit; past the cap memory stays bounded and percentiles
    are bin-interpolated (the lists are gone and raise instead of lying).

    Quantities that are *run-local* rather than engine-level (train
    iterations/losses, phase counts, virtual time, offline microsteps,
    spec rounds) stay plain attributes."""

    def __init__(self, obs: Optional[Observability] = None):
        #: engine-less runs (bubble accounting only) get a private registry
        self.obs = obs if obs is not None else Observability(tracing=False)
        m = self.obs.metrics
        self._ttft = m.histogram("core/online_ttft_s")
        self._lat = m.histogram("core/online_latency_s")
        self._ttft_base = self._ttft.count
        self._lat_base = self._lat.count
        self._served = m.counter("core/finished/online")
        self._served_base = self._served.value
        self._offline_tok = m.counter("core/generated_tokens/offline")
        self._offline_tok_base = self._offline_tok.value
        self._preempt = m.counter("core/preemptions")
        self._preempt_base = self._preempt.value
        self.train_iterations = 0
        self.train_losses: list = []
        self.offline_microsteps = 0
        self.virtual_time_s = 0.0
        self.phase_counts: dict = {}
        self.spec_rounds = 0

    # -- registry-backed views -----------------------------------------
    @property
    def online_served(self) -> int:
        return self._served.value - self._served_base

    @property
    def offline_tokens_generated(self) -> int:
        return self._offline_tok.value - self._offline_tok_base

    @property
    def preemptions(self) -> int:
        return self._preempt.value - self._preempt_base

    @property
    def online_latencies_s(self) -> list:
        """Online end-to-end latencies this run (exact list while the
        histogram is under its cap; past it, query the percentiles)."""
        return self._lat.values()[self._lat_base:]

    @property
    def online_ttft_s(self) -> list:
        """Time-to-first-token per online request (arrival -> first output
        token), stamped by the core on the step that produced it — prefill
        skips from prefix-cache hits show up here, where end-to-end latency
        alone would hide them."""
        return self._ttft.values()[self._ttft_base:]

    def _percentile(self, hist, base: int, q: float) -> float:
        if hist.count - base <= 0:
            return float("nan")
        if hist.exact:
            return float(np.percentile(hist.values()[base:], q))
        return hist.percentile(q)

    def p95_latency_s(self) -> float:
        return self._percentile(self._lat, self._lat_base, 95)

    def ttft_percentile_s(self, q: float) -> float:
        return self._percentile(self._ttft, self._ttft_base, q)

    def p95_ttft_s(self) -> float:
        return self.ttft_percentile_s(95)


class SpecInFPolicy(SchedulerPolicy):
    """Algorithm 1 as a ``SchedulerPolicy`` (paper §3.3 -> DESIGN.md §6).

    * ONLINE admission is the pull-and-execute path: gated on the IDLE
      status and arrival time.  When capacity blocks (no free slot, or no
      pool pages), admission preempts a RUNNING OFFLINE slot instead of
      queueing behind it — the paper's p95 protection inside bubbles.
    * OFFLINE quanta spend the Kernel-Barrier token grant, and only run
      when the grant covers one whole quantum (speculative engines spend
      grants in *verified* tokens, so the bar is the expected yield of one
      round at the phase's draft length).
    * Online execution, once admitted, is never token-metered — only its
      admission is gated.
    """

    def __init__(
        self,
        *,
        microstep_tokens: float = 1.0,
        gamma_ctrl: Optional[AdaptiveGammaController] = None,
        preemption: bool = True,
        prefill_token_cost_steps: float = 0.0,
    ):
        #: Kernel-Barrier token cost of one plain microstep (1 token/ms).
        self.microstep_tokens = microstep_tokens
        self.gamma_ctrl = gamma_ctrl
        self.preemption = preemption
        #: profiled per-prefill-token step cost in microstep-equivalents
        #: (DESIGN.md §7): converts a bubble window into a prefill token
        #: budget, so a grant can never be overrun by a long prompt.  0
        #: keeps prefill free in the cost model (the historical behavior).
        self.prefill_token_cost_steps = prefill_token_cost_steps

    def _spec(self, core) -> bool:
        return (
            core.engine.spec_enabled or core.engine.host_spec_enabled
        ) and self.gamma_ctrl is not None

    def min_offline_grant(self, core, phase) -> float:
        """Smallest grant that pays for one whole offline quantum."""
        if self._spec(core):
            g = self.gamma_ctrl.gamma_for(phase)
            return self.gamma_ctrl.expected_tokens_per_round(g)
        return self.microstep_tokens

    def plan(self, core, grant: Grant) -> StepPlan:
        admit = []
        if grant.online_ok:
            admit += [
                cr for cr in core.waiting[Priority.ONLINE]
                if self.eligible(cr, grant)
            ]
        offline_grant_ok = grant.tokens >= self.min_offline_grant(
            core, grant.phase
        )
        if offline_grant_ok:
            admit += [
                cr for cr in core.waiting[Priority.OFFLINE]
                if self.eligible(cr, grant)
            ]
        plan = StepPlan(admit=admit, preempt_to_admit=self.preemption)
        online = [
            cr for cr in list(core.slot_requests.values()) + admit
            if cr.priority is Priority.ONLINE
        ]
        room = max(int(grant.max_cost_steps), 1)
        if online:
            # dedicated quantum: size by the online work's remaining budget
            want = max(max(cr.remaining_budget for cr in online), 1)
            self._size_quantum(plan, core, grant, want)
        elif core.slot_requests or admit:
            # offline quantum: the grant must cover it whole
            if offline_grant_ok:
                if self._spec(core):
                    self._size_quantum(plan, core, grant, grant.tokens)
                else:
                    steps = int(grant.tokens // self.microstep_tokens)
                    plan.k = largest_bucket(min(steps, room))
                    plan.cost_steps = float(plan.k)
        # unified token-budget step (DESIGN.md §7): clamp decode rounds to
        # the grant's token budget, then spend what remains — of both the
        # budget and the bubble room, priced at the profiled per-token
        # cost — on streaming prefill chunks
        decode_tokens = self._clamp_k_to_budget(plan, core, grant)
        self.plan_prefill(core, grant, plan, decode_tokens)
        return plan

    def _size_quantum(self, plan, core, grant, want_tokens: float) -> None:
        """Pick k (and gamma) so the quantum's expected token yield stays
        within ``want_tokens`` and its cost within the bubble room."""
        if self._spec(core):
            g = self.gamma_ctrl.gamma_for(grant.phase)
            exp = self.gamma_ctrl.expected_tokens_per_round(g)
            # grant-aware routing (DESIGN.md §10): model-free host rounds
            # spend ~1 bubble step where a draft round spends
            # 1 + (gamma+1)*cost_ratio — Algorithm-1 grants are priced by
            # what will actually run
            plan.proposer = core.engine.route_proposer(g)
            rc = (
                core.engine.proposer_round_cost(plan.proposer, g)
                if plan.proposer is not None
                else self.gamma_ctrl.round_cost_steps(g)
            )
            afford = max(int(want_tokens / max(exp, 1e-9)), 1)
            left = max(int(grant.max_cost_steps / rc), 1)
            plan.k = largest_bucket(min(afford, left))
            plan.gamma = g
            plan.cost_steps = plan.k * rc
        else:
            room = max(int(grant.max_cost_steps), 1)
            plan.k = largest_bucket(min(room, int(max(want_tokens, 1))))
            plan.cost_steps = float(plan.k)

    def observe(self, outputs: StepOutputs) -> None:
        if self.gamma_ctrl is not None and outputs.spec_proposed:
            self.gamma_ctrl.observe(
                outputs.spec_accepted, outputs.spec_proposed
            )


class SpecInFRuntime:
    """Collocates one training driver with inference engines on a device set,
    running the deployable Algorithm-1 control plane over real JAX compute."""

    def __init__(
        self,
        *,
        train_step: Callable[[Any, Any], tuple[Any, Any]],  # (state, batch) -> (state, metrics)
        train_state: Any,
        batch_iter,
        profile: IterationProfile,
        engine: Optional[InferenceEngine] = None,
        online_requests: Optional[list[Request]] = None,
        cfg: SpecInFConfig = SpecInFConfig(),
        decode_microstep_s: float = 0.005,
        gamma_controller: Optional[AdaptiveGammaController] = None,
        faults: Optional[FaultInjector] = None,
        journal=None,
    ):
        self.train_step = train_step
        self.state = train_state
        self.batch_iter = batch_iter
        self.profile = profile
        self.engine = engine
        self.cfg = cfg
        # Seeded chaos (DESIGN.md §9): one injector shared by every fault
        # point in the stack — the runtime consults ``runtime/early_resume``
        # per bubble, and the same instance is handed down to the engine and
        # page pool so a single seed reproduces the whole fault schedule.
        self.faults = faults
        if faults is not None and engine is not None:
            faults.metrics = engine.obs.metrics
            if engine.fault_injector is None:
                engine.fault_injector = faults
                if engine.pool is not None:
                    engine.pool.fault_injector = faults
        self.monitor = BubbleMonitor(cfg)
        self.scheduler = AdaptiveKernelScheduler(cfg, num_instances=1)
        # metrics share the engine's registry (DESIGN.md §8): the core
        # records TTFT/latency/preemptions as they happen and FillingMetrics
        # is this run's view over them
        self.metrics = FillingMetrics(
            obs=engine.obs if engine is not None else None
        )
        self.decode_microstep_s = decode_microstep_s
        # Speculative engines spend grants in verified tokens: the gamma
        # controller sizes each round from phase + observed acceptance,
        # parameterized by the engine's draft/target pairing config.
        self.gamma_ctrl = gamma_controller
        if (
            self.gamma_ctrl is None
            and engine is not None
            and (engine.spec_enabled or engine.host_spec_enabled)
        ):
            sc = engine.spec_cfg
            self.gamma_ctrl = AdaptiveGammaController(
                sc.gamma_buckets, ewma=sc.accept_ewma,
                draft_cost_ratio=sc.draft_cost_ratio,
            )
        self._window_s = cfg.window_ms / 1e3
        # Bind the engine to the runtime's virtual clock: every request
        # timestamp then comes from ONE timebase (never mixed with
        # time.monotonic), and latencies are internally consistent.
        self._vnow = 0.0
        self.core = None
        self.recovery = None
        if engine is not None:
            engine.clock = lambda: self._vnow
            # Algorithm 1 as the engine core's scheduler policy.  Reusing
            # ``engine.core`` keeps requests admitted through the legacy
            # shim (add_request) in the same lifecycle the runtime steps.
            self.core = engine.core
            self.core.policy = SpecInFPolicy(
                microstep_tokens=decode_microstep_s / 1e-3,
                gamma_ctrl=self.gamma_ctrl,
                prefill_token_cost_steps=cfg.prefill_token_cost_steps,
            )
            # Requests submitted/admitted before this point were stamped on
            # the engine's OLD clock (usually wall time).  Restamp them to
            # the virtual epoch so they are pullable from the first bubble
            # — the same "no mixed timebases" rule the legacy add_request
            # applied to default-arrival offline work.  RUNNING slots are
            # restamped too: a wall-clock arrival would otherwise never
            # satisfy the policy's arrival gate if the slot is preempted
            # and must be re-admitted on the virtual clock.
            tr = engine.obs.tracer
            for q in self.core.waiting.values():
                for cr in q:
                    cr.arrival_time = 0.0
                    tr.restamp_arrival(cr.request_id, 0.0)
            for cr in self.core.slot_requests.values():
                cr.arrival_time = 0.0
                tr.restamp_arrival(cr.request_id, 0.0)
            # Crash durability (DESIGN.md §11): replay any existing journal
            # BEFORE fresh submissions, so a restarted runtime re-arms
            # bubble filling with the previous incarnation's surviving
            # requests already queued (restamped onto the virtual clock —
            # replay runs after the restamp loop above, so its shift-based
            # stamps are not clobbered back to 0), then attach so this
            # incarnation's lifecycle is journaled in turn.
            if journal is not None:
                self.recovery = journal.recover_into(self.core)
                journal.attach(self.core)
            for r in sorted(
                online_requests or [], key=lambda r: r.arrival_time
            ):
                self.core.submit(
                    r.prompt,
                    SamplingParams(max_new_tokens=r.max_new_tokens),
                    priority=(
                        Priority.ONLINE if r.online else Priority.OFFLINE
                    ),
                    arrival_time=r.arrival_time,
                )
        self.journal = journal

    # ------------------------------------------------------------------
    def _observe_windows(self, n: int, activity: int = 0):
        """Feed monitor + Algorithm 1 for ``n`` windows; returns the last
        decision.  One observe per window keeps accounting identical whether
        microsteps run fused or one-by-one."""
        d = None
        for _ in range(n):
            zc = self.monitor.observe(activity)
            d = self.scheduler.update(zc)
            ph = d.phase.value
            self.metrics.phase_counts[ph] = self.metrics.phase_counts.get(ph, 0) + 1
        return d

    def _advance_windows(self, span_s: float, activity: int) -> None:
        """Feed the monitor/scheduler for every 2 ms window inside a span."""
        self._observe_windows(
            max(1, int(round(span_s / self._window_s))), activity
        )

    def _fill_bubble(self, bubble_s: float) -> None:
        """Fill a virtual bubble of ``bubble_s`` with real engine compute,
        one ``EngineCore.step()`` quantum at a time.

        Each pass observes one 2 ms monitor window, converts the
        Algorithm-1 decision into a ``Grant`` (token grant, IDLE gate for
        online admission, phase for the gamma controller, and the bubble
        room as ``max_cost_steps``), and lets ``SpecInFPolicy`` decide what
        the quantum does: admit (preempting offline slots when an online
        arrival is capacity-blocked), pick the k bucket / draft length, and
        drive the fused loop.  The step's cost in microstep-equivalents
        advances the virtual clock and the monitor window count — the same
        accounting whether the quantum was plain or speculative.

        Revocation (DESIGN.md §9): when the bubble's ``RevocationSignal``
        is armed (seeded early-resume chaos) every grant carries it — a
        revoked quantum ends the fill immediately, the overrun past the
        resume instant is recorded, and the rest of the span is fed to the
        monitor as training activity."""
        if self.engine is None:
            self.metrics.virtual_time_s += bubble_s
            self._advance_windows(bubble_s, activity=0)
            return
        now = self.metrics.virtual_time_s
        tracer = self.engine.obs.tracer
        tracer.span("bubble", "train", now, now + bubble_s, span_s=bubble_s)
        sig, resume_at = self._arm_revocation(now, bubble_s)
        spent = 0.0
        step_cost = self.decode_microstep_s
        revoked = False
        while spent < bubble_s:
            base = now + spent
            if sig is not None and sig.check(base):
                revoked = True  # revoked on a quantum boundary: run nothing
                break
            d = self._observe_windows(1)
            self._vnow = base  # admission/TTFT stamps land at quantum start
            # the monitor/Algorithm-1 state behind this quantum's grant —
            # the core folds it into the quantum trace event
            tracer.window_state = {
                **self.monitor.state(),
                "status": d.status.value,
                "phase": d.phase.value,
                "tokens": _jnum(d.tokens),
            }
            grant = Grant(
                tokens=d.tokens,
                online_ok=d.status is Status.IDLE,
                phase=d.phase,
                now=base,
                max_cost_steps=max((bubble_s - spent) / step_cost, 1.0),
                token_budget=self.cfg.step_token_budget or math.inf,
                # retirement stamps land at quantum END: the core advances
                # the clock once the plan's cost is known, before the loop
                advance_clock=lambda steps, _b=base: setattr(
                    self, "_vnow", _b + steps * step_cost
                ),
                revocation=sig,
                revoke_check_steps=max(self.cfg.revocation_check_steps, 1),
            )
            out = self.core.step(grant)
            if out.cost_steps <= 0:
                if out.revoked:
                    revoked = True
                    break
                spent += self._window_s
                continue
            dt = out.cost_steps * step_cost
            spent += dt
            self._vnow = base + dt
            # the outer observe covered the quantum's first window
            quanta = max(out.k, int(round(out.cost_steps)))
            self._observe_windows(quanta - 1)
            self._record_step(out)
            if out.revoked or (sig is not None and sig.check(self._vnow)):
                # cut mid-plan, or tripped right as the quantum completed
                revoked = True
                break
        if not revoked and sig is not None and sig.check(now + bubble_s):
            # armed inside the span but no quantum was running to cut
            # (tiny bubble, or no grant) — the early resume still happened
            revoked = True
        if revoked:
            m = self.engine.obs.metrics
            m.counter("fault/early_resume").inc()
            m.histogram("fault/revocation_overrun_s").record(
                max(0.0, self._vnow - resume_at)
            )
            self.monitor.notice_activity()
            remaining = bubble_s - spent
            if remaining > 0:
                # training owns the rest of the span: the monitor sees it
                # as active windows, so Algorithm 1 stops granting
                self._advance_windows(remaining, activity=1)
        self.metrics.virtual_time_s += bubble_s
        self._vnow = self.metrics.virtual_time_s

    def _arm_revocation(self, now: float, bubble_s: float):
        """Build this bubble's revocation signal (DESIGN.md §9).

        Chaos: when the injector fires ``runtime/early_resume``, training
        is declared to resume at a seeded fraction (25–75%) of the
        profiled bubble — the signal is armed at that virtual instant,
        and ``EngineCore.step`` must yield within the documented token
        bound once it trips.  Without a fault, a signal is still attached
        whenever ``cfg.revocation_check_steps > 0`` (unarmed, never
        fires) so the sub-dispatch path is exercised; under the default
        config grants carry no signal and the single-dispatch quantum is
        byte-identical to pre-§9 behavior."""
        faults = self.faults
        if faults is not None and faults.should_fire("runtime/early_resume"):
            frac = 0.25 + 0.5 * faults.uniform("runtime/early_resume")
            resume_at = now + frac * bubble_s
            sig = RevocationSignal()
            sig.arm(resume_at, reason="early_resume")
            return sig, resume_at
        if self.cfg.revocation_check_steps > 0:
            return RevocationSignal(), math.inf
        return None, math.inf

    def _record_step(self, out: StepOutputs) -> None:
        """Fold one quantum's StepOutputs into the RUN-LOCAL metrics.  The
        engine-level quantities the old version stamped here (TTFT/latency
        samples, preemptions, served/offline-token counts) are now recorded
        by the core into the shared registry as they happen —
        ``FillingMetrics`` reads them back as derived views."""
        online_active = any(
            ro.priority is Priority.ONLINE
            and (ro.new_tokens or ro.state is RequestState.RUNNING)
            for ro in out.outputs
        )
        if out.gamma is not None:
            self.metrics.spec_rounds += out.k
        if not online_active:
            self.metrics.offline_microsteps += out.k

    # ------------------------------------------------------------------
    def run(self, num_iterations: int) -> FillingMetrics:
        for _ in range(num_iterations):
            batch = next(self.batch_iter)
            self.state, step_metrics = self.train_step(self.state, batch)
            loss = step_metrics.get("loss")
            if loss is not None:
                self.metrics.train_losses.append(float(loss))
            for kind, dur in self.profile.segments:
                if kind == "compute":
                    t0 = self.metrics.virtual_time_s
                    self.metrics.virtual_time_s += dur
                    if self.engine is not None:
                        self.engine.obs.tracer.span(
                            "train_compute", "train", t0, t0 + dur
                        )
                    self._advance_windows(dur, activity=1)
                else:
                    self._fill_bubble(dur)
            self.metrics.train_iterations += 1
        return self.metrics


# ---------------------------------------------------------------------------
# Beyond-paper: fused collocated step (bucketed k)
# ---------------------------------------------------------------------------


def make_collocated_step(
    train_step_fn: Callable,
    decode_step_fn: Callable,
    *,
    k_buckets: tuple[int, ...] = (0, 1, 2, 4, 8),
    decode_loop_fn: Optional[Callable] = None,
):
    """Build jitted fused programs ``{k: fn}`` where fn runs the train step
    plus k chained decode microsteps in one XLA program.  The decode chain
    has no data dependence on the train step, so the latency-hiding scheduler
    overlaps it with the training collectives (verified in §Perf by the
    fused program's collective/compute schedule).

    The decode chain is a ``lax.scan`` over microsteps (the engine's
    ``decode_loop`` shape), so the fused program's HLO stays O(1) in k
    instead of unrolling — all buckets share the same compile-size budget.
    Pass ``decode_loop_fn(params, tokens, cache, k) -> (tokens, cache)`` to
    supply a custom loop (e.g. ``transformer.decode_loop`` with masking);
    by default the chain is built from ``decode_step_fn``.
    """
    if decode_loop_fn is None:

        def decode_loop_fn(params, tokens, cache, k):
            def body(carry, _):
                t, c = carry
                logits, c = decode_step_fn(params, t, c)
                t = jax.numpy.argmax(logits, axis=-1).astype(jax.numpy.int32)
                return (t, c), None

            (t, c), _ = jax.lax.scan(body, (tokens, cache), None, length=k)
            return t, c

    def fused(k):
        def fn(train_state, batch, infer_params, tokens, cache):
            new_state, metrics = train_step_fn(train_state, batch)
            t, c = decode_loop_fn(infer_params, tokens, cache, k)
            return new_state, metrics, t, c

        return jax.jit(fn, donate_argnums=(0, 4))

    return {k: fused(k) for k in k_buckets}


def pick_bucket(tokens: float, microstep_tokens: float, buckets=(0, 1, 2, 4, 8)) -> int:
    """Largest bucket affordable under the current Algorithm-1 token grant.

    Thin wrapper over ``serving.core.largest_bucket`` (one bucket-floor
    implementation); a leading 0 bucket means "grant affords nothing"."""
    return largest_bucket(int(tokens // max(microstep_tokens, 1e-9)), buckets)
