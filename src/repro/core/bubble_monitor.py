"""Bubble Monitor (paper §3.3) — sliding-window activity statistics.

The GPU original hijacks CUDA launches via LD_PRELOAD and counts kernels per
2 ms window.  The TPU adaptation (DESIGN.md §2) feeds the same statistic from
a different source: per-window *device activity quanta* — in the calibrated
simulator these come from the training timeline; in the live runtime from
host timestamps around step dispatch; on real hardware they would come from
the static collective schedule of the compiled step.  Everything downstream
of ``observe()`` is source-agnostic and identical to the paper.
"""
from __future__ import annotations

import collections

from repro.configs.base import SpecInFConfig


class BubbleMonitor:
    """Counts per-window activity; reports the trailing run of zero windows."""

    def __init__(self, cfg: SpecInFConfig):
        self.cfg = cfg
        self.window = collections.deque(maxlen=cfg.window_len)
        self._zero_run = 0
        #: out-of-band early-resume notices (DESIGN.md §9)
        self.interrupts = 0

    def observe(self, activity_count: int) -> int:
        """Record one window's activity count; returns current zero-count Z_c."""
        self.window.append(activity_count)
        if activity_count == 0:
            self._zero_run += 1
        else:
            self._zero_run = 0
        return self._zero_run

    @property
    def zero_count(self) -> int:
        return self._zero_run

    def notice_activity(self) -> None:
        """Out-of-band activity notice (DESIGN.md §9): called the moment
        training resumes *inside* a span the profile predicted idle —
        e.g. on a revoked grant — so the zero run is cut immediately
        instead of waiting for the next window-boundary ``observe``.
        Algorithm 1 then sees Z_c = 0 and stops granting."""
        self.interrupts += 1
        self._zero_run = 0

    def utilization(self) -> float:
        """Fraction of recent windows with activity (diagnostics only)."""
        if not self.window:
            return 0.0
        return sum(1 for c in self.window if c > 0) / len(self.window)

    def state(self) -> dict:
        """JSON-able window snapshot for the step trace (DESIGN.md §8): the
        runtime attaches it to each quantum event so a trace shows what the
        monitor believed when the scheduling decision was made."""
        return {
            "zero_count": self._zero_run,
            "windows": len(self.window),
            "utilization": self.utilization(),
            "interrupts": self.interrupts,
        }

    def reset(self) -> None:
        self.window.clear()
        self._zero_run = 0
