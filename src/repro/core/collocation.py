"""Collocation planner — Principles I & II (paper §3.2).

Principle-I: sum of peak memory of all collocated instances must stay below
the device HBM limit; pack as many inference instances as fit.
Principle-II: the minimal execution time (batch size 1) of a collocated
*online* inference must be shorter than the maximal training bubble, so at
least one request can be served per iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.configs.base import SpecInFConfig


@dataclasses.dataclass(frozen=True)
class InstanceProfile:
    """Profiled footprint of one workload instance on one accelerator."""

    name: str
    peak_memory_bytes: int
    min_exec_time_s: float = 0.0  # batch-size-1 latency (inference)
    online: bool = False


@dataclasses.dataclass(frozen=True)
class TrainingProfile:
    name: str
    peak_memory_bytes: int
    iteration_time_s: float
    max_bubble_s: float  # longest contiguous idle window per iteration
    bubble_fraction: float = 0.0


@dataclasses.dataclass
class CollocationPlan:
    training: TrainingProfile
    accepted: list[InstanceProfile]
    rejected: list[tuple[InstanceProfile, str]]

    @property
    def num_instances(self) -> int:
        return len(self.accepted)

    @property
    def total_memory_bytes(self) -> int:
        return self.training.peak_memory_bytes + sum(
            i.peak_memory_bytes for i in self.accepted
        )


def plan_collocation(
    training: TrainingProfile,
    candidates: Sequence[InstanceProfile],
    cfg: SpecInFConfig,
) -> CollocationPlan:
    """Greedy packing under Principle-I, gating online work by Principle-II."""
    budget = cfg.hbm_limit_bytes - training.peak_memory_bytes
    if budget < 0:
        raise ValueError(
            f"training instance alone exceeds HBM: {training.peak_memory_bytes}"
            f" > {cfg.hbm_limit_bytes}"
        )
    accepted: list[InstanceProfile] = []
    rejected: list[tuple[InstanceProfile, str]] = []
    for cand in candidates:
        if len(accepted) >= cfg.max_instances:
            rejected.append((cand, "max_instances reached"))
            continue
        if cand.peak_memory_bytes > budget:
            rejected.append(
                (cand, f"Principle-I: needs {cand.peak_memory_bytes}, {budget} left")
            )
            continue
        if cand.online and cand.min_exec_time_s >= training.max_bubble_s:
            rejected.append(
                (
                    cand,
                    "Principle-II: min exec "
                    f"{cand.min_exec_time_s * 1e3:.1f}ms >= max bubble "
                    f"{training.max_bubble_s * 1e3:.1f}ms",
                )
            )
            continue
        accepted.append(cand)
        budget -= cand.peak_memory_bytes
    return CollocationPlan(training, accepted, rejected)
