"""Baseline GPU-sharing policies (paper §5.1): MPS, TGS, Co-Exec, Exclusive.

The implementations live in ``core.simulator`` (they share the timeline
contract with SpecInF); this module is the stable public surface.
"""
from repro.core.simulator import (
    CoExecPolicy,
    ExclusivePolicy,
    MPSPolicy,
    Policy,
    SpecInFPolicy,
    TGSPolicy,
    make_policy,
)

ALL_POLICIES = ("specinf", "mps", "tgs", "co-exec", "exclusive")

__all__ = [
    "Policy",
    "SpecInFPolicy",
    "MPSPolicy",
    "TGSPolicy",
    "CoExecPolicy",
    "ExclusivePolicy",
    "make_policy",
    "ALL_POLICIES",
]
