"""SpecInF core — the paper's contribution as a composable JAX-side system.

Components (paper §3):
  * BubbleMonitor            -- sliding-window idle detection (§3.3)
  * AdaptiveKernelScheduler  -- Algorithm 1 (conservative/incremental/stable)
  * plan_collocation         -- Principles I & II (§3.2)
  * SpecInFRuntime           -- speculative filling over real JAX compute
  * make_collocated_step     -- beyond-paper fused train+infer program
  * simulator / baselines    -- calibrated timeline evaluation vs MPS / TGS /
                                Co-Exec / Exclusive
"""
from repro.core.bubble_monitor import BubbleMonitor
from repro.core.collocation import (
    CollocationPlan,
    InstanceProfile,
    TrainingProfile,
    plan_collocation,
)
from repro.core.filling import (
    FillingMetrics,
    SpecInFRuntime,
    make_collocated_step,
    pick_bucket,
)
from repro.core.scheduler import (
    AdaptiveKernelScheduler,
    Phase,
    ScheduleDecision,
    Status,
)

__all__ = [
    "BubbleMonitor",
    "AdaptiveKernelScheduler",
    "Phase",
    "Status",
    "ScheduleDecision",
    "plan_collocation",
    "CollocationPlan",
    "InstanceProfile",
    "TrainingProfile",
    "SpecInFRuntime",
    "FillingMetrics",
    "make_collocated_step",
    "pick_bucket",
]
