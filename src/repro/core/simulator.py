"""Calibrated discrete-event timeline simulator for GPU/TPU sharing policies.

Evaluation vehicle for the paper's figures on a CPU-only container
(DESIGN.md §2): one representative accelerator executes a repeating training
iteration profile (compute/bubble segments from ``core.profiles``), and a
sharing *policy* decides when collocated inference instances may execute.
Time advances in fixed ticks (default 0.5 ms — finer than the paper's 2 ms
monitor window).  SpecInF's policy wraps the *real* ``BubbleMonitor`` and
``AdaptiveKernelScheduler`` classes, so the simulator exercises the exact
deployable Algorithm-1 implementation.

Contention model (fit to the paper's Co-Exec observations, §5.2):
  * inference overlapping a training *compute* span stretches training by
    ``kappa_train`` and itself runs ``1/(1+kappa_inf)`` slower;
  * inference inside a *bubble* is free (idle compute);
  * MPS partitions statically: inference always at ``mps_inf_share`` speed,
    training pays ``mps_train_overhead`` while inference is active;
  * n concurrent inference instances scale sub-linearly
    (``1/(1+(n-1)*multi_instance_drag)``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import SpecInFConfig
from repro.core.bubble_monitor import BubbleMonitor
from repro.core.profiles import IterationProfile
from repro.core.queues import RequestQueue, SimRequest
from repro.core.scheduler import AdaptiveKernelScheduler, Status


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Interference constants, fit to the paper's §5.2 magnitudes:
    Co-Exec degrades DP training by up to 28% (-> kappa_train); inference
    sharing a saturated device is *starved* behind long training kernels
    (-> kappa_inf ~ 12, the well-documented order-of-magnitude latency
    inflation of uncontrolled co-location that motivates the paper); MPS's
    static partition serves ~15% of exclusive offline throughput in DP."""

    kappa_train: float = 0.35
    kappa_inf: float = 30.0
    mps_inf_share: float = 0.15
    mps_train_overhead: float = 0.04
    multi_instance_drag: float = 0.15
    # Launch-queue delay: an online request issued while training kernels are
    # queued waits behind them before its first kernel runs (the paper's §3.3
    # synchronous-issue observation).  SpecInF avoids it by pulling only on
    # idle; MPS avoids it via its spatial partition (own queue); Co-Exec and
    # TGS pay it whenever they start during a compute span.
    kernel_queue_delay_s: float = 0.040
    tgs_probe_interval_s: float = 0.100
    tgs_increase_per_probe: float = 0.05  # additive-increase step (rate frac)
    # probe busy-fraction above which TGS halves its rate.  DP/MP training
    # runs 65-85% busy, so 0.85 keeps TGS slowly admitting work (the paper's
    # TGS achieves 1/3 - 1/14 of SpecInF, not zero) while still modelling
    # its conservative coarse-grained probing.
    tgs_busy_threshold: float = 0.85
    monitor_overhead_frac: float = 0.01  # SpecInF bookkeeping (paper Fig. 8)
    token_unit_s: float = 0.001  # 1 token == 1 ms of inference execution
    tick_s: float = 0.0005


@dataclasses.dataclass
class OfflineInstance:
    microstep_s: float
    remaining_s: float = 0.0
    executing: bool = False
    completed: int = 0
    current_request: Optional[SimRequest] = None  # online use
    cooldown_until: float = -1.0  # per-instance post-pull busy hold


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class Policy:
    name = "base"
    uses_monitor = False
    pays_launch_queue_delay = False  # online starts stall behind training queue

    def begin(self, profile: IterationProfile, cal: Calibration, m: int):
        self.profile, self.cal, self.m = profile, cal, m

    def on_window(self, activity: int, now: float) -> None:  # 2 ms cadence
        pass

    def allow_offline_start(self, cost_tokens: float, now: float) -> bool:
        return True

    def offline_may_progress(self, tick_s: float) -> bool:
        """Kernel-stream metering: called every tick an offline instance
        wants to advance; consuming budget 'per kernel' (paper KB: each
        forwarded kernel consumes tokens proportionate to its size).  A
        False return stalls the instance *without* device interference —
        blocked kernels are never issued."""
        return True

    def consume(self, cost_tokens: float) -> None:
        pass

    def allow_online_pull(self, now: float) -> bool:
        return True

    def notify_online_pull(self, now: float) -> None:
        pass

    def inference_speed(self, train_computing: bool, n_active: int) -> float:
        drag = 1.0 + (n_active - 1) * self.cal.multi_instance_drag
        if train_computing:
            return 1.0 / ((1.0 + self.cal.kappa_inf) * drag)
        return 1.0 / drag

    def train_speed(self, n_inf_active: int) -> float:
        if n_inf_active > 0:
            return 1.0 / (1.0 + self.cal.kappa_train)
        return 1.0


class SpecInFPolicy(Policy):
    """Wraps the real monitor + Algorithm-1 scheduler + Kernel Barrier
    token metering + online pull-and-execute (paper §3.3).

    Pull gating implements the paper's preemptive-busy via *profiling
    information*: the CKS knows the training profile's bubble durations, so
    a pull is admitted only while the conservative estimate of the current
    bubble's remainder still fits one service (Principle-II applied per
    pull).  The estimate assumes the current bubble is the SHORTEST
    profiled bubble consistent with the observed idle run — speculation
    never overcommits near a bubble's end."""

    name = "specinf"
    uses_monitor = True

    def __init__(self, cfg: SpecInFConfig):
        self.cfg = cfg

    def begin(self, profile, cal, m):
        super().begin(profile, cal, m)
        self.monitor = BubbleMonitor(self.cfg)
        self.scheduler = AdaptiveKernelScheduler(self.cfg, num_instances=m)
        self.allocation = 0.0  # per-instance tokens for the current window
        self.status = Status.BUSY
        self._idle_run_s = 0.0
        self._window_s = self.cfg.window_ms / 1e3
        self.bubble_durations = sorted(
            d for k, d in profile.segments if k == "bubble"
        )
        self.online_service_s = 0.0  # set by the simulator from the queue
        hold = self.cfg.busy_hold_ms / 1e3
        self.busy_hold_s = hold if hold > 0 else 0.0

    def on_window(self, activity: int, now: float) -> None:
        zc = self.monitor.observe(activity)
        d = self.scheduler.update(zc)
        self.allocation = d.tokens
        self.status = d.status
        if activity > 0:
            self._idle_run_s = 0.0
        else:
            self._idle_run_s += self._window_s

    def allow_offline_start(self, cost_tokens: float, now: float) -> bool:
        # one kernel's worth of budget admits the stream; the per-kernel
        # metering below throttles/stalls it
        return self.allocation >= 1.0

    def offline_may_progress(self, tick_s: float) -> bool:
        need = tick_s / self.cal.token_unit_s
        if self.allocation >= need:
            self.allocation -= need
            return True
        return False

    def allow_online_pull(self, now: float) -> bool:
        if self.status is not Status.IDLE:
            return False
        if not self.online_service_s:
            return True
        # Speculative bubble-remainder estimate: among profiled bubbles that
        # could fit one service at all, assume the shortest consistent with
        # the observed idle run.  Micro-bubbles (fwd gaps) are excluded from
        # the match — being wrong about them costs one bounded spill, while
        # letting them mask the big bubbles would forfeit most capacity.
        # The required span prices in multi-instance drag + a 15% guard —
        # a spilled service crawls at the contended rate AND drags training,
        # the paper's cardinal sin.
        drag = 1.0 + (self.m - 1) * self.cal.multi_instance_drag
        need = 1.15 * drag * self.online_service_s
        cands = [d for d in self.bubble_durations if d >= need]
        if not cands:
            return False
        cur = next((d for d in cands if d >= self._idle_run_s), cands[-1])
        return cur - self._idle_run_s >= need


class CoExecPolicy(Policy):
    name = "co-exec"
    pays_launch_queue_delay = True


class MPSPolicy(Policy):
    """Static spatial partition: inference always runs, at a fixed share."""

    name = "mps"

    def inference_speed(self, train_computing: bool, n_active: int) -> float:
        drag = 1.0 + (n_active - 1) * self.cal.multi_instance_drag
        return self.cal.mps_inf_share / drag

    def train_speed(self, n_inf_active: int) -> float:
        if n_inf_active > 0:
            return 1.0 / (1.0 + self.cal.mps_train_overhead)
        return 1.0


class TGSPolicy(Policy):
    """Transparent GPU sharing: coarse utilization probing (~100 ms) with
    additive-increase / multiplicative-decrease rate control — conservative
    by design, so it misses ms-scale bubbles (paper §5.2)."""

    name = "tgs"
    uses_monitor = True
    pays_launch_queue_delay = True

    def begin(self, profile, cal, m):
        super().begin(profile, cal, m)
        self.rate = 0.0  # fraction of time inference may run
        self.bucket = 0.0  # seconds of allowance
        self._probe_acc = 0
        self._probe_windows = 0
        self._last_probe = 0.0

    def on_window(self, activity: int, now: float) -> None:
        self._probe_acc += 1 if activity > 0 else 0  # busy-window fraction
        self._probe_windows += 1
        if now - self._last_probe >= self.cal.tgs_probe_interval_s:
            busy_frac = self._probe_acc / max(self._probe_windows, 1)
            if busy_frac > self.cal.tgs_busy_threshold:
                self.rate = max(0.0, self.rate * 0.5)  # multiplicative decrease
            else:
                self.rate = min(
                    max(0.0, 1.0 - busy_frac),
                    self.rate + self.cal.tgs_increase_per_probe,
                )
            self._probe_acc = 0
            self._probe_windows = 0
            self._last_probe = now
        self.bucket = min(
            self.bucket + self.rate * 0.002, 0.050
        )  # accrue allowance

    def allow_offline_start(self, cost_tokens: float, now: float) -> bool:
        return self.bucket >= self.cal.token_unit_s

    def offline_may_progress(self, tick_s: float) -> bool:
        if self.bucket >= tick_s:
            self.bucket -= tick_s
            return True
        return False

    def allow_online_pull(self, now: float) -> bool:
        return self.bucket >= 0.005


class ExclusivePolicy(Policy):
    """Inference on its own dedicated device (no training present)."""

    name = "exclusive"

    def inference_speed(self, train_computing: bool, n_active: int) -> float:
        drag = 1.0 + (n_active - 1) * self.cal.multi_instance_drag
        return 1.0 / drag

    def train_speed(self, n_inf_active: int) -> float:
        return 1.0


# ---------------------------------------------------------------------------
# Simulation results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    policy: str
    duration_s: float
    train_iterations: float
    train_throughput_norm: float  # vs exclusive training (1.0 = no impact)
    offline_completed: int
    offline_throughput_per_s: float
    offline_norm: float  # vs one exclusive instance on a dedicated device
    online_p95_s: float
    online_mean_s: float
    online_served: int
    phase_fractions: dict


# ---------------------------------------------------------------------------
# Core simulation loop
# ---------------------------------------------------------------------------


def simulate(
    profile: IterationProfile,
    policy: Policy,
    *,
    duration_s: float = 60.0,
    offline_instances: int = 0,
    offline_microstep_s: float = 0.010,
    online_queue: Optional[RequestQueue] = None,
    online_instances: int = 0,
    cal: Calibration = Calibration(),
    specinf_cfg: Optional[SpecInFConfig] = None,
    exclusive_training: bool = False,
) -> SimResult:
    """Run one accelerator for ``duration_s`` under ``policy``.

    ``exclusive_training``: drop all inference work (training-only baseline).
    """
    m = offline_instances + online_instances
    policy.begin(profile, cal, max(m, 1))
    if online_queue is not None and online_queue.pending:
        svc = float(np.median([r.service_s for r in online_queue.pending]))
        if hasattr(policy, "online_service_s"):
            policy.online_service_s = svc
    tick = cal.tick_s
    window_s = (specinf_cfg.window_ms if specinf_cfg else 2.0) / 1e3
    ticks_per_window = max(1, int(round(window_s / tick)))

    segments = list(profile.segments)
    seg_idx, seg_done = 0, 0.0
    train_iterations = 0.0
    # SpecInF bookkeeping overhead: stretch compute segments by the monitor
    # cost when the policy uses a monitor (paper Fig. 8: ~1%).
    train_overhead = 1.0 + (cal.monitor_overhead_frac if policy.uses_monitor else 0.0)

    offline = [OfflineInstance(offline_microstep_s) for _ in range(offline_instances)]
    online = [OfflineInstance(0.0) for _ in range(online_instances)]

    now = 0.0
    ntick = 0
    window_activity = 0
    total_ticks = int(round(duration_s / tick))

    for ntick in range(total_ticks):
        now = ntick * tick
        in_compute = segments[seg_idx][0] == "compute"

        # ---- monitor window boundary -----------------------------------
        if ntick % ticks_per_window == 0 and ntick > 0:
            policy.on_window(window_activity, now)
            window_activity = 0
        if in_compute:
            window_activity += 1

        if exclusive_training:
            # training alone: walk segments at full speed, no inference
            n_active = 0
        else:
            # ---- online pulls ------------------------------------------
            if online_queue is not None:
                for inst in online:
                    if inst.executing or now < inst.cooldown_until:
                        continue
                    if not policy.allow_online_pull(now):
                        break
                    req = online_queue.pull(now)
                    if req is None:
                        break
                    req.start_s = now
                    inst.current_request = req
                    inst.remaining_s = req.service_s
                    # bubble-blind sharers launch behind the training kernel
                    # queue on every start (paper §3.3 synchronous-issue)
                    if policy.pays_launch_queue_delay:
                        inst.remaining_s += cal.kernel_queue_delay_s
                    inst.executing = True
                    # CKS preemptively flips this instance busy after its pull
                    # (paper §3.3); other free instances may still pull.
                    inst.cooldown_until = now + getattr(policy, "busy_hold_s", 0.0)

            # ---- offline starts (Kernel Barrier admission) --------------
            for inst in offline:
                if inst.executing:
                    continue
                cost = inst.microstep_s / cal.token_unit_s
                if policy.allow_offline_start(cost, now):
                    inst.remaining_s = inst.microstep_s
                    inst.executing = True

            # ---- advance inference (kernel-stream metering) -------------
            # Offline instances only *issue* while the barrier grants budget;
            # a stalled instance has no kernels on device, so it neither
            # progresses nor interferes.  Online pulled requests always run
            # (pull-and-execute bypasses the token meter; mispredictions are
            # bounded by the per-instance busy hold).
            progressing: list[OfflineInstance] = []
            for inst in offline:
                if inst.executing and policy.offline_may_progress(tick):
                    progressing.append(inst)
            for inst in online:
                if inst.executing:
                    progressing.append(inst)
            n_active = len(progressing)
            if n_active:
                speed = policy.inference_speed(in_compute, n_active)
                for inst in progressing:
                    inst.remaining_s -= tick * speed
                    if inst.remaining_s <= 0:
                        inst.executing = False
                        if inst.current_request is not None:
                            inst.current_request.finish_s = now + tick
                            online_queue.done(inst.current_request)
                            inst.current_request = None
                        else:
                            inst.completed += 1

        # ---- advance training -------------------------------------------
        kind, dur = segments[seg_idx]
        if kind == "compute":
            rate = policy.train_speed(n_active) / train_overhead
        else:
            rate = 1.0  # communication proceeds regardless
        seg_done += tick * rate
        if seg_done >= dur:
            seg_done -= dur
            seg_idx += 1
            if seg_idx == len(segments):
                seg_idx = 0
                train_iterations += 1

    # partial iteration credit
    done_s = sum(d for _, d in segments[:seg_idx]) + seg_done
    train_iterations += done_s / max(profile.iteration_s, 1e-12)

    exclusive_rate = 1.0 / profile.iteration_s
    train_norm = (train_iterations / duration_s) / exclusive_rate
    off_completed = sum(i.completed for i in offline)
    off_rate = off_completed / duration_s
    off_norm = off_rate * offline_microstep_s  # exclusive one-instance == 1.0

    return SimResult(
        policy=policy.name,
        duration_s=duration_s,
        train_iterations=train_iterations,
        train_throughput_norm=train_norm,
        offline_completed=off_completed,
        offline_throughput_per_s=off_rate,
        offline_norm=off_norm,
        online_p95_s=online_queue.p95_latency() if online_queue else float("nan"),
        online_mean_s=online_queue.mean_latency() if online_queue else float("nan"),
        online_served=len(online_queue.completed) if online_queue else 0,
        phase_fractions={},
    )


def make_policy(name: str, specinf_cfg: Optional[SpecInFConfig] = None) -> Policy:
    name = name.lower()
    if name == "specinf":
        return SpecInFPolicy(specinf_cfg or SpecInFConfig())
    if name in ("co-exec", "coexec"):
        return CoExecPolicy()
    if name == "mps":
        return MPSPolicy()
    if name == "tgs":
        return TGSPolicy()
    if name == "exclusive":
        return ExclusivePolicy()
    raise ValueError(name)
