"""Request queues + Poisson arrival generation (paper §5.1 methodology)."""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class SimRequest:
    arrival_s: float
    service_s: float  # execution time on an otherwise-idle device
    request_id: int
    online: bool
    start_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s


def poisson_arrivals(
    *,
    mean_interval_s: float,
    num_requests: int,
    service_s: float,
    seed: int = 0,
    online: bool = True,
    start_s: float = 0.0,
) -> list[SimRequest]:
    """Exponential inter-arrival times (Poisson process), as in the paper:
    'Poisson distribution is used for generating online inference workloads'
    with a given mean across N total requests."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=mean_interval_s, size=num_requests)
    t = start_s + np.cumsum(gaps)
    return [
        SimRequest(
            arrival_s=float(t[i]),
            service_s=service_s,
            request_id=i,
            online=online,
        )
        for i in range(num_requests)
    ]


class RequestQueue:
    """Priority-aware FIFO with arrival-time gating (requests become
    visible at their arrival timestamp).

    ``pull`` serves the earliest-arrived ONLINE request first, then falls
    back to offline work: the old strictly-FIFO pull could park an online
    arrival behind an earlier offline queue head for the offline request's
    whole service time — head-of-line blocking the paper's p95 story
    cannot afford.  Within a priority class, order stays FIFO by arrival.
    """

    def __init__(self, requests: list[SimRequest]):
        by_arrival = sorted(requests, key=lambda r: r.arrival_s)
        self._online = collections.deque(r for r in by_arrival if r.online)
        self._offline = collections.deque(
            r for r in by_arrival if not r.online
        )
        self.completed: list[SimRequest] = []

    def available(self, now_s: float) -> int:
        return sum(
            1 for r in (*self._online, *self._offline) if r.arrival_s <= now_s
        )

    def pull(self, now_s: float) -> Optional[SimRequest]:
        for q in (self._online, self._offline):
            if q and q[0].arrival_s <= now_s:
                return q.popleft()
        return None

    def done(self, req: SimRequest) -> None:
        self.completed.append(req)

    @property
    def remaining(self) -> int:
        return len(self._online) + len(self._offline)

    @property
    def pending(self) -> list[SimRequest]:
        """Snapshot of not-yet-pulled requests (online first)."""
        return [*self._online, *self._offline]

    def p95_latency(self) -> float:
        lats = [r.latency_s for r in self.completed if r.latency_s is not None]
        if not lats:
            return float("nan")
        return float(np.percentile(lats, 95))

    def mean_latency(self) -> float:
        lats = [r.latency_s for r in self.completed if r.latency_s is not None]
        if not lats:
            return float("nan")
        return float(np.mean(lats))
