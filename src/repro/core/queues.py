"""Request queues + Poisson arrival generation (paper §5.1 methodology)."""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class SimRequest:
    arrival_s: float
    service_s: float  # execution time on an otherwise-idle device
    request_id: int
    online: bool
    start_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s


def poisson_arrivals(
    *,
    mean_interval_s: float,
    num_requests: int,
    service_s: float,
    seed: int = 0,
    online: bool = True,
    start_s: float = 0.0,
) -> list[SimRequest]:
    """Exponential inter-arrival times (Poisson process), as in the paper:
    'Poisson distribution is used for generating online inference workloads'
    with a given mean across N total requests."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=mean_interval_s, size=num_requests)
    t = start_s + np.cumsum(gaps)
    return [
        SimRequest(
            arrival_s=float(t[i]),
            service_s=service_s,
            request_id=i,
            online=online,
        )
        for i in range(num_requests)
    ]


class RequestQueue:
    """FIFO with arrival-time gating (requests become visible at their
    arrival timestamp)."""

    def __init__(self, requests: list[SimRequest]):
        self._pending = collections.deque(sorted(requests, key=lambda r: r.arrival_s))
        self.completed: list[SimRequest] = []

    def available(self, now_s: float) -> int:
        return sum(1 for r in self._pending if r.arrival_s <= now_s)

    def pull(self, now_s: float) -> Optional[SimRequest]:
        if self._pending and self._pending[0].arrival_s <= now_s:
            return self._pending.popleft()
        return None

    def done(self, req: SimRequest) -> None:
        self.completed.append(req)

    @property
    def remaining(self) -> int:
        return len(self._pending)

    def p95_latency(self) -> float:
        lats = [r.latency_s for r in self.completed if r.latency_s is not None]
        if not lats:
            return float("nan")
        return float(np.percentile(lats, 95))

    def mean_latency(self) -> float:
        lats = [r.latency_s for r in self.completed if r.latency_s is not None]
        if not lats:
            return float("nan")
        return float(np.mean(lats))
