"""Mamba1 / Mamba2 state-space blocks.

TPU-native adaptation (DESIGN.md §2): the CUDA selective-scan kernel becomes
  * train/prefill: a *chunked* scan — ``lax.scan`` over sequence chunks with an
    ``associative_scan`` (Mamba1) or SSD matmul form (Mamba2) inside each
    chunk, so the O(S * d_inner * d_state) state tensor is never materialized
    beyond one chunk.  The Pallas kernel in ``kernels/ssm_scan.py`` fuses the
    Mamba1 inner chunk for TPU VMEM.
  * decode: a single recurrence step over carried (conv window, ssm state).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.act_sharding import shard

Params = Any
DEFAULT_CHUNK = 64


# ---------------------------------------------------------------------------
# Depthwise causal conv
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array | None) -> jax.Array:
    """x: [B, S, C]; w: [K, C] depthwise kernel; left-padded causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # gather K shifted views: sum_j x[t-K+1+j] * w[j]
    s = x.shape[1]
    out = jnp.zeros_like(x)
    for j in range(k):  # K is 4 — unrolled python loop is fine
        out = out + xp[:, j : j + s, :] * w[j]
    if b is not None:
        out = out + b
    return out


def causal_conv_step(
    x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """One decode step.  x_t: [B, C]; conv_state: [B, K-1, C] (past inputs)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        out = out + b
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------


def init_mamba1(cfg: ModelConfig, key, dtype) -> Params:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, k = cfg.resolved_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * d**-0.5,
        "conv_w": jax.random.normal(ks[1], (k, di), dtype) * k**-0.5,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * ds), dtype) * di**-0.5,
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dtype) * dtr**-0.5,
        "dt_bias": jnp.full((di,), -2.0, dtype),  # softplus(-2) ~ small init dt
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), dtype) * di**-0.5,
    }


def _scan_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def selective_scan_chunked(
    xi: jax.Array,
    dt: jax.Array,
    B_: jax.Array,
    C_: jax.Array,
    A: jax.Array,
    h0: jax.Array,
    chunk: int = DEFAULT_CHUNK,
    impl: str = "xla",
) -> tuple[jax.Array, jax.Array]:
    """Selective scan h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = h_t . C_t.

    xi/dt: [B, S, di]; B_/C_: [B, S, ds]; A: [di, ds]; h0: [B, di, ds].
    Returns (y [B, S, di], h_final).  Memory bound by one chunk's
    [B, chunk, di, ds] state tensor.
    """
    from repro.kernels import ops  # local import avoids cycles

    b, s, di = xi.shape
    nchunks = max(1, (s + chunk - 1) // chunk)
    pad = nchunks * chunk - s
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))

    def ref_chunk(xi_c, dt_c, B_c, C_c, h):
        # a: [B, Q, di, ds] decay; bb: input contribution
        a = jnp.exp(dt_c[..., None] * A)
        bb = (dt_c * xi_c)[..., None] * B_c[:, :, None, :]
        aa, bbs = jax.lax.associative_scan(_scan_combine, (a, bb), axis=1)
        hs = aa * h[:, None] + bbs  # [B, Q, di, ds]
        y = jnp.einsum("bqdn,bqn->bqd", hs, C_c)
        return y, hs[:, -1]

    def body(h, inputs):
        xi_c, dt_c, B_c, C_c = inputs
        if impl == "pallas":
            y, h_new = ops.ssm_scan_chunk(xi_c, dt_c, B_c, C_c, A, h)
        else:
            y, h_new = ref_chunk(xi_c, dt_c, B_c, C_c, h)
        return h_new, y

    reshape = lambda t: t.reshape(b, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    h_fin, ys = jax.lax.scan(
        body, h0, (reshape(xi), reshape(dt), reshape(B_), reshape(C_))
    )
    y = ys.swapaxes(0, 1).reshape(b, nchunks * chunk, di)
    return y[:, :s], h_fin


def mamba1_block(
    cfg: ModelConfig, p: Params, x: jax.Array, impl: str = "xla"
) -> jax.Array:
    """Full-sequence Mamba1 block. x: [B, S, d]."""
    b, s, _ = x.shape
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    xz = shard(jnp.einsum("bsd,de->bse", x, p["in_proj"]), "bti")
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = causal_conv(xi, p["conv_w"], p["conv_b"])
    xi = jax.nn.silu(xi)
    dbc = jnp.einsum("bse,ef->bsf", xi, p["x_proj"])
    dt_r, B_, C_ = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"]) + p["dt_bias"]
    ).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    y, _ = selective_scan_chunked(
        xi.astype(jnp.float32), dt, B_.astype(jnp.float32), C_.astype(jnp.float32),
        A, h0, impl=impl,
    )
    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * xi
    y = shard(y * jax.nn.silu(z), "bti")
    return shard(jnp.einsum("bse,ed->bsd", y, p["out_proj"]), "btd")


def mamba1_init_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba1_step(
    cfg: ModelConfig, p: Params, x_t: jax.Array, state: Params
) -> tuple[jax.Array, Params]:
    """One decode step. x_t: [B, d]."""
    ds, dtr = cfg.ssm_state, cfg.resolved_dt_rank
    xz = jnp.einsum("bd,de->be", x_t, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = causal_conv_step(xi, state["conv"], p["conv_w"], p["conv_b"])
    xi = jax.nn.silu(xi)
    dbc = jnp.einsum("be,ef->bf", xi, p["x_proj"])
    dt_r, B_, C_ = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,re->be", dt_r, p["dt_proj"]) + p["dt_bias"]
    ).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)  # [B, di, ds]
    h = a * state["h"] + (dt * xi.astype(jnp.float32))[..., None] * B_.astype(
        jnp.float32
    )[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_.astype(jnp.float32)).astype(x_t.dtype)
    y = y + p["D"].astype(x_t.dtype) * xi
    y = y * jax.nn.silu(z)
    return jnp.einsum("be,ed->bd", y, p["out_proj"]), {"conv": conv_state, "h": h}


# ---------------------------------------------------------------------------
# Mamba2 (zamba2) — SSD chunked matmul form
# ---------------------------------------------------------------------------


def init_mamba2(cfg: ModelConfig, key, dtype) -> Params:
    """Projections are kept *unpacked* (z/x vs B/C/dt, conv_x vs conv_bc) so
    tensor-parallel sharding boundaries fall on whole weights instead of
    inside a packed dim (which would force GSPMD reshards)."""
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, k = cfg.ssm_num_heads, cfg.ssm_conv
    ks = jax.random.split(key, 4)
    return {
        "in_proj_zx": jax.random.normal(ks[0], (d, 2 * di), dtype) * d**-0.5,
        "in_proj_bcdt": jax.random.normal(ks[1], (d, 2 * ds + nh), dtype) * d**-0.5,
        "conv_x_w": jax.random.normal(ks[2], (k, di), dtype) * k**-0.5,
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": jax.random.normal(ks[3], (k, 2 * ds), dtype) * k**-0.5,
        "conv_bc_b": jnp.zeros((2 * ds,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[0], (di, d), dtype) * di**-0.5,
    }


def _segsum(logd: jax.Array) -> jax.Array:
    """logd: [..., Q] -> [..., Q, Q] lower-triangular cumulative log decay:
    out[i, j] = sum_{t=j+1..i} logd[t], -inf above diagonal."""
    q = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [.., i, j] = sum_{j+1..i}
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    B_: jax.Array,
    C_: jax.Array,
    A: jax.Array,
    h0: jax.Array,
    chunk: int = DEFAULT_CHUNK,
) -> tuple[jax.Array, jax.Array]:
    """Mamba2 SSD.  x: [B, S, nh, hp]; dt: [B, S, nh]; B_/C_: [B, S, ds];
    A: [nh] (negative); h0: [B, nh, hp, ds].  Returns (y, h_final)."""
    b, s, nh, hp = x.shape
    nchunks = max(1, (s + chunk - 1) // chunk)
    pad = nchunks * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))

    def body(h, inputs):
        x_c, dt_c, B_c, C_c = inputs  # [B,Q,nh,hp], [B,Q,nh], [B,Q,ds]
        logd = dt_c * A  # [B, Q, nh] log decay per step
        L = jnp.exp(_segsum(jnp.moveaxis(logd, -1, 1)))  # [B, nh, Q, Q]
        # intra-chunk: scores[q, p] = C_q . B_p, weighted by decay and dt_p
        scores = jnp.einsum("bqn,bpn->bqp", C_c, B_c)  # [B, Q, Q]
        M = L * scores[:, None] * jnp.moveaxis(dt_c, -1, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhqp,bphx->bqhx", M, x_c)
        # inter-chunk: contribution of carried state
        cum = jnp.cumsum(logd, axis=1)  # [B, Q, nh]
        decay_in = jnp.exp(cum)  # decay from chunk start to step q
        y_inter = jnp.einsum("bqn,bnxs,bqs->bqnx", decay_in, h, C_c)
        # state update: h' = exp(cum[-1]) h + sum_p exp(cum[-1]-cum[p]) dt_p x_p B_p
        decay_out = jnp.exp(cum[:, -1:, :] - cum)  # [B, Q, nh]
        dx = (dt_c * decay_out)[..., None] * x_c  # [B, Q, nh, hp]
        h_new = jnp.exp(cum[:, -1])[..., None, None] * h + jnp.einsum(
            "bqnx,bqs->bnxs", dx, B_c
        )
        return h_new, y_intra + y_inter

    reshape = lambda t: t.reshape(b, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    h_fin, ys = jax.lax.scan(
        body, h0, (reshape(x), reshape(dt), reshape(B_), reshape(C_))
    )
    y = ys.swapaxes(0, 1).reshape(b, nchunks * chunk, nh, hp)
    return y[:, :s], h_fin


def mamba2_block(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba2 block.  x: [B, S, d]."""
    from repro.models.layers import rms_norm

    b, s, _ = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    zx = shard(jnp.einsum("bsd,de->bse", x, p["in_proj_zx"]), "bti")
    z, xr = jnp.split(zx, 2, axis=-1)
    bcdt = jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"])
    bc, dt = jnp.split(bcdt, [2 * ds], axis=-1)
    xi = jax.nn.silu(causal_conv(xr, p["conv_x_w"], p["conv_x_b"]))
    bc = jax.nn.silu(causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"]))
    B_, C_ = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, s, nh, hp).astype(jnp.float32)
    h0 = jnp.zeros((b, nh, hp, ds), jnp.float32)
    y, _ = ssd_chunked(xh, dt, B_.astype(jnp.float32), C_.astype(jnp.float32), A, h0)
    y = y + p["D"][:, None] * xh
    y = shard(y.reshape(b, s, di).astype(x.dtype), "bti")
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])  # gated RMSNorm (Mamba2)
    return shard(jnp.einsum("bse,ed->bsd", y, p["out_proj"]), "btd")


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state), dtype),
        "h": jnp.zeros(
            (batch, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    }


def mamba2_step(
    cfg: ModelConfig, p: Params, x_t: jax.Array, state: Params
) -> tuple[jax.Array, Params]:
    """One decode step.  x_t: [B, d]."""
    from repro.models.layers import rms_norm

    b = x_t.shape[0]
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    zx = jnp.einsum("bd,de->be", x_t, p["in_proj_zx"])
    z, xr = jnp.split(zx, 2, axis=-1)
    bcdt = jnp.einsum("bd,de->be", x_t, p["in_proj_bcdt"])
    bc, dt = jnp.split(bcdt, [2 * ds], axis=-1)
    xi, conv_x = causal_conv_step(xr, state["conv_x"], p["conv_x_w"], p["conv_x_b"])
    xi = jax.nn.silu(xi)
    bc, conv_bc = causal_conv_step(
        bc, state["conv_bc"], p["conv_bc_w"], p["conv_bc_b"]
    )
    bc = jax.nn.silu(bc)
    B_, C_ = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, nh]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # [B, nh]
    xh = xi.reshape(b, nh, hp).astype(jnp.float32)
    h = a[..., None, None] * state["h"] + (dt[..., None] * xh)[..., None] * B_.astype(
        jnp.float32
    )[:, None, None, :]
    y = jnp.einsum("bnxs,bs->bnx", h, C_.astype(jnp.float32))
    y = y + p["D"][:, None] * xh
    y = y.reshape(b, di).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    return jnp.einsum("be,ed->bd", y, p["out_proj"]), {
        "conv_x": conv_x,
        "conv_bc": conv_bc,
        "h": h,
    }
