"""Activation-sharding constraints at block boundaries (MaxText-style).

Model code is mesh-agnostic; the step builders activate a context with the
resolved activation specs, and ``shard(x, kind)`` becomes a
``with_sharding_constraint`` only while a context is live (tests / CPU
smoke paths are unaffected).

Why this exists (observed on the dry-run HLO): without activation anchors
GSPMD resolves the (FSDP x TP) weight shardings by *partial contraction* —
per-layer all-reduces of activation-sized tensors over the fsdp axis, and
attention replicated over ``model``.  Anchoring activations (batch on
``data``/``pod``, heads/ffn/vocab on ``model``) makes it pick the intended
program: per-layer weight all-gather (ZeRO-3) + Megatron-style block
collectives.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_ACTIVE: Optional[tuple] = None  # (mesh, {kind: PartitionSpec})


@contextlib.contextmanager
def activation_sharding(mesh, specs: dict):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = (mesh, specs)
    try:
        yield
    finally:
        _ACTIVE = prev


def shard(x: jax.Array, kind: str) -> jax.Array:
    """Constrain ``x`` to the active context's spec for ``kind`` (no-op
    outside a context or for unknown kinds)."""
    if _ACTIVE is None:
        return x
    mesh, specs = _ACTIVE
    spec = specs.get(kind)
    if spec is None:
        return x
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
