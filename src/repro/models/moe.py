"""Top-k Mixture-of-Experts block (GShard/Switch-style, capacity-bounded).

Dispatch uses scatter/gather with GShard priority positioning instead of the
classic ``[s, e, c]`` one-hot einsum, so the only O(tokens * capacity) buffer
is the real expert activation ``[E, C, d]`` — this keeps the memory roofline
term honest at 1M-token global batches.

Sharding: tokens (group dim) ride the ``data`` axis, experts ride ``model``
(expert parallelism).  The scatter into the expert-sharded buffer is what
GSPMD turns into the dispatch all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.act_sharding import shard


_DISPATCH = "vmap"  # "batched" | "vmap" (perf-experiment switch; see
# EXPERIMENTS.md §Perf — "batched" shards expert compute 4.8x better on
# moonshot but explodes dispatch collectives on dbrx's wider capacity)


def set_dispatch(mode: str) -> None:
    global _DISPATCH
    assert mode in ("batched", "vmap")
    globals()["_DISPATCH"] = mode


def init_moe(cfg: ModelConfig, key, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * d**-0.5,
        "wg": jax.random.normal(ks[1], (e, d, f), dtype) * d**-0.5,
        "wu": jax.random.normal(ks[2], (e, d, f), dtype) * d**-0.5,
        "wd": jax.random.normal(ks[3], (e, f, d), dtype) * f**-0.5,
    }


def expert_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    cap = tokens_per_group * cfg.experts_per_token * cfg.moe_capacity_factor
    cap = int(cap / cfg.num_experts) + 1
    return max(8, ((cap + 7) // 8) * 8)  # multiple of 8 for TPU-friendly layout


def _route_one_group(x, p, cfg: ModelConfig, capacity: int):
    """x: [s, d] one token group. Returns (y [s, d], aux metrics)."""
    s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("sd,de->se", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)  # [s, k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # GShard priority: all 1st choices rank before any 2nd choice, etc.
    ids_t = ids.T.reshape(-1)  # [k*s], k-major
    onehot = jax.nn.one_hot(ids_t, e, dtype=jnp.int32)  # [k*s, e]
    pos = jnp.cumsum(onehot, axis=0) - onehot  # position within expert
    pos_of = jnp.sum(onehot * pos, axis=-1)  # [k*s]
    keep = pos_of < capacity
    dest = ids_t * capacity + jnp.minimum(pos_of, capacity - 1)

    xr = jnp.tile(x, (k, 1))  # [k*s, d]
    contrib = jnp.where(keep[:, None], xr, 0)
    buf = jnp.zeros((e * capacity, d), x.dtype).at[dest].add(contrib)
    buf = buf.reshape(e, capacity, d)
    # NOTE: no with_sharding_constraint here — under vmap a constraint pins
    # the mapped (batch) dim replicated, which costs TBs of dispatch
    # collectives (measured; see EXPERIMENTS.md §Perf moonshot log).

    # per-expert SwiGLU
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(e * capacity, d)

    wt = weights.T.reshape(-1)  # [k*s] aligned with ids_t
    y_r = out[dest] * (wt * keep).astype(x.dtype)[:, None]
    y = y_r.reshape(k, s, d).sum(axis=0)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = jax.nn.one_hot(ids[:, 0], e).mean(axis=0)  # top-1 dispatch fraction
    aux = e * jnp.sum(me * ce)
    dropped = 1.0 - keep.mean()
    return y, (aux, dropped)


def moe_block(cfg: ModelConfig, p, x: jax.Array):
    """x: [B, S, d] -> (y, aux_loss, drop_fraction).

    Train/prefill: each batch row is a routing group (its tokens share
    expert capacity), so group count rides the data axis and routing is
    shard-local.  The dispatch is written as ONE batched scatter into a
    ``[B, E, C, d]`` buffer (no vmap): a with_sharding_constraint inside
    vmap pins the mapped dim replicated, which turned the dispatch into
    per-layer buffer-sized all-reduces over ``data`` (measured 2.3 TB/step
    wire on moonshot train — see EXPERIMENTS.md §Perf).

    Decode (S == 1): the whole batch forms ONE routing group.  Per-row
    groups would hold ``max(8, ...)`` capacity slots per expert for a
    single token — at B=128, E=64 that computes ~85x more expert-FLOPs
    than routed (measured useful ratio 0.001 on the dry-run) and OOMs the
    decode cells.  Batch-grouping drops capacity to ``B*k*cf/E``.
    """
    b, s, d = x.shape
    if s == 1 and b > 1:
        capacity = expert_capacity(cfg, b)
        y, (aux, dropped) = _route_one_group(x[:, 0, :], p, cfg, capacity)
        return shard(y[:, None, :], "btd"), aux, dropped

    if _DISPATCH == "vmap":
        capacity = expert_capacity(cfg, s)
        y, (aux, dropped) = jax.vmap(
            lambda xg: _route_one_group(xg, p, cfg, capacity)
        )(x)
        return shard(y, "btd"), aux.mean(), dropped.mean()

    e, k = cfg.num_experts, cfg.experts_per_token
    capacity = expert_capacity(cfg, s)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)  # [b, s, k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # GShard priority, k-major within each group (batch row)
    ids_t = ids.transpose(0, 2, 1).reshape(b, k * s)  # [b, k*s]
    onehot = jax.nn.one_hot(ids_t, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_of = jnp.sum(onehot * pos, axis=-1)  # [b, k*s]
    keep = pos_of < capacity
    dest = ids_t * capacity + jnp.minimum(pos_of, capacity - 1)

    xr = jnp.tile(x, (1, k, 1))  # [b, k*s, d], k-major
    contrib = jnp.where(keep[..., None], xr, 0)
    # batch-dim scatter: the leading coordinate keeps the op visibly
    # batch-parallel so the group dim stays on ``data`` (a flattened
    # [b*e*cap] scatter hides that and GSPMD falls back to replication)
    bidx = jnp.arange(b)[:, None]
    buf = (
        jnp.zeros((b, e * capacity, d), x.dtype).at[bidx, dest].add(contrib)
    )
    buf = shard(buf.reshape(b, e, capacity, d), "becd")

    g = jnp.einsum("becd,edf->becf", buf, p["wg"])
    u = jnp.einsum("becd,edf->becf", buf, p["wu"])
    h = jax.nn.silu(g) * u
    out = shard(jnp.einsum("becf,efd->becd", h, p["wd"]), "becd")

    wt = weights.transpose(0, 2, 1).reshape(b, k * s)  # aligned with ids_t
    y_r = jnp.take_along_axis(
        out.reshape(b, e * capacity, d), dest[..., None], axis=1
    )
    y_r = y_r * (wt * keep).astype(x.dtype)[..., None]
    y = y_r.reshape(b, k, s, d).sum(axis=1)

    me = probs.mean(axis=1)  # [b, e]
    ce = jax.nn.one_hot(ids[:, :, 0], e).mean(axis=1)
    aux = (e * jnp.sum(me * ce, axis=-1)).mean()
    dropped = 1.0 - keep.mean()
    return shard(y, "btd"), aux, dropped
