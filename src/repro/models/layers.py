"""Shared neural layers: norms, RoPE, GQA attention (full / blocked / decode),
SwiGLU MLP.  Pure functions over explicit parameter pytrees.

Attention exposes three execution paths:
  * ``xla``       -- plain einsum softmax (small sequences)
  * ``xla_flash`` -- lax.scan blocked online-softmax (long prefill; no S^2 buffer)
  * ``pallas``    -- Pallas TPU flash kernel (kernels/flash_attention.py)
The path is chosen by ``repro.kernels.ops.attention`` unless forced.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.act_sharding import shard

Params = Any


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    y = x32 * inv
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(dtype)


def layer_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(dtype)


def norm(cfg: ModelConfig, x: jax.Array, weight: Optional[jax.Array]) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, weight if cfg.parametric_norm else None)
    return rms_norm(x, weight if cfg.parametric_norm else None)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2]."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    inv_freq = rope_frequencies(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, q_heads: int) -> jax.Array:
    """[B, S, kvH, hd] -> [B, S, qH, hd] by group broadcast."""
    b, s, kvh, hd = k.shape
    if kvh == q_heads:
        return k
    reps = q_heads // kvh
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvh, reps, hd)).reshape(
        b, s, q_heads, hd
    )


def attention_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    length_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Plain softmax attention.  q: [B,Sq,H,hd], k/v: [B,Sk,kvH,hd]."""
    qh = q.shape[2]
    k = _repeat_kv(k, qh)
    v = _repeat_kv(v, qh)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None], scores, -1e30)
    if length_mask is not None:  # [B, Sk] valid-key mask (decode)
        scores = jnp.where(length_mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_xla_flash(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_k: int = 1024,
) -> jax.Array:
    """Blocked online-softmax attention (no S^2 buffer) via lax.scan over KV
    blocks.  Used for long-prefill shapes where materializing scores is
    infeasible.  Matches attention_xla to fp32 accumulation error."""
    b, sq, qh, hd = q.shape
    k = _repeat_kv(k, qh)
    v = _repeat_kv(v, qh)
    sk = k.shape[1]
    nblocks = max(1, (sk + block_k - 1) // block_k)
    pad = nblocks * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblocks, block_k, qh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblocks, block_k, qh, hd).transpose(1, 0, 2, 3, 4)
    scale = hd**-0.5
    qpos = jnp.arange(sq)

    def body(carry, blk):
        acc, m, l = carry
        kblk, vblk, start = blk
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk).astype(jnp.float32) * scale
        kpos = start + jnp.arange(block_k)
        valid = kpos[None, :] < sk
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vblk
        ).astype(jnp.float32)
        # pin the carry's sharding: scan carries silently lose it, which
        # replicates the fp32 accumulators over the model axis (observed:
        # +GBs of temp on the 32k prefill dry-runs)
        acc_new = shard(acc_new, "bhtd")
        m_new = shard(m_new, "bht")
        l_new = shard(l_new, "bht")
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, qh, sq, hd), jnp.float32)
    m0 = jnp.full((b, qh, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, qh, sq), jnp.float32)
    starts = jnp.arange(nblocks) * block_k
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + core), train/prefill + decode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    num_heads: int
    num_kv_heads: int
    head_dim: int


def head_mask(cfg: ModelConfig, dtype) -> Optional[jax.Array]:
    """[H_phys] 1/0 mask selecting real q-head slots (None when unpadded).
    With per-group padding, slot ``s`` is real iff ``s % group_phys`` is
    below the logical group size, keeping GQA's head->kv mapping exact."""
    if not cfg.padded_heads:
        return None
    kv = max(cfg.num_kv_heads, 1)
    group_phys = cfg.num_heads_physical // kv
    group_log = cfg.num_heads // kv
    m = (jnp.arange(cfg.num_heads_physical) % group_phys) < group_log
    return m.astype(dtype)


def init_attention(cfg: ModelConfig, key, d_model: int, dtype) -> Params:
    hd = cfg.resolved_head_dim
    h = cfg.num_heads_physical
    ks = jax.random.split(key, 4)
    scale = d_model**-0.5
    p = {
        "wq": jax.random.normal(ks[0], (d_model, h, hd), dtype) * scale,
        "wk": jax.random.normal(ks[1], (d_model, cfg.num_kv_heads, hd), dtype) * scale,
        "wv": jax.random.normal(ks[2], (d_model, cfg.num_kv_heads, hd), dtype) * scale,
        "wo": jax.random.normal(ks[3], (h, hd, d_model), dtype)
        * (cfg.num_heads * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array):
    q = shard(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), "bthd")
    k = shard(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), "btkv")
    v = shard(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), "btkv")
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    *,
    impl: str = "xla",
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence causal attention (train / prefill). x: [B, S, d]."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(cfg, p, x, positions)
    from repro.kernels import ops  # local import to avoid cycles

    out = shard(ops.attention(q, k, v, causal=True, impl=impl), "bthd")
    mask = head_mask(cfg, out.dtype)
    if mask is not None:  # zero padded head slots (and their gradients)
        out = out * mask[None, None, :, None]
    return shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "btd")


def attention_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    kv_cache: tuple[jax.Array, jax.Array],
    cache_index: jax.Array,
    *,
    impl: str = "auto",
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode.  x: [B, 1, d]; cache k/v: [B, S_max, kvH, hd];
    cache_index: [] or [B] int32 current length(s) — per-slot indices allow
    continuous batching (each slot at its own position).

    The attention core is the flash-decode path (``ops.decode_attention``):
    length-aware over the ragged batch instead of dense over S_max."""
    b = x.shape[0]
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
    positions = idx[:, None]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    k_cache, v_cache = kv_cache
    upd = jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
    )
    k_cache = upd(k_cache, k_new.astype(k_cache.dtype), idx)
    v_cache = upd(v_cache, v_new.astype(v_cache.dtype), idx)
    from repro.kernels import ops  # local import to avoid cycles

    out = shard(
        ops.decode_attention(q[:, 0], k_cache, v_cache, idx + 1, impl=impl)[
            :, None
        ],
        "bthd",
    )
    mask = head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "btd")
    return y, (k_cache, v_cache)


def attention_verify(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    kv_cache: tuple[jax.Array, jax.Array],
    cache_index: jax.Array,
    *,
    impl: str = "auto",
    anc: Optional[jax.Array] = None,
    depths: Optional[jax.Array] = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Chunk-verify decode: score T = gamma+1 chunk tokens in one pass.

    x: [B, T, d] — embeddings of the speculative chunk (current token +
    gamma draft tokens); cache k/v: [B, S_max, kvH, hd]; cache_index: [] or
    [B] int32 per-slot prefix length(s).  Writes the chunk's K/V at
    positions ``index .. index + T - 1`` and attends each chunk token to the
    prefix plus the chunk's own causal triangle (``ops.verify_attention``).
    Rollback after acceptance only rewinds ``index`` — rejected positions'
    K/V entries sit beyond the rewound index and are rewritten before ever
    being attended to (the same stale-overwrite invariant bucket-padded
    prefill relies on, DESIGN.md §3/§4).

    Tree mode (``anc`` + ``depths`` given): x holds one embedding per
    packed-tree node; ``anc`` [B, T] int32 ancestor bitmasks select the
    intra-chunk visibility (``ops.tree_verify_attention``); ``depths`` [T]
    int32 per-node tree depth replaces ``arange(T)`` as the RoPE offset so
    sibling branches rotate at the same sequence position.  K/V still
    writes at node-index positions — the slot each bitmask bit refers to.
    A linear chain (depths == arange, anc == cumulative bits) is
    bit-identical to the default path."""
    b, t, _ = x.shape
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
    offs = jnp.arange(t) if depths is None else depths.astype(jnp.int32)
    positions = idx[:, None] + offs[None, :]  # [B, T] RoPE positions
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    k_cache, v_cache = kv_cache
    upd = jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
    )
    k_cache = upd(k_cache, k_new.astype(k_cache.dtype), idx)
    v_cache = upd(v_cache, v_new.astype(v_cache.dtype), idx)
    from repro.kernels import ops  # local import to avoid cycles

    if anc is None:
        core = ops.verify_attention(q, k_cache, v_cache, idx + t, impl=impl)
    else:
        core = ops.tree_verify_attention(
            q, k_cache, v_cache, idx + t, anc, impl=impl
        )
    out = shard(core, "bthd")
    mask = head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "btd")
    return y, (k_cache, v_cache)


def attention_prefill_chunk(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    kv_cache: tuple[jax.Array, jax.Array],
    cache_index: jax.Array,
    chunk_lens: jax.Array,
    *,
    impl: str = "auto",
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Chunked-prefill step (dense cache): C prompt tokens per slot in one
    pass.

    x: [B, C, d] — one fixed-width prefill chunk per slot, zero-padded past
    ``chunk_lens``; cache k/v: [B, S_max, kvH, hd]; cache_index: [B] int32
    per-slot prefill progress; chunk_lens: [B] int32 real tokens per chunk
    (0 == frozen slot).  Writes the chunk's *real* K/V at positions
    ``index .. index + chunk_lens - 1`` — pad rows scatter out of bounds
    and are DROPPED, so a chunk near the sequence horizon can never clamp
    onto (and corrupt) live entries — then attends each real row to the
    prefix plus the chunk's own causal triangle
    (``ops.prefill_chunk_attention``)."""
    b, c, _ = x.shape
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
    positions = idx[:, None] + jnp.arange(c)[None, :]  # [B, C]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    k_cache, v_cache = kv_cache
    s_max = k_cache.shape[1]
    valid = jnp.arange(c)[None, :] < chunk_lens[:, None]  # [B, C]
    pos_w = jnp.where(valid, positions, s_max)  # out of bounds -> dropped
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, c))
    k_cache = k_cache.at[rows, pos_w].set(
        k_new.astype(k_cache.dtype), mode="drop"
    )
    v_cache = v_cache.at[rows, pos_w].set(
        v_new.astype(v_cache.dtype), mode="drop"
    )
    from repro.kernels import ops  # local import to avoid cycles

    out = shard(
        ops.prefill_chunk_attention(
            q, k_cache, v_cache, idx, chunk_lens, impl=impl
        ),
        "bthd",
    )
    mask = head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "btd")
    return y, (k_cache, v_cache)


def paged_kv_write(
    pool: jax.Array,
    new: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
) -> jax.Array:
    """Scatter new K/V rows into the paged pool through the block table.

    pool: [P, page, kvH, hd]; new: [B, T, kvH, hd]; block_tables: [B, W]
    int32; positions: [B, T] int32 logical positions.  Positions whose
    logical page index falls past the table width clamp onto the last
    column, which the engine keeps permanently at the sentinel page — the
    fused loops' overflow writes (frozen slots at the sequence boundary,
    bucket-pad chunk tails) land there instead of corrupting live pages."""
    page = pool.shape[1]
    w = block_tables.shape[1]
    cols = jnp.minimum(positions // page, w - 1)
    pages = jnp.take_along_axis(block_tables, cols, axis=1)  # [B, T]
    return pool.at[pages, positions % page].set(new.astype(pool.dtype))


def attention_decode_paged(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    kv_pool: tuple[jax.Array, jax.Array],
    block_tables: jax.Array,
    cache_index: jax.Array,
    *,
    impl: str = "auto",
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode against the paged KV pool.

    x: [B, 1, d]; pool k/v: [P, page, kvH, hd] physical pages shared across
    slots; block_tables: [B, W] int32 logical->physical page map;
    cache_index: [B] int32 per-slot lengths.  The new token's K/V scatters
    into the slot's own page at ``index`` (always a private page — shared
    prefix pages are never written after insertion), then the attention core
    gathers pages through the block table (``ops.paged_decode_attention``)."""
    b = x.shape[0]
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
    positions = idx[:, None]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    k_pool, v_pool = kv_pool
    k_pool = paged_kv_write(k_pool, k_new, block_tables, positions)
    v_pool = paged_kv_write(v_pool, v_new, block_tables, positions)
    from repro.kernels import ops  # local import to avoid cycles

    out = shard(
        ops.paged_decode_attention(
            q[:, 0], k_pool, v_pool, block_tables, idx + 1, impl=impl
        )[:, None],
        "bthd",
    )
    mask = head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "btd")
    return y, (k_pool, v_pool)


def attention_verify_paged(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    kv_pool: tuple[jax.Array, jax.Array],
    block_tables: jax.Array,
    cache_index: jax.Array,
    *,
    impl: str = "auto",
    anc: Optional[jax.Array] = None,
    depths: Optional[jax.Array] = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Chunk-verify decode against the paged KV pool: T tokens in one pass.

    x: [B, T, d] chunk embeddings; the chunk's K/V scatters into the slot's
    pages at logical positions ``index .. index + T - 1`` before the fused
    prefix+triangle attention (``ops.paged_verify_attention``).  Rollback
    after acceptance only rewinds ``index``: rejected positions sit past the
    rewound index inside the slot's *private* pages and are rewritten before
    ever being attended to — the dense path's stale-overwrite invariant,
    unchanged by paging (DESIGN.md §5).

    Tree mode (``anc`` + ``depths``): same contract as
    ``attention_verify`` — ancestor-bitmask intra-chunk visibility
    (``ops.paged_tree_verify_attention``), depth-based RoPE offsets,
    node-index K/V scatter."""
    b, t, _ = x.shape
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
    pos_w = idx[:, None] + jnp.arange(t)[None, :]  # [B, T] write slots
    if depths is None:
        positions = pos_w
    else:
        positions = idx[:, None] + depths.astype(jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    k_pool, v_pool = kv_pool
    k_pool = paged_kv_write(k_pool, k_new, block_tables, pos_w)
    v_pool = paged_kv_write(v_pool, v_new, block_tables, pos_w)
    from repro.kernels import ops  # local import to avoid cycles

    if anc is None:
        core = ops.paged_verify_attention(
            q, k_pool, v_pool, block_tables, idx + t, impl=impl
        )
    else:
        core = ops.paged_tree_verify_attention(
            q, k_pool, v_pool, block_tables, idx + t, anc, impl=impl
        )
    out = shard(core, "bthd")
    mask = head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "btd")
    return y, (k_pool, v_pool)


def attention_prefill_chunk_paged(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    kv_pool: tuple[jax.Array, jax.Array],
    block_tables: jax.Array,
    cache_index: jax.Array,
    chunk_lens: jax.Array,
    *,
    impl: str = "auto",
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Chunked-prefill step against the paged KV pool.

    x: [B, C, d] chunk embeddings; the chunk's *real* K/V scatters into the
    slot's pages at logical positions ``index .. index + chunk_lens - 1``
    before the fused prefix+triangle attention
    (``ops.paged_prefill_chunk_attention``).  Pad rows are steered onto the
    table's sentinel column (a write sink nobody attends to) instead of
    being dropped — the block-table analog of the dense path's out-of-bounds
    drop.  Earlier chunks' pages — including radix-shared prefix pages —
    are read, never written, so prefix sharing composes with chunking."""
    b, c, _ = x.shape
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (b,))
    positions = idx[:, None] + jnp.arange(c)[None, :]  # [B, C]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    k_pool, v_pool = kv_pool
    page = k_pool.shape[1]
    w = block_tables.shape[1]
    valid = jnp.arange(c)[None, :] < chunk_lens[:, None]  # [B, C]
    # invalid rows clamp onto the last table column == the sentinel page
    pos_w = jnp.where(valid, positions, w * page)
    k_pool = paged_kv_write(k_pool, k_new, block_tables, pos_w)
    v_pool = paged_kv_write(v_pool, v_new, block_tables, pos_w)
    from repro.kernels import ops  # local import to avoid cycles

    out = shard(
        ops.paged_prefill_chunk_attention(
            q, k_pool, v_pool, block_tables, idx, chunk_lens, impl=impl
        ),
        "bthd",
    )
    mask = head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = shard(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), "btd")
    return y, (k_pool, v_pool)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wg": jax.random.normal(ks[0], (d_model, d_ff), dtype) * d_model**-0.5,
        "wu": jax.random.normal(ks[1], (d_model, d_ff), dtype) * d_model**-0.5,
        "wd": jax.random.normal(ks[2], (d_ff, d_model), dtype) * d_ff**-0.5,
    }


def mlp_block(p: Params, x: jax.Array) -> jax.Array:
    g = shard(jnp.einsum("bsd,df->bsf", x, p["wg"]), "btf")
    u = shard(jnp.einsum("bsd,df->bsf", x, p["wu"]), "btf")
    h = jax.nn.silu(g) * u
    return shard(jnp.einsum("bsf,fd->bsd", h, p["wd"]), "btd")
