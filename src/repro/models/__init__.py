"""Model zoo: decoder LMs for all assigned families."""
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    prefill,
)

__all__ = [
    "init_params",
    "forward",
    "lm_loss",
    "init_cache",
    "decode_step",
    "prefill",
]
